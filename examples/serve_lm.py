"""Batched serving example: prefill + greedy decode with PANN weights at a
chosen power budget, across architecture families (attention KV cache,
Mamba2 state, RWKV state).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--power_bits", type=int, default=4)
    args = ap.parse_args()
    summary = serve.main([
        "--arch", args.arch, "--reduced", "--batch", "4",
        "--prompt_len", "16", "--gen", "12",
        "--quant", "pann", "--power_bits", str(args.power_bits)])
    assert summary["generated"] == 12
    print(f"served {summary['arch']} with PANN at the power of a "
          f"{args.power_bits}-bit unsigned MAC: "
          f"{summary['tok_per_s']} tok/s (CPU)")


if __name__ == "__main__":
    main()
