"""Power-accuracy traversal serving example: one server process, a ladder of
PANN operating points, per-request power budgets (repro.serve_engine).

Each request declares the power it can afford (as an unsigned-MAC bit
budget); the engine picks the matching rung from its cached int8 variants
and reports the estimated bit-flip price per generated token in the
response metadata.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402
from repro.serve_engine import build_ladder, select_rung  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--ladder", default="2,4,6")
    ap.add_argument("--budgets", default="4,2,6")
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args(argv)

    n_requests = 6
    summary = serve.main([
        "--arch", args.arch, "--reduced",
        "--power_ladder", args.ladder, "--budgets", args.budgets,
        "--batch", "2", "--prompt_len", "16", "--gen", str(args.gen),
        "--requests", str(n_requests)])

    # assert the output shape so this example can't rot silently
    assert summary["mode"] == "ladder"
    reqs = summary["requests"]
    assert len(reqs) == n_requests
    for r in reqs:
        assert len(r["sample"]) == min(8, args.gen)
        for key in ("rung_bits", "b_x_tilde", "r", "tokens",
                    "est_bitflips_per_token", "est_bitflips_total"):
            assert key in r, key
        assert r["tokens"] == args.gen
    assert summary["engine"]["compilations_after_warmup"] == 1
    served = sorted({r["rung_bits"] for r in reqs})
    # expected rungs follow from the flags: map each budget through the
    # ladder's selection policy (budget-path selection depends only on bits)
    ladder = build_ladder([int(b) for b in args.ladder.split(",")])
    expected = sorted({select_rung(ladder, power_budget_bits=int(b)).bits
                       for b in args.budgets.split(",")})
    assert served == expected, (served, expected)

    print(f"served {summary['arch']}: {n_requests} requests across "
          f"{len(served)} power rungs {served} (bits), one compiled step, "
          f"{summary['tok_per_s']} tok/s (CPU)")
    for r in reqs:
        print(f"  request {r['uid']}: rung {r['rung_bits']}b -> "
              f"{r['est_gbitflips_per_token']*1e3:.3f} Mbit-flips/token")
    return summary


if __name__ == "__main__":
    main()
