"""Fault-tolerance demo: train, crash mid-run, restart from the newest
committed checkpoint, finish — and verify the result equals an uninterrupted
run bit-for-bit (deterministic replayable data + saved optimizer state).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import train  # noqa: E402


def main():
    base = tempfile.mkdtemp(prefix="repro_elastic_")
    args = ["--arch", "llama3-8b", "--reduced", "--batch", "4",
            "--seq", "32", "--lr", "1e-3", "--ckpt_every", "10",
            "--total_steps", "30"]

    print("== uninterrupted 30-step run ==")
    full = train.main(args + ["--steps", "30", "--ckpt_dir", f"{base}/a"])

    print("\n== run to step 10, 'crash', restart, finish ==")
    train.main(args + ["--steps", "10", "--ckpt_dir", f"{base}/b"])
    print("-- simulated node failure; restarting from checkpoint --")
    resumed = train.main(args + ["--steps", "30", "--ckpt_dir", f"{base}/b"])

    drift = abs(resumed["last_loss"] - full["last_loss"])
    print(f"\nfinal losses: uninterrupted {full['last_loss']:.6f} vs "
          f"resumed {resumed['last_loss']:.6f} (drift {drift:.2e})")
    assert drift < 1e-5, "resume drifted!"
    print("checkpoint/restart is exact — no training state was lost.")
    shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    main()
