"""Layer-wise power-budget allocation: plan a ladder of per-module
QuantPolicy trees for llama3-8b and show where every Giga bit-flip goes.

For each rung (an unsigned-MAC bit budget), `planner.allocate_layerwise`
spends the SAME total power as the uniform Algorithm-1 plan, but
non-uniformly: modules with narrow fan-in buy more fidelity per bit flip
(core/policy.py explains why), so the tree's theory score never trails the
uniform plan's — usually it strictly beats it.

    PYTHONPATH=src python examples/layerwise_allocator.py --arch llama3-8b
    PYTHONPATH=src python examples/layerwise_allocator.py --full   # full-size
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs  # noqa: E402
from repro.core import costs, planner  # noqa: E402
from repro.core import policy as pol  # noqa: E402
from repro.core import power as pw  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--ladder", default="2,3,4,6",
                    help="bit budgets of the serving ladder")
    ap.add_argument("--full", action="store_true",
                    help="plan the full-size config (default: reduced)")
    args = ap.parse_args(argv)
    cfg = configs.get_config(args.arch)
    if not args.full:
        cfg = configs.reduced(cfg)
    profile = costs.module_cost_profile(cfg)
    total_macs = sum(m.macs for m in profile)
    act_macs = costs.macs_per_token(cfg).act_macs

    print(f"{cfg.name}: {len(profile)} quantized module roles, "
          f"{total_macs:.3e} weight MACs/token "
          f"(+{act_macs:.3e} act x act)\n")

    ladder_bits = sorted({int(b) for b in args.ladder.split(",")})
    plans = []
    for bits in ladder_bits:
        budget = planner.budget_from_bits(bits)
        lw = planner.allocate_layerwise(budget, profile)
        plans.append(lw)
        print(lw.describe())
        print(lw.bit_table())
        total, breakdown = pol.tree_power_per_token(profile, lw.tree,
                                                    act_macs=act_macs)
        top = sorted(breakdown.items(), key=lambda kv: -kv[1])[:3]
        shares = ", ".join(f"{p} {v / total:.0%}" for p, v in top)
        print(f"  power breakdown: {pw.giga(total):.3f} Gbf/token; "
              f"top spenders: {shares}\n")

    # output-shape assertions so this example can't rot silently
    assert len(plans) == len(ladder_bits)
    for bits, lw in zip(ladder_bits, plans):
        budget_total = planner.budget_from_bits(bits) * total_macs
        assert abs(lw.total_power - budget_total) <= 0.01 * budget_total
        assert lw.score >= lw.uniform_score
        assert len(lw.per_module) == len(profile)
        assert len(lw.bit_table().splitlines()) == len(profile) + 1
    # ladder totals rise monotonically with the rung
    totals = [lw.total_power for lw in plans]
    assert totals == sorted(totals) and totals[0] > 0

    print("(same total power per rung as the uniform ladder — the gain is "
          "purely in WHERE the bit flips are spent)")
    return {"arch": cfg.name, "ladder_bits": ladder_bits,
            "plans": plans, "total_macs": total_macs}


if __name__ == "__main__":
    main()
