"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with PANN quantization-aware training, checkpointing, and restart.

Default is a fast CPU-sized run; pass --full for the ~100M configuration
(slow on CPU, sized for a real accelerator host).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (d=768, 12L) instead of the tiny run")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--quant", default="pann")
    ap.add_argument("--ckpt_dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "llama3-8b", "--steps", str(args.steps),
            "--quant", args.quant, "--r", "2.0",
            "--ckpt_dir", args.ckpt_dir, "--ckpt_every", "100",
            "--batch", "8", "--seq", "256", "--remat"]
    if args.full:
        # ~100M: 12 layers, d_model 768, d_ff 3072 + llama3 128k vocab
        argv += ["--d_model", "768", "--d_ff", "3072", "--layers", "12"]
    else:
        argv += ["--reduced"]
    summary = train.main(argv)
    assert summary["last_loss"] < summary["first_loss"], "did not learn!"
    print(f"loss {summary['first_loss']:.3f} -> {summary['last_loss']:.3f} "
          f"over {summary['steps']} steps "
          f"(mean step {summary['mean_step_s']:.2f}s, "
          f"{summary['stragglers']} stragglers)")


if __name__ == "__main__":
    main()
