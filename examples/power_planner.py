"""Deployment-time power planning: Algorithm 1, the Fig. 3 trade-off, and
the serving ladder (planner.plan_ladder) — no training required.

    PYTHONPATH=src python examples/power_planner.py --arch dbrx-132b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs  # noqa: E402
from repro.core import costs, planner  # noqa: E402
from repro.core import power as pw  # noqa: E402
from repro.serve_engine import build_ladder  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--ladder", default="2,3,4,6",
                    help="bit budgets of the serving ladder")
    args = ap.parse_args(argv)
    cfg = configs.get_config(args.arch)
    shape = configs.SHAPES_BY_NAME["train_4k"]
    macs = costs.network_macs(cfg, shape).scale(
        1.0 / (shape.seq_len * shape.global_batch))

    print(f"{cfg.name}: {costs.param_count(cfg)/1e9:.1f}B params "
          f"({costs.param_count(cfg, active_only=True)/1e9:.1f}B active), "
          f"{macs.total:.3e} MACs/token\n")
    print("power/token under each scheme (Giga bit-flips), and the PANN "
          "plan at each budget:")
    print(f"{'bits':>4} {'signed':>9} {'unsigned':>9} {'PANN plan':>24}")
    rows = []
    for bits in [8, 6, 5, 4, 3, 2]:
        signed = pw.giga(pw.network_power_bitflips(macs, scheme="signed",
                                                   bits=bits))
        unsig = pw.giga(pw.network_power_bitflips(macs, scheme="unsigned",
                                                  bits=bits))
        plan = planner.plan_with_theory(planner.budget_from_bits(bits))
        rows.append({"bits": bits, "signed_gflips": signed,
                     "unsigned_gflips": unsig, "b_x_tilde": plan.b_x_tilde,
                     "r": plan.r})
        print(f"{bits:>4} {signed:>9.2f} {unsig:>9.2f} "
              f"{'b~x=' + str(plan.b_x_tilde) + ' R=' + format(plan.r, '.2f'):>24}")

    # the serving ladder: what repro.serve_engine materializes at startup
    ladder_bits = [int(b) for b in args.ladder.split(",")]
    ops = build_ladder(ladder_bits, d=float(cfg.d_model))
    print(f"\nserving ladder (build_ladder, d={cfg.d_model}) — per-token "
          "price at each rung:")
    ladder = []
    for op in ops:
        per_tok = pw.pann_token_bitflips(macs, op.r, op.b_x_tilde)
        ladder.append({"bits": op.bits, "b_x_tilde": op.b_x_tilde,
                       "r": op.r, "gbitflips_per_token": pw.giga(per_tok)})
        print(f"  rung {op.bits}b: b~x={op.b_x_tilde} R={op.r:.2f} "
              f"-> {pw.giga(per_tok):.2f} Gbit-flips/token")

    # assert the output shape so this example can't rot silently
    assert len(rows) == 6 and len(ladder) == len(set(ladder_bits))
    assert [op.power for op in ops] == sorted(op.power for op in ops)
    for row in ladder:
        assert row["gbitflips_per_token"] > 0

    print("\n(moving between rungs needs NO hardware change with PANN — "
          "only (b~x, R); a regular quantizer needs a different multiplier)")
    return {"rows": rows, "ladder": ladder}


if __name__ == "__main__":
    main()
