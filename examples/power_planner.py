"""Deployment-time power planning (Algorithm 1 + the Fig. 3 trade-off) for
the assigned architectures — no training required.

    PYTHONPATH=src python examples/power_planner.py --arch dbrx-132b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs  # noqa: E402
from repro.core import costs, planner  # noqa: E402
from repro.core import power as pw  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()
    cfg = configs.get_config(args.arch)
    shape = configs.SHAPES_BY_NAME["train_4k"]
    macs = costs.network_macs(cfg, shape).scale(
        1.0 / (shape.seq_len * shape.global_batch))

    print(f"{cfg.name}: {costs.param_count(cfg)/1e9:.1f}B params "
          f"({costs.param_count(cfg, active_only=True)/1e9:.1f}B active), "
          f"{macs.total:.3e} MACs/token\n")
    print("power/token under each scheme (Giga bit-flips), and the PANN "
          "plan at each budget:")
    print(f"{'bits':>4} {'signed':>9} {'unsigned':>9} {'PANN plan':>24}")
    for bits in [8, 6, 5, 4, 3, 2]:
        signed = pw.giga(pw.network_power_bitflips(macs, scheme="signed",
                                                   bits=bits))
        unsig = pw.giga(pw.network_power_bitflips(macs, scheme="unsigned",
                                                  bits=bits))
        plan = planner.plan_with_theory(planner.budget_from_bits(bits))
        print(f"{bits:>4} {signed:>9.2f} {unsig:>9.2f} "
              f"{'b~x=' + str(plan.b_x_tilde) + ' R=' + format(plan.r, '.2f'):>24}")
    print("\n(moving between rows needs NO hardware change with PANN — "
          "only (b~x, R); a regular quantizer needs a different multiplier)")


if __name__ == "__main__":
    main()
