"""Quickstart: PANN-ify a model and traverse the power-accuracy trade-off.

    PYTHONPATH=src:. python examples/quickstart.py

1. trains a small LM in full precision on the synthetic stream,
2. converts it to unsigned arithmetic (Sec. 4 — free power saving),
3. applies PANN at the power budget of a 2-bit unsigned MAC via Algorithm 1,
4. compares accuracy against a regular 2-bit quantizer at the same power.
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.common import eval_accuracy, train_small_lm  # noqa: E402
from repro.configs.base import QuantConfig  # noqa: E402
from repro.core import planner  # noqa: E402
from repro.core import power as pw  # noqa: E402


def main():
    print("== training a small LM (fp32) ==")
    tl = train_small_lm(steps=150)
    fp = eval_accuracy(tl, QuantConfig(mode="none"))
    print(f"full-precision accuracy: {fp:.3f}")

    bits = 2
    budget = planner.budget_from_bits(bits)
    print(f"\n== power budget: {bits}-bit unsigned MAC = {budget:.0f} "
          f"bit-flips/MAC ==")
    print(f"(signed 2-bit MAC would cost {pw.p_mac_signed(bits):.0f} — "
          f"switching to unsigned saves "
          f"{pw.unsigned_power_save(bits):.0%} for free)")

    ruq = eval_accuracy(tl, QuantConfig(mode="ruq_unsigned",
                                        weight_bits=bits, act_bits=bits))
    print(f"regular {bits}-bit quantizer accuracy: {ruq:.3f}")

    plan = planner.plan_with_eval(
        budget, lambda b, r: eval_accuracy(
            tl, QuantConfig(mode="pann", r=r, act_bits_tilde=b)))
    print(f"PANN (Algorithm 1): {plan.describe()}")
    print("\ncandidates swept:")
    for b, r, acc in plan.candidates:
        print(f"  b~x={b}  R={r:5.2f}  acc={acc:.3f}")
    print(f"\nPANN accuracy {plan.score:.3f} vs RUQ {ruq:.3f} "
          f"at the same {budget:.0f} bit-flips/MAC")


if __name__ == "__main__":
    main()
