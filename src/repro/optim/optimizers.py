"""Optimizers (AdamW, SGD+momentum), LR schedules, global-norm clipping —
pure-JAX pytree implementations (no optax in this environment)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Array = jax.Array
tmap = jax.tree_util.tree_map


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: Array


class SGDState(NamedTuple):
    momentum: Any
    count: Array


def cosine_warmup_schedule(cfg: TrainConfig) -> Callable[[Array], Array]:
    """Cosine decay with linear warmup, plus optional LR *re*-warmup ramps
    after budget-annealing knots (``cfg.lr_rewarmup_knots`` /
    ``cfg.anneal_warmup_steps``): tightening the quantization budget changes
    the loss surface, and a brief ramp lets the Adam moments re-adapt
    instead of taking the first post-knot steps at full speed. Off by
    default (empty knots / 0 ramp) — bit-identical to the plain schedule."""
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        out = jnp.where(step < cfg.warmup_steps, warm,
                        cfg.lr * (0.1 + 0.9 * cos))
        if cfg.anneal_warmup_steps > 0:
            for knot in cfg.lr_rewarmup_knots:
                ramp = jnp.clip((step - knot) / cfg.anneal_warmup_steps,
                                0.0, 1.0)
                out = out * jnp.where(step >= knot, ramp, 1.0)
        return out
    return lr


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return tmap(lambda g: g * scale, grads), gnorm


@dataclasses.dataclass(frozen=True)
class AdamW:
    cfg: TrainConfig

    def init(self, params: Any) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(mu=tmap(z, params), nu=tmap(z, params),
                          count=jnp.zeros((), jnp.int32))

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> tuple[Any, AdamWState, dict]:
        c = self.cfg
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        step = state.count + 1
        stepf = step.astype(jnp.float32)
        lr = cosine_warmup_schedule(c)(step)
        b1, b2 = c.beta1, c.beta2

        new_mu = tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
        new_nu = tmap(lambda v, g: b2 * v
                      + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)

        def upd(p, m, v):
            mhat = m / (1 - b1 ** stepf)
            vhat = v / (1 - b2 ** stepf)
            delta = mhat / (jnp.sqrt(vhat) + 1e-8)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = tmap(upd, params, new_mu, new_nu)
        metrics = {"lr": lr, "grad_norm": gnorm}
        return new_params, AdamWState(new_mu, new_nu, step), metrics


@dataclasses.dataclass(frozen=True)
class SGDM:
    cfg: TrainConfig
    momentum: float = 0.9

    def init(self, params: Any) -> SGDState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return SGDState(momentum=tmap(z, params),
                        count=jnp.zeros((), jnp.int32))

    def update(self, grads: Any, state: SGDState, params: Any
               ) -> tuple[Any, SGDState, dict]:
        c = self.cfg
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        step = state.count + 1
        lr = cosine_warmup_schedule(c)(step)
        new_m = tmap(lambda m, g: self.momentum * m + g.astype(jnp.float32),
                     state.momentum, grads)
        new_params = tmap(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_m)
        return new_params, SGDState(new_m, step), {"lr": lr,
                                                   "grad_norm": gnorm}


def make_optimizer(name: str, cfg: TrainConfig):
    if name == "adamw":
        return AdamW(cfg)
    if name == "sgdm":
        return SGDM(cfg)
    raise ValueError(name)
