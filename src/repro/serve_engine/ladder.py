"""The operating-point ladder: the deployment-time power-accuracy dial.

A rung is one equal-power PANN point — "the accuracy you can buy for the
power of a b-bit unsigned MAC" (Fig. 3). The ladder is a handful of rungs
planned once at server startup; every request then names a rung indirectly,
through a power budget or an accuracy floor, and the scheduler resolves it
with ``select_rung``.

Two allocation modes per rung (DESIGN.md §7):

  * ``uniform`` — one global (b~x, R) for every module (the legacy rung);
  * ``layerwise`` — a ``PolicyTree`` from ``planner.allocate_layerwise``
    spending the SAME total bit-flip budget non-uniformly across module
    paths. A layerwise rung's total power matches its uniform twin within
    float precision and its theory score never trails it (asserted in
    tests/test_policy_allocator.py).

A rung's planned R is the EXACT Algorithm-1 point. It is realized as a
zero-copy view over the one weight store (DESIGN.md §11): each module
quantizes once at its maximal rung budget and the rung's view drops low
bit-planes, SERVING the snapped budget ``core.pann.snapped_r(r_max,
shift)`` rather than ``plan.r`` itself (power drift < sqrt(2), equal-power
score gap bounded in closed form by benchmarks/artifact_parity.py). The
OperatingPoint stays the planning-side truth — budgets, scores and
scheduling all key off the planned point.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core import planner
from repro.core import policy as pol


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One rung: the bit budget it matches and the planned PANN point.

    ``lw`` holds the layerwise plan when the ladder was built with
    ``allocation="layerwise"``; ``plan`` is always the matched uniform
    Algorithm-1 point at the same budget (the rung's per-MAC power and the
    fallback single-point view)."""
    bits: int                    # unsigned-MAC bit width this rung's power equals
    plan: planner.PannPlan
    lw: Optional[planner.LayerwisePlan] = None

    @property
    def power(self) -> float:
        return self.plan.power_budget

    @property
    def r(self) -> float:
        return self.plan.r

    @property
    def b_x_tilde(self) -> int:
        return self.plan.b_x_tilde

    @property
    def score(self) -> float:
        return self.lw.score if self.lw is not None else self.plan.score

    @property
    def allocation(self) -> str:
        return "layerwise" if self.lw is not None else "uniform"

    @property
    def tree(self) -> Optional[pol.PolicyTree]:
        """The rung's PolicyTree (None for a uniform rung)."""
        return self.lw.tree if self.lw is not None else None

    def describe(self) -> str:
        if self.lw is not None:
            return f"rung[{self.bits}b] {self.lw.describe()}"
        return f"rung[{self.bits}b] {self.plan.describe()}"


def build_ladder(bits: Sequence[int] = (2, 3, 4, 6), d: float = 4096.0,
                 eval_fn=None, allocation: str = "uniform",
                 profile: Optional[Sequence] = None
                 ) -> tuple[OperatingPoint, ...]:
    """Plan the ladder, sorted by ascending power. Deterministic: a pure
    function of its inputs, so two servers configured alike agree rung for
    rung (tested in tests/test_serve_engine.py).

    ``allocation="layerwise"`` needs ``profile`` (a
    ``costs.module_cost_profile``); each rung then carries a PolicyTree
    spending the rung's total budget across modules, plus its matched
    uniform plan for comparison and logging. ``eval_fn`` (the Algorithm-1
    per-(b~x, R) backend) is rejected for layerwise ladders rather than
    silently dropped — every rung score on one ladder must come from ONE
    metric, or ``select_rung``'s accuracy floors compare apples to oranges.
    """
    sorted_bits = sorted({int(b) for b in bits})
    if allocation == "uniform":
        plans = planner.plan_ladder(sorted_bits, d=d, eval_fn=eval_fn)
        return tuple(OperatingPoint(b, p) for b, p in zip(sorted_bits, plans))
    lw_plans = planner.plan_ladder(sorted_bits, d=d, eval_fn=eval_fn,
                                   allocation=allocation, profile=profile)
    plans = planner.plan_ladder(sorted_bits, d=d)   # theory metric, matched
    return tuple(OperatingPoint(b, p, lw)
                 for b, p, lw in zip(sorted_bits, plans, lw_plans))


def select_rung(ladder: Sequence[OperatingPoint],
                power_budget_bits: Optional[int] = None,
                min_score: Optional[float] = None,
                max_bits: Optional[int] = None) -> OperatingPoint:
    """Resolve a request's declared constraint to a rung.

    * power budget: the highest-fidelity rung whose power fits the budget
      (best accuracy the budget can buy); below the lowest rung we clamp to
      the lowest rung rather than refuse the request.
    * accuracy floor: the cheapest rung whose planner score meets the floor
      (least power that honors the SLO); unattainable floors get the top
      rung — the best the server has.
    * both: the cheapest rung meeting the floor WITHIN the budget; if the
      floor needs more power than the budget allows, raise — silently
      violating a declared SLO is worse than refusing the request.
    * neither: the top rung.

    ``max_bits`` is the fleet power governor's ceiling (docs/fleet.md): the
    ladder is first clipped to rungs at or below it (keeping at least the
    cheapest rung, mirroring the budget clamp), then the rules above apply
    within the clipped ladder — so a global cap squeezes every selection
    down the ladder without rewriting per-request constraints. A floor
    that only a rung ABOVE the ceiling meets raises, like an unaffordable
    budget+floor pair: the caller decides whether the cap or the SLO wins.
    """
    if not ladder:
        raise ValueError("empty ladder")
    ladder = sorted(ladder, key=lambda op: op.power)
    if max_bits is not None:
        clipped = [op for op in ladder if op.bits <= max_bits] or [ladder[0]]
        if min_score is not None and all(op.score < min_score
                                        for op in clipped):
            raise ValueError(
                f"no rung under the {max_bits}-bit governor ceiling meets "
                f"score floor {min_score} (best: {clipped[-1].score})")
        ladder = clipped
    if power_budget_bits is not None:
        fits = [op for op in ladder if op.bits <= power_budget_bits] \
            or [ladder[0]]
        if min_score is None:
            return fits[-1]
        for op in fits:                # ascending power == ascending score
            if op.score >= min_score:
                return op
        raise ValueError(
            f"no rung within a {power_budget_bits}-bit power budget meets "
            f"score floor {min_score} (best affordable: {fits[-1].score})")
    if min_score is not None:
        for op in ladder:
            if op.score >= min_score:
                return op
        return ladder[-1]
    return ladder[-1]
