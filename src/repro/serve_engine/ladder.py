"""The operating-point ladder: the deployment-time power-accuracy dial.

A rung is one equal-power PANN point — "the accuracy you can buy for the
power of a b-bit unsigned MAC" (Fig. 3). The ladder is a handful of rungs
planned once at server startup; every request then names a rung indirectly,
through a power budget or an accuracy floor, and the scheduler resolves it
with ``select_rung``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core import planner


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One rung: the bit budget it matches and the planned PANN point."""
    bits: int                    # unsigned-MAC bit width this rung's power equals
    plan: planner.PannPlan

    @property
    def power(self) -> float:
        return self.plan.power_budget

    @property
    def r(self) -> float:
        return self.plan.r

    @property
    def b_x_tilde(self) -> int:
        return self.plan.b_x_tilde

    @property
    def score(self) -> float:
        return self.plan.score

    def describe(self) -> str:
        return f"rung[{self.bits}b] {self.plan.describe()}"


def build_ladder(bits: Sequence[int] = (2, 3, 4, 6), d: float = 4096.0,
                 eval_fn=None) -> tuple[OperatingPoint, ...]:
    """Plan the ladder, sorted by ascending power. Deterministic: a pure
    function of (bits, d), so two servers configured alike agree rung for
    rung (tested in tests/test_serve_engine.py)."""
    sorted_bits = sorted({int(b) for b in bits})
    plans = planner.plan_ladder(sorted_bits, d=d, eval_fn=eval_fn)
    return tuple(OperatingPoint(b, p) for b, p in zip(sorted_bits, plans))


def select_rung(ladder: Sequence[OperatingPoint],
                power_budget_bits: Optional[int] = None,
                min_score: Optional[float] = None) -> OperatingPoint:
    """Resolve a request's declared constraint to a rung.

    * power budget: the highest-fidelity rung whose power fits the budget
      (best accuracy the budget can buy); below the lowest rung we clamp to
      the lowest rung rather than refuse the request.
    * accuracy floor: the cheapest rung whose planner score meets the floor
      (least power that honors the SLO); unattainable floors get the top
      rung — the best the server has.
    * both: the cheapest rung meeting the floor WITHIN the budget; if the
      floor needs more power than the budget allows, raise — silently
      violating a declared SLO is worse than refusing the request.
    * neither: the top rung.
    """
    if not ladder:
        raise ValueError("empty ladder")
    ladder = sorted(ladder, key=lambda op: op.power)
    if power_budget_bits is not None:
        fits = [op for op in ladder if op.bits <= power_budget_bits] \
            or [ladder[0]]
        if min_score is None:
            return fits[-1]
        for op in fits:                # ascending power == ascending score
            if op.score >= min_score:
                return op
        raise ValueError(
            f"no rung within a {power_budget_bits}-bit power budget meets "
            f"score floor {min_score} (best affordable: {fits[-1].score})")
    if min_score is not None:
        for op in ladder:
            if op.score >= min_score:
                return op
        return ladder[-1]
    return ladder[-1]
