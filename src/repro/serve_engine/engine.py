"""ServeEngine: one checkpoint, a ladder of PANN operating points, per-request
power-accuracy selection — with no re-quantization and no recompilation after
warmup.

Why switching is free (DESIGN.md §6): every rung's variant is produced by
``models/serving.py`` with the same pytree structure, shapes, and dtypes
(int8 codes + f32 scales); jax.jit keys its compilation cache on exactly
those avals, so ONE traced decode step serves every rung and moving between
rungs is a pointer swap into the variant cache. ``warmup()`` runs each rung
once and records the jit cache size; ``assert_no_recompile()`` proves the
claim after serving mixed traffic.

The engine interleaves *lanes* (one per in-flight wave) round-robin, one
decode step each — so a 2-bit lane and a 6-bit lane genuinely alternate
operating points between decode steps of a single process.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core import policy as pol
from repro.core import power as pw
from repro.kernels import dispatch
from repro.models import model as MD
from repro.models import serving
from repro.serve_engine.ladder import build_ladder, select_rung
from repro.serve_engine.scheduler import Request, Response, Scheduler, Wave


@dataclasses.dataclass
class Lane:
    """One in-flight wave: its decode state and the tokens grown so far.

    Public because the fleet (``serve_engine.fleet``) moves lanes BETWEEN
    engines: a prefill host builds the lane, a decode host advances it, and
    a restarted or switched-to host rebuilds it from ``prefix_rows()`` —
    the decode state is re-derivable from the token prefix (teacher-forced
    replay, DESIGN.md §6), so a lane's identity is its tokens, not its
    arrays. ``done`` counts tokens generated before this lane's state was
    (re)built; ``generated`` holds only the tokens grown since."""
    wave: Wave
    state: Any
    tok: Any                 # (max_batch, 1) int32 — last sampled token
    generated: list          # [(max_batch, 1), ...] greedy tokens
    steps_left: int
    done: int = 0            # tokens generated before the latest (re)build

    def generated_rows(self) -> np.ndarray:
        """(n_requests, n_generated_since_build) int32 token matrix —
        what the fleet appends to its per-request records when this lane
        finishes, switches rung, or dies with its host."""
        n = len(self.wave.requests)
        if not self.generated:
            return np.zeros((n, 0), np.int32)
        return np.asarray(jnp.concatenate(self.generated, axis=1))[:n]


_Lane = Lane                  # pre-fleet private name (back-compat)


class ServeEngine:
    """Multi-operating-point PANN serving runtime (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params: Any = None,
                 ladder_bits: Sequence[int] = (2, 3, 4, 6),
                 max_batch: int = 4, max_len: int = 64, mesh=None,
                 par=None, mse_dim: Optional[float] = None,
                 allocation: str = "uniform",
                 backend: Optional[str] = None,
                 autotune: bool = False,
                 cache_bits: Any = None,
                 artifact_format: str = "views",
                 weight_store: Optional[serving.WeightStore] = None,
                 frontend_kwargs_fn: Optional[Callable[[int], dict]] = None):
        if (params is None) == (weight_store is None):
            raise ValueError(
                "pass exactly one of params (quantize here) or "
                "weight_store (serve a prebuilt/loaded artifact)")
        if cfg.family in ("encdec", "vlm") and frontend_kwargs_fn is None:
            raise ValueError(
                f"{cfg.family} decode needs a frontend; pass "
                "frontend_kwargs_fn(batch) -> init_decode_state kwargs")
        # quantized KV cache (docs/kv_cache.md): None leaves the fp cache;
        # an int pins every rung's cache width; "auto" lets each rung pick —
        # a uniform rung caches at its own b~x, a layerwise rung lets the
        # allocator trade cache bits against weight bits under one budget
        # (cache pseudo-modules appended to its profile). Trace-time static
        # on the config like the backend: the cache STRUCTURE is fixed,
        # per-rung widths ride in the variants as data (k_nlvl / v_nlvl),
        # so one compiled decode step still serves the whole ladder.
        if cache_bits is not None and cache_bits != "auto":
            cache_bits = int(cache_bits)
            if not 2 <= cache_bits <= 7:
                raise ValueError(
                    f"cache_bits must be in [2, 7] (codes are <= 7 planes), "
                    f"got {cache_bits}")
        self.cache_bits = cache_bits
        if cache_bits is not None:
            cfg = dataclasses.replace(
                cfg, cache_bits=7 if cache_bits == "auto" else cache_bits)
        # the serving-matmul backend (repro.kernels.dispatch) is trace-time
        # static on the config: ONE jitted decode step per backend, still
        # one per ENGINE — every rung of this ladder shares it
        self.backend = backend
        if backend is not None:
            dispatch.parse_backend(backend)      # fail fast on typos
            cfg = dataclasses.replace(cfg, kernel_backend=backend)
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.allocation = allocation
        # the per-module MAC profile: feeds the layerwise allocator AND the
        # per-module energy breakdown on every response (either allocation)
        self.profile = costs.module_cost_profile(cfg)
        # "auto" + layerwise: the allocator sees the cache roles as
        # pseudo-modules and spends ONE budget across weights AND cache
        alloc_profile = self.profile
        if cache_bits == "auto" and allocation == "layerwise":
            alloc_profile = self.profile + costs.cache_cost_modules(cfg)
        self.ladder = build_ladder(ladder_bits,
                                   d=float(mse_dim or cfg.d_model),
                                   allocation=allocation,
                                   profile=alloc_profile)
        self.rungs = {op.bits: op for op in self.ladder}
        # per-rung cache width handed to the variant cache: an int pins the
        # rung's k_nlvl/v_nlvl leaves; None defers to the rung's PolicyTree
        # cache-role overrides (quantize_params_for_serving reads those)
        self._cache_bits_by_rung: dict[int, Optional[int]] = {}
        if cache_bits is not None:
            for op in self.ladder:
                if cache_bits != "auto":
                    self._cache_bits_by_rung[op.bits] = cache_bits
                elif op.tree is not None and pol.tree_cache_bits(op.tree):
                    self._cache_bits_by_rung[op.bits] = None
                else:
                    self._cache_bits_by_rung[op.bits] = min(
                        int(op.b_x_tilde), 7)
        # the variant cache: int8 weight codes per rung, activations
        # quantized at the rung's b~x (stored as data so rungs share one
        # compilation), sharded like training params on a mesh; a layerwise
        # rung materializes per-module (R, b~x) codes via its PolicyTree —
        # same pytree structure and avals, so it still shares the one
        # compiled decode step with every uniform rung
        # par: the training ParallelConfig, so an FSDP-trained layout and
        # the serving cache layout can't drift apart
        # the 'packed' backend reads bit-packed plane leaves; the pinned
        # LADDER_PLANE_COUNT keeps plane avals identical across rungs
        needs_planes = (backend is not None
                        and dispatch.parse_backend(backend)[0] == "packed")
        rung_specs = {op.bits: (op.tree if op.tree is not None
                                else (op.r, op.b_x_tilde))
                      for op in self.ladder}
        # The ladder is materialized as ONE weight store with zero-copy rung
        # views: quantize ONCE at the per-module max budget, realize every
        # rung as a view adding only small data leaves — HBM independent of
        # ladder depth, rung budgets snapped to powers of two of the top
        # rung (DESIGN.md §11). The per-rung "legacy" quantizer was retired
        # (benchmarks/artifact_parity.py bounds the snapping drift in
        # closed form; serving it cost N full stores for no exactness win).
        if artifact_format != "views":
            raise ValueError(
                f"artifact_format {artifact_format!r} is gone: the per-rung "
                "'legacy' materialization was retired — 'views' (one weight "
                "store, zero-copy rung views) is the only format. Budget "
                "snapping drift is bounded by benchmarks/artifact_parity.py; "
                "drop the artifact_format argument.")
        self.artifact_format = artifact_format
        if weight_store is not None:
            # serve a prebuilt store — typically artifact.load_artifact's
            # mmap-backed views (ROADMAP item 5: no re-quantization on the
            # serving host; fleet hosts all map ONE weights.bin). The store
            # must cover this engine's ladder; extra rungs are fine — a
            # rung-sharded fleet host serves a SUBSET of the artifact's
            # ladder (dist.sharding.rung_shard) from the same file.
            missing = [b for b in rung_specs if b not in weight_store.views]
            if missing:
                raise ValueError(
                    f"weight_store has no view for rung(s) {missing}; "
                    f"available: {sorted(weight_store.views)}")
            if needs_planes:
                leaf_names = {getattr(p[-1], "key", "") for p, _ in
                              jax.tree_util.tree_leaves_with_path(
                                  next(iter(weight_store.views.values())))}
                if "w_planes_pos" not in leaf_names:
                    raise ValueError(
                        "packed backend needs plane leaves; this weight "
                        "store was built without pack_planes")
            ws = serving.device_put_weight_store(
                serving.WeightStore(
                    store=weight_store.store,
                    views={b: weight_store.views[b] for b in rung_specs}),
                mesh=mesh, par=par)
            self.weight_store = ws.store
            self.variants = ws.views
        else:
            quant_spec = serving.ServingQuantSpec(
                pack_planes=needs_planes,
                cache_bits=self._cache_bits_by_rung or None)
            ws = serving.build_weight_store(params, cfg, rung_specs,
                                            mesh=mesh, par=par,
                                            spec=quant_spec)
            self.weight_store = ws.store
            self.variants = ws.views
        # offline block autotuning (kernels/autotune): measure-and-cache the
        # best Pallas block shapes per projection BEFORE the decode step is
        # ever traced — serving_linear then reads the cache at trace time,
        # so tuning never invalidates the one-compiled-decode-step claim
        # (all rungs share avals, hence shapes, hence tuning decisions)
        if autotune and backend is not None \
                and dispatch.parse_backend(backend)[0] != "ref":
            self._autotune_projections()
        self._frontend_kwargs_fn = frontend_kwargs_fn
        self._step = jax.jit(lambda p, s, t: MD.decode_step(p, cfg, s, t))
        self.scheduler = Scheduler(self.ladder, self.max_batch)
        self.compilations_after_warmup: Optional[int] = None
        self.steps_by_rung = {op.bits: 0 for op in self.ladder}
        self.rung_switches = 0
        self._last_step_bits: Optional[int] = None
        self._macs_by_ctx: dict[int, Any] = {}   # macs_per_token memo

    # -- offline autotuning -------------------------------------------------

    def _autotune_projections(self) -> None:
        """Tune every distinct projection shape in the (shape-identical)
        variants once, at the engine's decode row count. Idempotent: cached
        shapes short-circuit inside ``autotune.tune``."""
        variant = next(iter(self.variants.values()))
        seen: set = set()

        def walk(node):
            if isinstance(node, dict):
                if "w_q" in node:
                    sd = node["w_q"].ndim - 2    # scan-stacked leading dims
                    leaf = {k: (v[(0,) * sd]
                                if sd and getattr(v, "ndim", 0) >= sd else v)
                            for k, v in node.items()}
                    key = (leaf["w_q"].shape,
                           leaf["w_planes_pos"].shape[-3]
                           if "w_planes_pos" in leaf else None)
                    if key not in seen:
                        seen.add(key)
                        dispatch.tune_projection(self.max_batch, leaf,
                                                 self.backend)
                    return
                for v in node.values():
                    walk(v)
            elif isinstance(node, (list, tuple)):
                for v in node:
                    walk(v)

        walk(variant)

    # -- jit bookkeeping ----------------------------------------------------

    def _jit_cache_size(self) -> int:
        try:
            return int(self._step._cache_size())
        except Exception:
            return -1

    def warmup(self) -> None:
        """Run one decode step per rung so every compilation (there should
        be exactly one) happens before traffic."""
        state = self._init_state(self.ladder[0].bits)
        tok = jnp.zeros((self.max_batch, 1), jnp.int32)
        for op in self.ladder:
            jax.block_until_ready(
                self._step(self.variants[op.bits], state, tok)[0])
        self.compilations_after_warmup = self._jit_cache_size()

    def assert_no_recompile(self) -> None:
        """After serving: the jit cache must not have grown past warmup."""
        if self.compilations_after_warmup is None:
            raise RuntimeError("call warmup() first")
        now = self._jit_cache_size()
        if now < 0 or self.compilations_after_warmup < 0:
            # fail loudly rather than silently skipping the central claim
            raise RuntimeError(
                "cannot verify the no-recompilation claim: jit cache "
                "introspection (_cache_size) is unavailable on this jax")
        if now > self.compilations_after_warmup:
            raise AssertionError(
                f"decode step recompiled while serving: "
                f"{self.compilations_after_warmup} -> {now} cache entries")

    # -- decode plumbing ----------------------------------------------------

    def _init_state(self, bits: int):
        kwargs = {}
        if self._frontend_kwargs_fn is not None:
            kwargs = self._frontend_kwargs_fn(self.max_batch)
        # the serving rung's variant: for encdec/vlm, init_decode_state runs
        # the encoder and projects cross-K/V through these weights, so the
        # frontend side must be quantized at the same rung as decode
        variant = self.variants[bits]
        return MD.init_decode_state(variant, self.cfg, self.max_batch,
                                    self.max_len, **kwargs)

    def _run_step(self, bits: int, state, tok):
        if self._last_step_bits is not None and bits != self._last_step_bits:
            self.rung_switches += 1
        self._last_step_bits = bits
        self.steps_by_rung[bits] += 1
        return self._step(self.variants[bits], state, tok)

    def _greedy(self, logits):
        v = self.cfg.vocab_size
        return jnp.argmax(logits[:, :, :v], axis=-1).astype(jnp.int32)

    def _teacher_force(self, bits: int, state, prompts):
        """Feed a (max_batch, L) prefix token by token; return the logits of
        the final position and the threaded state."""
        logits = None
        for i in range(prompts.shape[1]):
            logits, state = self._run_step(bits, state, prompts[:, i:i + 1])
        return logits, state

    def _pad_rows(self, rows: np.ndarray) -> np.ndarray:
        """Pad the request dim to max_batch (repeating row 0) so every wave
        presents identical avals to the jitted step."""
        if rows.shape[0] == self.max_batch:
            return rows
        pad = np.broadcast_to(rows[:1],
                              (self.max_batch - rows.shape[0],) + rows.shape[1:])
        return np.concatenate([rows, pad], axis=0)

    def prefill_wave(self, wave: Wave,
                     prefix_rows: Optional[np.ndarray] = None) -> Lane:
        """Teacher-force a wave's prompts and return its lane (the first
        generated token included).

        ``prefix_rows`` — (n_requests, prompt_len + done) int32 — replays a
        lane that already generated ``done`` tokens elsewhere: on a host
        restart (``dist.fault``) or a governor-forced rung switch the fleet
        rebuilds the lane here from prompt + tokens-so-far, and because the
        decode state is a pure function of the token prefix the rebuilt
        lane's continuation is bit-identical to the uninterrupted one
        (tests/test_fleet.py). The replayed wave's rung is THIS wave's rung
        — switching is replaying into a different rung's view.
        """
        reqs = wave.requests
        gen_max = max(r.max_new_tokens for r in reqs)
        if prefix_rows is None:
            rows, done = np.stack([r.prompt for r in reqs]), 0
        else:
            rows = np.asarray(prefix_rows, np.int32)
            done = rows.shape[1] - reqs[0].prompt_len
            if not 0 <= done < gen_max:
                raise ValueError(
                    f"replay prefix carries {done} generated tokens, "
                    f"wave needs 0 <= done < {gen_max}")
        if reqs[0].prompt_len + gen_max > self.max_len:
            raise ValueError(
                f"prompt_len {reqs[0].prompt_len} + gen {gen_max} exceeds "
                f"engine max_len {self.max_len}")
        rows = jnp.asarray(self._pad_rows(rows), jnp.int32)
        state = self._init_state(wave.rung.bits)
        logits, state = self._teacher_force(wave.rung.bits, state, rows)
        tok = self._greedy(logits)
        return Lane(wave=wave, state=state, tok=tok, generated=[tok],
                    steps_left=gen_max - done - 1, done=done)

    _prefill = prefill_wave       # pre-fleet private name (back-compat)

    def step_lane(self, lane: Lane) -> bool:
        """Advance a lane one decode step; True when the lane is finished.
        One step serves every live row of the wave — the fleet's unit of
        power-cap admission (each call costs the wave one token per
        request at its rung's bit-flip price)."""
        if lane.steps_left > 0:
            logits, lane.state = self._run_step(
                lane.wave.rung.bits, lane.state, lane.tok)
            lane.tok = self._greedy(logits)
            lane.generated.append(lane.tok)
            lane.steps_left -= 1
        return lane.steps_left <= 0

    def _rung_tree(self, rung) -> pol.PolicyTree:
        """The rung's PolicyTree: its layerwise tree, or the uniform lift
        of its single (b~x, R) point — one pricing path for both. With a
        quantized cache the tree additionally carries EXPLICIT cache-role
        overrides at the rung's resolved width, so
        ``policy.tree_power_per_token`` prices the act x act MACs at the
        cache's own bits (the per-response cache bit-flip line items)."""
        if rung.tree is not None:
            tree = rung.tree
        else:
            tree = pol.uniform_policy(pol.ModuleQuant(
                mode="pann", r=rung.r, b_x_tilde=rung.b_x_tilde))
        cb = self._cache_bits_by_rung.get(rung.bits)
        if cb is None:          # cache off, or policy-driven (tree has them)
            return tree
        ov = dict(tree.overrides)
        for role in pol.CACHE_PATHS:
            ov[role] = pol.cache_module_quant(cb)
        return pol.policy_tree(tree.default, ov)

    def ledger_for(self, rung, ctx: int) -> pw.EnergyLedger:
        macs = self._macs_by_ctx.get(ctx)
        if macs is None:
            macs = self._macs_by_ctx.setdefault(
                ctx, costs.macs_per_token(self.cfg, context_len=ctx))
        total, breakdown = pol.tree_power_per_token(
            self.profile, self._rung_tree(rung), act_macs=macs.act_macs)
        if rung.tree is None and self.cache_bits is None:
            # uniform rung, fp cache: keep the legacy headline number
            # bit-for-bit (same formula; the breakdown itemizes it). A
            # quantized cache re-prices the act x act half, so the
            # cache-aware total stands on its own there.
            total = pw.pann_token_bitflips(macs, rung.r, rung.b_x_tilde)
        return pw.EnergyLedger(total, breakdown_per_token=breakdown)

    _ledger_for = ledger_for      # pre-fleet private name (back-compat)

    def token_flips(self, bits: int, ctx: int) -> float:
        """Estimated bit flips of ONE token at rung ``bits`` with context
        ``ctx`` — the deterministic per-step price the fleet governor
        charges against its per-tick power grant before the step runs
        (admission control is pre-paid; that is what makes zero cap
        violations a structural property, not a measurement)."""
        return self.ledger_for(self.rungs[bits], ctx).bitflips_per_token

    def _finalize(self, lane: _Lane) -> list[Response]:
        gen = np.asarray(jnp.concatenate(lane.generated, axis=1))
        rung = lane.wave.rung
        out = []
        for i, req in enumerate(lane.wave.requests):
            toks = gen[i, :req.max_new_tokens].tolist()
            ledger = self._ledger_for(rung, req.prompt_len
                                      + req.max_new_tokens)
            ledger.charge(len(toks))
            meta = {
                "rung_bits": rung.bits,
                "b_x_tilde": rung.b_x_tilde,
                "r": rung.r,
                "allocation": rung.allocation,
                "power_per_weight_mac": rung.power,
                **ledger.report(),
            }
            if self.cache_bits is not None:
                meta["cache_bits"] = pol.tree_cache_bits(
                    self._rung_tree(rung))
            out.append(Response(uid=req.uid, tokens=toks,
                                rung_bits=rung.bits, metadata=meta))
        return out

    # -- serving loops ------------------------------------------------------

    def generate(self, requests: Sequence[Request], max_lanes: int = 2
                 ) -> list[Response]:
        """Serve a batch of mixed-budget requests to completion.

        Lanes (one per admitted wave) advance round-robin one decode step at
        a time, so different rungs interleave between steps; finished lanes
        free a slot and the scheduler admits the next wave (continuous
        batching at wave granularity).
        """
        # validate the whole batch before any work: an oversized request or
        # an infeasible budget/floor combination must fail the call up
        # front, never mid-submit (stranding half the batch in the queue)
        # or mid-generate (discarding completed lanes' responses)
        resolved = []
        for r in requests:
            if r.prompt_len + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt_len {r.prompt_len} + gen "
                    f"{r.max_new_tokens} exceeds engine max_len "
                    f"{self.max_len}")
            resolved.append(
                select_rung(self.ladder, r.power_budget_bits, r.min_score))
        for r, rung in zip(requests, resolved):
            self.scheduler.submit(r, rung=rung)
        lanes: list[Lane] = []
        responses: list[Response] = []
        while lanes or self.scheduler.pending():
            while len(lanes) < max_lanes:
                wave = self.scheduler.next_wave()
                if wave is None:
                    break
                lanes.append(self.prefill_wave(wave))
            for lane in list(lanes):
                if self.step_lane(lane):
                    responses.extend(self._finalize(lane))
                    lanes.remove(lane)
        return sorted(responses, key=lambda r: r.uid)

    def decode_stream(self, prompt: np.ndarray,
                      schedule: Sequence[tuple[int, int]]) -> dict:
        """Greedy-decode one stream whose rung changes mid-flight.

        ``schedule`` is ``[(bits, n_tokens), ...]``. A switch replays the
        accumulated prefix through the target rung's cached variant
        (teacher-forced, same jitted step — no re-quantization, no
        recompilation), then continues decoding; the continuation is
        therefore bit-identical to a fresh server at that rung given the
        same prefix (tested in tests/test_serve_engine.py).
        """
        prefix = [int(t) for t in np.asarray(prompt).reshape(-1)]
        prompt_len = len(prefix)
        total_gen = sum(n for _, n in schedule)
        if prompt_len + total_gen > self.max_len:
            raise ValueError("schedule exceeds engine max_len")
        for bits, _ in schedule:       # whole schedule up front, like the
            if bits not in self.rungs:  # length check — never mid-decode
                raise KeyError(f"no rung for {bits}-bit budget; "
                               f"ladder has {sorted(self.rungs)}")
        segments = []
        for bits, n in schedule:
            if n <= 0:
                segments.append({"rung_bits": bits, "tokens": []})
                continue
            rows = jnp.asarray(
                self._pad_rows(np.asarray(prefix, np.int32)[None, :]),
                jnp.int32)
            state = self._init_state(bits)
            logits, state = self._teacher_force(bits, state, rows)
            seg = []
            tok = self._greedy(logits)
            seg.append(int(np.asarray(tok)[0, 0]))
            for _ in range(n - 1):
                logits, state = self._run_step(bits, state, tok)
                tok = self._greedy(logits)
                seg.append(int(np.asarray(tok)[0, 0]))
            prefix.extend(seg)
            segments.append({"rung_bits": bits, "tokens": seg})
        return {"tokens": prefix[prompt_len:], "segments": segments}

    # -- reporting ----------------------------------------------------------

    def describe(self) -> dict:
        total_macs = sum(m.macs for m in self.profile)
        return {
            "allocation": self.allocation,
            "artifact_format": self.artifact_format,
            "backend": self.backend or "legacy",
            "cache_bits": self.cache_bits,
            "cache_bits_by_rung": dict(self._cache_bits_by_rung) or None,
            "ladder": [{"bits": op.bits, "b_x_tilde": op.b_x_tilde,
                        "r": round(op.r, 3),
                        "power_per_weight_mac": round(op.power, 2),
                        "total_gbitflips_per_token":
                            round(pw.giga(op.power * total_macs), 3)}
                       for op in self.ladder],
            "max_batch": self.max_batch,
            "max_len": self.max_len,
            "compilations_after_warmup": self.compilations_after_warmup,
            "steps_by_rung": dict(self.steps_by_rung),
            "rung_switches": self.rung_switches,
        }
