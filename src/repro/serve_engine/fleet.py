"""Multi-host fleet serving under one global power cap (DESIGN.md §12).

``Fleet`` runs ``ServeEngine`` replicas across a simulated mesh of hosts on
the ``repro.dist`` substrate: prefill and decode are disaggregated
(prefill hosts teacher-force prompts and hand finished lanes to decode
hosts), the per-rung variant cache is SHARDED across decode hosts
(``dist.sharding.rung_shard`` — each host warms only its rung shard), and
every host serves zero-copy views out of ONE mmap serving artifact
(``serve_engine.artifact``) — a restarted host resumes from the same
``weights.bin`` the fleet booted from.

The fleet-level power governor closes the loop the paper opens: live
``EnergyLedger`` bit-flip telemetry, aggregated across hosts every tick
(``core.power.aggregate_ledgers``), drives periodic
``planner.allocate_layerwise`` replans (``planner.replan_for_rate``) whose
per-MAC budget picks the RUNG CEILING — the highest ladder rung any
request may be served at — and a hard per-tick flip grant pre-pays every
prefill and decode step, so the fleet stays under the cap by construction
(zero violations is structural, not statistical). A mid-run cap change
re-resolves queued work and switches in-flight lanes down the ladder by
prefix replay — bit-exact, per DESIGN.md §6 — and a host kill is absorbed
by ``dist.fault.FleetSupervisor``: the host rebuilds from the artifact and
replays its lost lanes, changing latency and restart energy but never a
served token (tests/test_fleet.py, benchmarks/fleet_sim.py).

Simulated time advances in TICKS (``FleetConfig.tick_seconds`` of virtual
wall time each); everything the CI gate checks — requests served, realized
fleet bit flips, cap violations — is a deterministic function of the
seeded trace, while real wall-clock timings ride along as informational
metrics only. The synthetic traffic generator vendors a SplitMix64 stream
so the trace is identical on every numpy/jax version.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core import planner
from repro.core import power as pw
from repro.dist import fault
from repro.dist.sharding import rung_shard
from repro.serve_engine.engine import Lane, ServeEngine
from repro.serve_engine.ladder import build_ladder, select_rung
from repro.serve_engine.scheduler import Request, Response, Scheduler, Wave


# ---------------------------------------------------------------------------
# Deterministic traffic generation
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


class SplitMix64:
    """Vendored 64-bit stream: the fleet trace must be bit-identical on
    every numpy version (NEP 19 lets ``np.random.Generator`` streams move
    between releases), so the traffic generator rolls its own."""

    def __init__(self, seed: int):
        self._s = seed & _MASK64

    def next_u64(self) -> int:
        self._s = (self._s + 0x9E3779B97F4A7C15) & _MASK64
        z = self._s
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
        return z ^ (z >> 31)

    def uniform(self) -> float:
        return (self.next_u64() >> 11) * (2.0 ** -53)

    def randint(self, n: int) -> int:
        return self.next_u64() % n

    def choice(self, seq):
        return seq[self.randint(len(seq))]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Knobs of the synthetic fleet workload (docs/fleet.md).

    Arrivals are bursty: each tick opens a burst with ``burst_prob`` whose
    size is 1 + uniform[0, 2 x mean_burst) — quiet ticks and multi-request
    spikes, not a smooth rate. ``budget_mix`` cycles per-request power
    budgets; ``slo_prob`` requests additionally carry a ``min_score``
    accuracy floor pinned to a rung in ``slo_bits``. ``budget_steps``
    rewrites the GLOBAL cap mid-run ((tick, gbitflips_per_s) pairs);
    ``host_kills`` murders decode hosts ((tick, host_id) pairs)."""
    seed: int = 0
    n_ticks: int = 24
    burst_prob: float = 0.7
    mean_burst: float = 2.0
    prompt_lens: tuple = (8,)
    gen_tokens: tuple = (8, 12)
    budget_mix: tuple = (2, 4, 6, 6)
    slo_prob: float = 0.25
    slo_bits: tuple = (4,)
    budget_steps: tuple = ()
    host_kills: tuple = ()


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """One materialized workload: everything ``Fleet.run`` consumes."""
    arrivals: tuple            # ((tick, (Request, ...)), ...)
    budget_steps: tuple        # ((tick, gbitflips_per_s), ...)
    host_kills: tuple          # ((tick, host_id), ...)
    n_ticks: int

    @property
    def n_requests(self) -> int:
        return sum(len(reqs) for _, reqs in self.arrivals)


def make_trace(spec: TrafficSpec, vocab_size: int, ladder) -> FleetTrace:
    """Deterministically expand a spec into a trace. ``ladder`` supplies
    the rung scores ``slo_bits`` floors pin to — a floor IS a rung's
    planner score, so 'meets the floor' and 'serves at that rung or
    better' coincide exactly."""
    rng = SplitMix64(spec.seed)
    scores = {op.bits: op.score for op in ladder}
    for b in spec.slo_bits:
        if b not in scores:
            raise ValueError(f"slo_bits {b} not a ladder rung "
                             f"{sorted(scores)}")
    uid = 0
    arrivals = []
    for tick in range(spec.n_ticks):
        if rng.uniform() >= spec.burst_prob:
            continue
        size = 1 + rng.randint(max(int(2 * spec.mean_burst), 1))
        reqs = []
        for _ in range(size):
            n = rng.choice(spec.prompt_lens)
            prompt = np.array([rng.randint(vocab_size) for _ in range(n)],
                              np.int32)
            floor = None
            if rng.uniform() < spec.slo_prob:
                floor = scores[rng.choice(spec.slo_bits)]
            reqs.append(Request(
                uid=uid, prompt=prompt,
                max_new_tokens=rng.choice(spec.gen_tokens),
                power_budget_bits=spec.budget_mix[uid % len(spec.budget_mix)],
                min_score=floor))
            uid += 1
        arrivals.append((tick, tuple(reqs)))
    return FleetTrace(arrivals=tuple(arrivals),
                      budget_steps=tuple(spec.budget_steps),
                      host_kills=tuple(spec.host_kills),
                      n_ticks=spec.n_ticks)


# ---------------------------------------------------------------------------
# The fleet power governor
# ---------------------------------------------------------------------------

class PowerGovernor:
    """Closed loop from aggregated telemetry to per-request rung selection.

    Two actuators, one cap:

      * the per-tick GRANT — ``cap_gbitflips_per_s x tick_seconds`` bit
        flips a tick may spend, debited before any prefill or decode step
        runs (``try_spend``). This is the hard guarantee: a step that does
        not fit stalls to the next tick, so realized flips never exceed
        the cap in any tick.
      * the RUNG CEILING — every ``control_interval`` ticks (and
        immediately on a cap change) the realized fleet token rate from
        ``EnergyLedger`` telemetry feeds ``planner.replan_for_rate``; the
        replan's per-MAC budget picks the highest ladder rung the traffic
        can afford fleet-wide, and ``select_rung(max_bits=ceiling)``
        squeezes every subsequent selection under it. The grant keeps the
        cap; the ceiling keeps the fleet NEAR the cap instead of stalling.
    """

    def __init__(self, ladder, profile, cap_gbitflips_per_s: float,
                 tick_seconds: float = 1.0, control_interval: int = 4):
        self.ladder = tuple(sorted(ladder, key=lambda op: op.power))
        self.profile = [m for m in profile if m.macs > 0]
        self.tick_seconds = float(tick_seconds)
        self.control_interval = int(control_interval)
        self.ceiling_bits = self.ladder[-1].bits
        self.replans: list[dict] = []
        self._spent_this_tick = 0.0
        self._window_tokens = 0
        self._window_ticks = 0
        self.set_cap(cap_gbitflips_per_s, tick=0, replan=False)

    # -- the hard per-tick grant -------------------------------------------

    @property
    def cap_per_tick(self) -> float:
        return self.cap_gbitflips_per_s * 1e9 * self.tick_seconds

    def begin_tick(self) -> None:
        self._spent_this_tick = 0.0

    def try_spend(self, flips: float) -> bool:
        """Debit ``flips`` from this tick's grant; False = stall (the
        caller must not run the step)."""
        if self._spent_this_tick + flips > self.cap_per_tick:
            return False
        self._spent_this_tick += flips
        return True

    def take(self, flips: float) -> float:
        """Debit up to ``flips`` from what remains of this tick's grant
        and return the amount actually taken. Lets a single action whose
        price exceeds one tick's whole grant (a long replay under a tight
        cap) save up across ticks — each tick still spends at most its
        grant, and the action runs only once fully paid."""
        got = min(max(flips, 0.0), self.cap_per_tick - self._spent_this_tick)
        got = max(got, 0.0)
        self._spent_this_tick += got
        return got

    @property
    def spent_this_tick(self) -> float:
        return self._spent_this_tick

    # -- the telemetry-driven ceiling --------------------------------------

    def set_cap(self, gbitflips_per_s: float, tick: int,
                replan: bool = True) -> None:
        if gbitflips_per_s <= 0:
            raise ValueError(f"cap must be positive: {gbitflips_per_s}")
        self.cap_gbitflips_per_s = float(gbitflips_per_s)
        if replan:
            self.replan(tick, reason="cap_step")

    def observe(self, tokens: int) -> None:
        """Record one tick's realized decode tokens (from the aggregated
        ledgers) into the replan window."""
        self._window_tokens += int(tokens)
        self._window_ticks += 1

    def maybe_replan(self, tick: int) -> bool:
        if self._window_ticks < self.control_interval:
            return False
        return self.replan(tick, reason="periodic")

    def replan(self, tick: int, reason: str) -> bool:
        """allocate_layerwise on the budget the measured rate leaves under
        the cap; returns True when the ceiling moved."""
        ticks = max(self._window_ticks, 1)
        rate = self._window_tokens / (ticks * self.tick_seconds)
        if rate <= 0:
            # no traffic observed yet: assume one wave-step per tick at the
            # top rung would be served, i.e. stay permissive until data
            rate = 1.0 / self.tick_seconds
        plan = planner.replan_for_rate(self.cap_gbitflips_per_s * 1e9,
                                       rate, self.profile)
        fits = [op.bits for op in self.ladder
                if op.power <= plan.power_budget * (1 + 1e-9)]
        new_ceiling = fits[-1] if fits else self.ladder[0].bits
        moved = new_ceiling != self.ceiling_bits
        self.replans.append({
            "tick": int(tick), "reason": reason,
            "tokens_per_s": rate,
            "per_mac_budget": plan.power_budget,
            "plan_gbitflips_per_token": pw.giga(plan.total_power),
            "ceiling_bits": int(new_ceiling),
            "moved": bool(moved),
        })
        self.ceiling_bits = new_ceiling
        self._window_tokens = 0
        self._window_ticks = 0
        return moved


# ---------------------------------------------------------------------------
# Hosts and per-request bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetHost:
    """One simulated host: an engine plus its live lanes and telemetry."""
    host_id: int
    role: str                      # "prefill" | "decode"
    engine: ServeEngine
    rung_bits: tuple
    max_lanes: int
    lanes: list = dataclasses.field(default_factory=list)
    monitor: fault.StepMonitor = dataclasses.field(
        default_factory=fault.StepMonitor)

    def free_slots(self) -> int:
        return self.max_lanes - len(self.lanes)


@dataclasses.dataclass
class _StreamRec:
    """Fleet-side record of one request across hosts, rungs and restarts.
    ``tokens`` is lane-aligned (uncapped at max_new_tokens — a row rides
    its wave to the wave's gen_max); the response truncates, the replay
    and verification paths use the full row."""
    req: Request
    arrival: int
    rung_bits: int
    slo_violated: bool
    tokens: list = dataclasses.field(default_factory=list)
    segments: list = dataclasses.field(default_factory=list)
    decode_ledgers: list = dataclasses.field(default_factory=list)
    prefill_ledgers: list = dataclasses.field(default_factory=list)
    first_token_tick: Optional[int] = None
    done_tick: Optional[int] = None
    restarts: int = 0
    switches: int = 0
    wave_uids: tuple = ()          # uids sharing this stream's wave/lane

    def close_segment(self, new_tokens: list) -> None:
        self.tokens.extend(new_tokens)
        if self.segments and self.segments[-1]["rung_bits"] == \
                self.rung_bits:
            self.segments[-1]["tokens"].extend(new_tokens)
        else:
            self.segments.append({"rung_bits": self.rung_bits,
                                  "tokens": list(new_tokens)})


@dataclasses.dataclass
class _Replay:
    """Work waiting for budget and a slot: a detached lane to be
    teacher-forced back into a (possibly different) host at a (possibly
    different) rung, or a fresh wave whose prefill did not fit this
    tick's grant. ``paid`` accumulates grant credit across ticks so an
    action pricier than one whole tick's grant still makes progress —
    it executes once fully paid, and no tick ever overspends."""
    wave: Wave
    prefix_rows: Optional[np.ndarray]   # None for a fresh prefill
    pinned_host: Optional[int]          # restarts resume on the reborn host
    reason: str                         # "restart" | "switch" | "prefill"
    paid: float = 0.0


# ---------------------------------------------------------------------------
# The fleet
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Shape of the simulated fleet (docs/fleet.md walks every knob)."""
    n_decode_hosts: int = 4
    n_prefill_hosts: int = 1
    ladder_bits: tuple = (2, 4, 6)
    allocation: str = "uniform"
    cap_gbitflips_per_s: float = 2.0
    tick_seconds: float = 1.0
    control_interval: int = 4
    steps_per_tick: int = 4        # decode steps per lane per tick
    prefills_per_tick: int = 1     # waves one prefill host starts per tick
    max_lanes_per_host: int = 2
    max_batch: int = 2
    max_len: int = 48
    rung_sharding: bool = True
    backend: Optional[str] = None
    cache_bits: Optional[int] = None
    max_restarts_per_host: int = 3
    drain_tick_factor: int = 10    # stall guard: max ticks / trace ticks


class Fleet:
    """A mesh of ServeEngine hosts under one power governor.

    Build once from a model config + params (the weight store is written
    to ``artifact_dir`` as the PR-8 mmap artifact) or point ``artifact_dir``
    at an existing artifact (``params=None``) — every host then loads the
    SAME ``weights.bin`` by mmap, including hosts reborn after a kill.
    """

    def __init__(self, cfg: ModelConfig, fleet_cfg: FleetConfig,
                 artifact_dir: str, params: Any = None,
                 mse_dim: Optional[float] = None):
        from repro.models import serving
        from repro.serve_engine import artifact as afct

        fc = fleet_cfg
        if fc.n_decode_hosts < 1 or fc.n_prefill_hosts < 1:
            raise ValueError("need >= 1 prefill and >= 1 decode host")
        if fc.cache_bits is not None:
            # "auto" is engine-level; fleet pricing needs one fixed width
            if not isinstance(fc.cache_bits, int) \
                    or not 2 <= fc.cache_bits <= 7:
                raise ValueError(f"fleet cache_bits must be None or an int "
                                 f"in [2, 7]: {fc.cache_bits!r}")
        self.cfg = cfg
        self.fc = fc
        self.artifact_dir = artifact_dir
        self._mse_dim = float(mse_dim or cfg.d_model)
        self.profile = costs.module_cost_profile(cfg)
        alloc_profile = self.profile
        self.ladder = build_ladder(fc.ladder_bits, d=self._mse_dim,
                                   allocation=fc.allocation,
                                   profile=alloc_profile)
        if params is not None:
            # quantize ONCE, persist as the mmap artifact all hosts map
            from repro.kernels import dispatch
            needs_planes = (fc.backend is not None and
                            dispatch.parse_backend(fc.backend)[0]
                            == "packed")
            specs = {op.bits: (op.tree if op.tree is not None
                               else (op.r, op.b_x_tilde))
                     for op in self.ladder}
            cb = ({op.bits: fc.cache_bits for op in self.ladder}
                  if fc.cache_bits is not None else None)
            qspec = serving.ServingQuantSpec(pack_planes=needs_planes,
                                             cache_bits=cb)
            ws = serving.build_weight_store(params, cfg, specs, spec=qspec)
            afct.write_artifact(artifact_dir, ws,
                                meta={"fleet_ladder": list(fc.ladder_bits)})
        self._load_artifact = lambda: afct.load_artifact(artifact_dir)

        shards = (rung_shard(fc.ladder_bits, fc.n_decode_hosts)
                  if fc.rung_sharding else
                  {h: tuple(sorted(fc.ladder_bits))
                   for h in range(fc.n_decode_hosts)})
        self.decode_hosts: dict[int, FleetHost] = {
            h: self._build_host(h, "decode", shards[h])
            for h in range(fc.n_decode_hosts)}
        self.prefill_hosts: dict[int, FleetHost] = {
            h: self._build_host(h, "prefill",
                                tuple(sorted(fc.ladder_bits)))
            for h in range(fc.n_prefill_hosts)}
        # ONE pricing authority: the first prefill host's full-ladder
        # engine prices every ledger, so fleet accounting cannot drift
        # between hosts serving different shards
        self._pricer = self.prefill_hosts[0].engine
        self.governor = PowerGovernor(
            self.ladder, self.profile, fc.cap_gbitflips_per_s,
            tick_seconds=fc.tick_seconds,
            control_interval=fc.control_interval)
        self.supervisor = fault.FleetSupervisor(
            self._restart_host,
            max_restarts_per_host=fc.max_restarts_per_host)
        self.scheduler = Scheduler(self.ladder, fc.max_batch)
        self.streams: dict[int, _StreamRec] = {}
        self._replays: list[_Replay] = []
        self._deferred: list[_Replay] = []
        self._pending_responses: list[Response] = []
        self.migrations = 0

    # -- host lifecycle -----------------------------------------------------

    def _build_host(self, host_id: int, role: str,
                    rung_bits: tuple) -> FleetHost:
        eng = ServeEngine(self.cfg, weight_store=self._load_artifact(),
                          ladder_bits=rung_bits,
                          max_batch=self.fc.max_batch,
                          max_len=self.fc.max_len,
                          mse_dim=self._mse_dim,
                          allocation=self.fc.allocation,
                          backend=self.fc.backend,
                          cache_bits=self.fc.cache_bits)
        eng.warmup()
        return FleetHost(host_id=host_id, role=role, engine=eng,
                         rung_bits=rung_bits,
                         max_lanes=self.fc.max_lanes_per_host)

    def _restart_host(self, host_id: int) -> FleetHost:
        """dist.fault restart path: the reborn host re-mmaps the SAME
        artifact — no re-quantization, no new weight bytes on the wire."""
        dead = self.decode_hosts[host_id]
        return self._build_host(host_id, "decode", dead.rung_bits)

    def _alive_decode_hosts(self) -> list[FleetHost]:
        return [self.decode_hosts[h] for h in sorted(self.decode_hosts)]

    def _slot_for(self, bits: int,
                  pinned: Optional[int] = None) -> Optional[FleetHost]:
        """Deterministic placement: the pinned host if it can take the
        lane, else the least-loaded (lowest id) live host serving ``bits``
        with a free slot."""
        if pinned is not None:
            host = self.decode_hosts[pinned]
            if bits in host.rung_bits and host.free_slots() > 0:
                return host
        cands = [h for h in self._alive_decode_hosts()
                 if bits in h.rung_bits and h.free_slots() > 0]
        if not cands:
            return None
        return min(cands, key=lambda h: (len(h.lanes), h.host_id))

    # -- request admission --------------------------------------------------

    def _resolve(self, req: Request) -> tuple:
        """(rung, slo_violated) under the governor ceiling. The cap wins
        conflicts: a floor that needs a rung above the ceiling (or above
        the request's own budget) is served at the best rung the ceiling
        allows and COUNTED as an SLO violation — never silently dropped,
        never a cap breach."""
        ceiling = self.governor.ceiling_bits
        try:
            rung = select_rung(self.ladder, req.power_budget_bits,
                               req.min_score, max_bits=ceiling)
        except ValueError:
            rung = select_rung(self.ladder, req.power_budget_bits,
                               max_bits=ceiling)
        violated = (req.min_score is not None
                    and rung.score < req.min_score)
        return rung, violated

    def _admit(self, req: Request, tick: int) -> None:
        rung, violated = self._resolve(req)
        self.streams[req.uid] = _StreamRec(
            req=req, arrival=tick, rung_bits=rung.bits,
            slo_violated=violated)
        self.scheduler.submit(req, rung=rung)

    def _requeue_for_ceiling(self, tick: int) -> None:
        """Ceiling moved: re-resolve every piece of work that has not
        finished — queued requests, deferred waves, queued replays, and
        in-flight lanes above the ceiling (those close their segment and
        queue a bit-exact prefix replay at the new rung on whichever host
        takes it)."""
        queued = self.scheduler.drain() + \
            [req for ent in self._deferred for req in ent.wave.requests]
        self._deferred.clear()   # partial credit on deferred waves is burned
        for req in queued:
            rec = self.streams[req.uid]
            rung, violated = self._resolve(req)
            rec.rung_bits = rung.bits
            rec.slo_violated = rec.slo_violated or violated
            self.scheduler.submit(req, rung=rung)
        ceiling = self.governor.ceiling_bits
        new_rung = select_rung(self.ladder, max_bits=ceiling)
        for i, rp in enumerate(self._replays):
            if rp.wave.rung.bits > ceiling:
                for req in rp.wave.requests:
                    rec = self.streams[req.uid]
                    rec.switches += 1
                    rec.rung_bits = new_rung.bits
                self._replays[i] = dataclasses.replace(
                    rp, wave=Wave(rung=new_rung,
                                  requests=rp.wave.requests))
        for host in self._alive_decode_hosts():
            for lane in list(host.lanes):
                if lane.wave.rung.bits <= ceiling:
                    continue
                host.lanes.remove(lane)
                if lane.steps_left <= 0:
                    # already fully generated — nothing left to serve at
                    # the new rung; finalize instead of replaying
                    self._detach_lane_finished(lane, tick)
                    self._pending_responses.extend(
                        self._responses_for(lane))
                    continue
                prefix = self._detach_lane(lane, switch_to=new_rung.bits)
                self._replays.append(_Replay(
                    wave=Wave(rung=new_rung, requests=lane.wave.requests),
                    prefix_rows=prefix, pinned_host=None, reason="switch"))
                self.migrations += 1

    def _detach_lane(self, lane: Lane,
                     switch_to: Optional[int] = None) -> np.ndarray:
        """Fold a detached lane's tokens into its streams and return the
        replay prefix rows (prompt + every token grown so far)."""
        gen = lane.generated_rows()
        for i, req in enumerate(lane.wave.requests):
            rec = self.streams[req.uid]
            rec.close_segment(gen[i].tolist())
            if switch_to is not None:
                rec.switches += 1
                rec.rung_bits = switch_to
            else:
                rec.restarts += 1
        prompts = np.stack([r.prompt for r in lane.wave.requests])
        rows = [np.concatenate([prompts[i].astype(np.int32),
                                np.asarray(self.streams[r.uid].tokens,
                                           np.int32)])
                for i, r in enumerate(lane.wave.requests)]
        return np.stack(rows)

    def _kill_host(self, host_id: int, tick: int) -> None:
        if host_id not in self.decode_hosts:
            raise ValueError(f"host_kills names unknown decode host "
                             f"{host_id}")
        host = self.decode_hosts[host_id]
        lost = list(host.lanes)
        host.lanes.clear()
        reborn = self.supervisor.absorb(
            fault.HostFailure(host_id, f"killed at tick {tick}"))
        self.decode_hosts[host_id] = reborn
        for lane in lost:
            if lane.steps_left <= 0:
                # the lane finished before the kill; its tokens were
                # already produced (streamed) — finalize, don't replay
                self._detach_lane_finished(lane, tick)
                self._pending_responses.extend(self._responses_for(lane))
                continue
            prefix = self._detach_lane(lane)
            self._replays.append(_Replay(
                wave=lane.wave, prefix_rows=prefix,
                pinned_host=host_id, reason="restart"))

    # -- pricing ------------------------------------------------------------

    def _stream_ctx(self, req: Request) -> int:
        return req.prompt_len + req.max_new_tokens

    def _wave_step_flips(self, wave: Wave, gen_counts) -> float:
        """One decode step's price: one token per request still inside its
        own quota, at the wave's rung. Fixed summation order (request
        order in the wave) keeps the float total deterministic."""
        bits = wave.rung.bits
        total = 0.0
        for req, done in zip(wave.requests, gen_counts):
            if done < req.max_new_tokens:
                total += self._pricer.token_flips(bits,
                                                  self._stream_ctx(req))
        return total

    def _prefix_flips(self, wave: Wave, n_prefix: int) -> float:
        bits = wave.rung.bits
        return sum(self._pricer.token_flips(bits, self._stream_ctx(req))
                   * n_prefix for req in wave.requests)

    def _charge_prefill(self, wave: Wave, n_prefix: int) -> None:
        for req in wave.requests:
            led = self._pricer.ledger_for(self.ladder_rung(wave.rung.bits),
                                          self._stream_ctx(req))
            led.charge(n_prefix)
            self.streams[req.uid].prefill_ledgers.append(led)

    def ladder_rung(self, bits: int):
        for op in self.ladder:
            if op.bits == bits:
                return op
        raise KeyError(bits)

    # -- lane servicing -----------------------------------------------------

    def _start_lane(self, host: FleetHost, wave: Wave, lane: Lane,
                    tick: int) -> None:
        host.lanes.append(lane)
        uids = tuple(r.uid for r in wave.requests)
        for req in wave.requests:
            rec = self.streams[req.uid]
            rec.wave_uids = uids
            if rec.first_token_tick is None:
                rec.first_token_tick = tick
            led = self._pricer.ledger_for(self.ladder_rung(wave.rung.bits),
                                          self._stream_ctx(req))
            rec.decode_ledgers.append(led)
            # the lane is born with its first generated token on board
            if len(rec.tokens) < req.max_new_tokens:
                led.charge(1)

    def _replay_cost(self, rp: _Replay) -> float:
        """Full price of executing a pending entry: the teacher-forced
        prefix plus the one new token the (re)built lane is born with."""
        if rp.prefix_rows is not None:
            n_prefix = rp.prefix_rows.shape[1]
            gen = [len(self.streams[r.uid].tokens)
                   for r in rp.wave.requests]
        else:
            n_prefix = rp.wave.requests[0].prompt_len
            gen = [0] * len(rp.wave.requests)
        return self._prefix_flips(rp.wave, n_prefix) + \
            self._wave_step_flips(rp.wave, gen)

    def _pay(self, rp: _Replay) -> bool:
        """Pay down a pending entry from this tick's grant; True when it
        is fully paid and may execute."""
        cost = self._replay_cost(rp)
        need = cost - rp.paid
        if need > 0:
            rp.paid += self.governor.take(need)
        return rp.paid >= cost - 1e-6

    def _service_replays(self, tick: int) -> None:
        kept = []
        for rp in self._replays:
            host = self._slot_for(rp.wave.rung.bits, rp.pinned_host)
            if host is None or not self._pay(rp):
                kept.append(rp)
                continue
            n_prefix = rp.prefix_rows.shape[1]
            t0 = time.monotonic()
            lane = host.engine.prefill_wave(rp.wave,
                                            prefix_rows=rp.prefix_rows)
            host.monitor.record(tick, time.monotonic() - t0)
            self._charge_prefill(rp.wave, n_prefix)
            self._start_lane(host, rp.wave, lane, tick)
        self._replays = kept

    def _service_prefill(self, tick: int) -> None:
        """Start new lanes, up to the fleet's prefill capacity this tick.
        A wave whose prefill does not fit what remains of the grant parks
        in the deferred queue with its partial credit and executes once
        fully paid (FIFO — later arrivals don't overtake it)."""
        capacity = len(self.prefill_hosts) * self.fc.prefills_per_tick
        ph_ids = sorted(self.prefill_hosts)
        started = 0
        while started < capacity:
            if self._deferred:
                ent = self._deferred[0]
                if self._slot_for(ent.wave.rung.bits) is None:
                    break
                if not self._pay(ent):
                    break
                self._deferred.pop(0)
            else:
                eligible = {op.bits for op in self.ladder
                            if self._slot_for(op.bits) is not None}
                wave = self.scheduler.next_wave(eligible)
                if wave is None:
                    break
                ent = _Replay(wave=wave, prefix_rows=None,
                              pinned_host=None, reason="prefill")
                if not self._pay(ent):
                    self._deferred.append(ent)
                    break
            host = self._slot_for(ent.wave.rung.bits)
            ph = self.prefill_hosts[ph_ids[started % len(ph_ids)]]
            t0 = time.monotonic()
            lane = ph.engine.prefill_wave(ent.wave)
            ph.monitor.record(tick, time.monotonic() - t0)
            # disaggregation handoff: the lane's state arrays move to
            # the decode host; both engines share the artifact, so the
            # continuation is the same function either side computes
            self._charge_prefill(ent.wave, ent.wave.requests[0].prompt_len)
            self._start_lane(host, ent.wave, lane, tick)
            started += 1

    def _service_decode(self, tick: int) -> tuple[int, list[Response]]:
        tokens = 0
        finished: list[Response] = []
        for _ in range(self.fc.steps_per_tick):
            for host in self._alive_decode_hosts():
                for lane in list(host.lanes):
                    if lane.steps_left <= 0:
                        done = True
                    else:
                        gen_counts = [len(self.streams[r.uid].tokens)
                                      + len(lane.generated)
                                      for r in lane.wave.requests]
                        cost = self._wave_step_flips(lane.wave, gen_counts)
                        if not self.governor.try_spend(cost):
                            continue          # stall: grant exhausted
                        t0 = time.monotonic()
                        done = host.engine.step_lane(lane)
                        host.monitor.record(tick,
                                            time.monotonic() - t0)
                        for req, n in zip(lane.wave.requests, gen_counts):
                            if n < req.max_new_tokens:
                                rec = self.streams[req.uid]
                                rec.decode_ledgers[-1].charge(1)
                                tokens += 1
                    if done:
                        host.lanes.remove(lane)
                        self._detach_lane_finished(lane, tick)
                        finished.extend(self._responses_for(lane))
        return tokens, finished

    def _detach_lane_finished(self, lane: Lane, tick: int) -> None:
        gen = lane.generated_rows()
        for i, req in enumerate(lane.wave.requests):
            rec = self.streams[req.uid]
            rec.close_segment(gen[i].tolist())
            rec.done_tick = tick

    def _responses_for(self, lane: Lane) -> list[Response]:
        out = []
        for req in lane.wave.requests:
            rec = self.streams[req.uid]
            agg = pw.aggregate_ledgers(rec.decode_ledgers)
            meta = {
                "rung_bits": rec.rung_bits,
                "segments": [{"rung_bits": s["rung_bits"],
                              "tokens": len(s["tokens"])}
                             for s in rec.segments],
                "arrival_tick": rec.arrival,
                "done_tick": rec.done_tick,
                "first_token_tick": rec.first_token_tick,
                "restarts": rec.restarts,
                "switches": rec.switches,
                "slo_violated": rec.slo_violated,
                "est_bitflips_total": agg["bitflips_total"],
                "tokens": agg["tokens"],
            }
            out.append(Response(
                uid=req.uid,
                tokens=rec.tokens[:req.max_new_tokens],
                rung_bits=rec.rung_bits, metadata=meta))
        return out

    # -- the tick loop ------------------------------------------------------

    def _work_pending(self) -> bool:
        return bool(self.scheduler.pending() or self._deferred
                    or self._replays or self._pending_responses
                    or any(h.lanes for h in self._alive_decode_hosts()))

    def run(self, trace: FleetTrace) -> dict:
        """Serve the whole trace; returns the fleet report (docs/fleet.md
        explains every field). Deterministic up to wall-clock timing
        fields, which are informational."""
        t_wall = time.monotonic()
        arrivals = dict(trace.arrivals)
        kills: dict[int, list[int]] = {}
        for t, h in trace.host_kills:
            kills.setdefault(int(t), []).append(int(h))
        steps = {int(t): float(g) for t, g in trace.budget_steps}
        responses: list[Response] = []
        per_tick: list[dict] = []
        max_ticks = max(trace.n_ticks, 1) * self.fc.drain_tick_factor
        tick = 0
        while tick < trace.n_ticks or self._work_pending():
            if tick >= max_ticks:
                raise RuntimeError(
                    f"fleet stalled: work still pending after {tick} "
                    f"ticks (cap too small for the trace? "
                    f"{len(responses)} / {len(self.streams)} streams "
                    f"done)")
            self.governor.begin_tick()
            if tick in steps:
                self.governor.set_cap(steps[tick], tick)
                self._requeue_for_ceiling(tick)
            for h in kills.get(tick, ()):
                self._kill_host(h, tick)
            for req in arrivals.get(tick, ()):
                self._admit(req, tick)
            self._service_replays(tick)
            self._service_prefill(tick)
            tokens, done = self._service_decode(tick)
            responses.extend(self._pending_responses)
            self._pending_responses.clear()
            responses.extend(done)
            self.governor.observe(tokens)
            per_tick.append({
                "tick": tick,
                "flips": self.governor.spent_this_tick,
                "cap": self.governor.cap_per_tick,
                "tokens": tokens,
                "ceiling_bits": self.governor.ceiling_bits,
            })
            if self.governor.maybe_replan(tick):
                self._requeue_for_ceiling(tick)
            tick += 1
        return self._report(trace, responses, per_tick,
                            time.monotonic() - t_wall)

    # -- reporting ----------------------------------------------------------

    def assert_no_recompile(self) -> None:
        """Every host (including reborn ones) kept ONE compiled decode
        step across governor replans, rung switches and replays."""
        for host in (list(self.prefill_hosts.values())
                     + list(self.decode_hosts.values())):
            host.engine.assert_no_recompile()

    def _report(self, trace, responses, per_tick, wall_s) -> dict:
        responses = sorted(responses, key=lambda r: r.uid)
        recs = [self.streams[uid] for uid in sorted(self.streams)]
        decode_agg = pw.aggregate_ledgers(
            led for rec in recs for led in rec.decode_ledgers)
        prefill_agg = pw.aggregate_ledgers(
            led for rec in recs for led in rec.prefill_ledgers)
        realized = decode_agg["bitflips_total"] + \
            prefill_agg["bitflips_total"]
        violations = sum(1 for t in per_tick if t["flips"] > t["cap"])
        hist: dict[int, int] = {}
        for rec in recs:
            for seg in rec.segments:
                hist[seg["rung_bits"]] = hist.get(seg["rung_bits"], 0) \
                    + len(seg["tokens"])
        lat = sorted((rec.done_tick - rec.arrival) for rec in recs
                     if rec.done_tick is not None)
        ttft = sorted((rec.first_token_tick - rec.arrival) for rec in recs
                      if rec.first_token_tick is not None)

        def p50(xs):
            return xs[len(xs) // 2] if xs else None

        sim_seconds = len(per_tick) * self.fc.tick_seconds
        return {
            "hosts": {
                "decode": len(self.decode_hosts),
                "prefill": len(self.prefill_hosts),
                "rung_shards": {h: list(self.decode_hosts[h].rung_bits)
                                for h in sorted(self.decode_hosts)},
            },
            "requests": trace.n_requests,
            "served": len(responses),
            "ticks": len(per_tick),
            "sim_seconds": sim_seconds,
            # the EXACT-gated telemetry numbers (benchmarks/fleet_sim.py)
            "realized_bitflips": realized,
            "realized_gbitflips": pw.giga(realized),
            "decode_gbitflips": decode_agg["gbitflips_total"],
            "prefill_gbitflips": prefill_agg["gbitflips_total"],
            "decode_tokens": decode_agg["tokens"],
            "cap_violations": violations,
            "realized_gbitflips_per_s": pw.giga(realized)
            / max(sim_seconds, 1e-9),
            "tokens_per_sim_s": decode_agg["tokens"]
            / max(sim_seconds, 1e-9),
            "rung_token_histogram": {str(k): hist[k]
                                     for k in sorted(hist)},
            "slo_violations": sum(1 for rec in recs if rec.slo_violated),
            "host_restarts": self.supervisor.total_restarts,
            "migrations": self.migrations,
            "governor": {
                "cap_gbitflips_per_s": self.governor.cap_gbitflips_per_s,
                "ceiling_bits": self.governor.ceiling_bits,
                "replans": self.governor.replans,
            },
            "per_tick": per_tick,
            "straggler_steps": sum(
                h.monitor.stragglers
                for h in (list(self.prefill_hosts.values())
                          + list(self.decode_hosts.values()))),
            # informational (wall clock — NOT gated)
            "wall_s": round(wall_s, 3),
            "latency_ticks_p50": p50(lat),
            "ttft_ticks_p50": p50(ttft),
            "streams": [{
                "uid": rec.req.uid,
                "prompt": rec.req.prompt.tolist(),
                "max_new_tokens": rec.req.max_new_tokens,
                "budget_bits": rec.req.power_budget_bits,
                "wave_uids": list(rec.wave_uids),
                "segments": rec.segments,
                "restarts": rec.restarts,
                "switches": rec.switches,
            } for rec in recs],
        }


def verify_streams(report: dict, engine: ServeEngine,
                   only_disrupted: bool = False) -> list[str]:
    """Replay every served WAVE through ONE uninterrupted reference engine
    and compare tokens segment by segment — the fleet-scope bit-exactness
    oracle. A wave that crossed the prefill/decode handoff, a host restart,
    a governor rung switch and any number of hosts must equal a single
    engine serving the same (requests, rung schedule) start to finish.

    Replays are wave-granular, not stream-granular, because activation
    quantization scales are computed over the whole batch: a row's logits
    depend on its batchmates, so only a replay with the SAME batch
    composition (which is exactly what fleet restarts and switches
    preserve) is bit-comparable. Returns human-readable mismatches
    (empty = all verified)."""
    failures = []
    waves: dict[tuple, dict] = {}
    for s in report["streams"]:
        waves.setdefault(tuple(s["wave_uids"]), {})[s["uid"]] = s
    by_bits = {op.bits: op for op in engine.ladder}
    for uids in sorted(waves):
        if not uids:
            continue               # stream never reached a lane
        ss = [waves[uids][u] for u in uids]
        if only_disrupted and not any(s["restarts"] or s["switches"]
                                      for s in ss):
            continue
        # rows of one wave step together, so their segment structures are
        # identical; total is the lane-aligned (uncapped) token count
        segs = ss[0]["segments"]
        total = sum(len(seg["tokens"]) for seg in segs)
        if total == 0:
            continue
        reqs = tuple(Request(uid=s["uid"],
                             prompt=np.asarray(s["prompt"], np.int32),
                             max_new_tokens=total) for s in ss)
        prompts = np.stack([np.asarray(s["prompt"], np.int32) for s in ss])
        grown = np.zeros((len(ss), 0), np.int32)
        for k, seg in enumerate(segs):
            n = len(seg["tokens"])
            if n == 0:
                continue
            wave = Wave(rung=by_bits[seg["rung_bits"]], requests=reqs)
            if grown.shape[1] == 0:
                lane = engine.prefill_wave(wave)
            else:
                lane = engine.prefill_wave(
                    wave, prefix_rows=np.concatenate([prompts, grown],
                                                     axis=1))
            for _ in range(n - 1):
                engine.step_lane(lane)
            rows = lane.generated_rows()[:, :n]
            for i, s in enumerate(ss):
                want = s["segments"][k]["tokens"]
                got = rows[i].tolist()
                if got != want:
                    failures.append(
                        f"stream {s['uid']} segment {k} "
                        f"({seg['rung_bits']}b x {n}): fleet tokens != "
                        f"uninterrupted replay; fleet {want[:8]} "
                        f"ref {got[:8]}")
            grown = np.concatenate([grown, rows], axis=1)
    return failures
