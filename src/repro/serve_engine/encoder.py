"""EncodeEngine: batch-oriented encoder serving — PANN beyond the LM decoder.

The decode engine (``serve_engine.engine``) is token-oriented: lanes, KV
caches, one step per token. Encoder workloads (vision towers, speech
frontends) are ITEM-oriented: one whole-sequence forward per image or
utterance, no cache, and a per-ITEM power budget instead of per-token.
This engine serves them through the SAME machinery:

  * the same ladder (``serve_engine.ladder``) — rungs planned against the
    per-item ``costs.encoder_cost_profile``, whose conv rows carry the
    exact kh·kw·Cin·Cout·Ho·Wo MAC account, so a layerwise rung trades
    conv-stem bits against encoder attention/mlp bits under one budget;
  * the same one-weight-store materialization (``models/serving.py``):
    every rung a zero-copy view, same avals, ONE jitted encode step for
    the whole ladder — ``warmup``/``assert_no_recompile`` prove it exactly
    as the decode engine does;
  * the same request-side dial: ``power_budget_bits`` / ``min_score``
    resolve through ``select_rung``, and every response carries an
    ``EnergyLedger`` itemizing its rung's per-module bit-flips (the
    ``conv.s{i}`` roles included).

Waves are whole-sequence: requests resolve to rungs, group into
``max_batch`` batches per rung, and each batch is one jitted call on the
rung's view — rung switching between waves is a pointer swap, never a
retrace.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core import policy as pol
from repro.core import power as pw
from repro.kernels import dispatch
from repro.models import model as MD
from repro.models import serving
from repro.serve_engine.ladder import build_ladder, select_rung


@dataclasses.dataclass(frozen=True)
class EncodeRequest:
    """One item to encode. ``item`` is the RAW frontend input — (H, W, C)
    pixels / (frames, 1, mels) features when the config owns a conv stem,
    or pre-embedded (T, d_model) stub embeddings when it doesn't. The
    budget/floor fields mean what they mean on a decode ``Request``, but
    per ITEM: the rung whose per-item encode power fits the budget."""
    uid: int
    item: np.ndarray
    power_budget_bits: Optional[int] = None
    min_score: Optional[float] = None


@dataclasses.dataclass
class EncodeResponse:
    uid: int
    encoded: np.ndarray          # (T, d_model) encoder states
    rung_bits: int
    metadata: dict


class EncodeEngine:
    """Multi-operating-point encoder serving runtime (module docstring)."""

    def __init__(self, cfg: ModelConfig, params: Any = None,
                 ladder_bits: Sequence[int] = (2, 3, 4, 6),
                 max_batch: int = 4, mesh=None, par=None,
                 mse_dim: Optional[float] = None,
                 allocation: str = "uniform",
                 backend: Optional[str] = None,
                 weight_store: Optional[serving.WeightStore] = None):
        if (params is None) == (weight_store is None):
            raise ValueError(
                "pass exactly one of params (quantize here) or "
                "weight_store (serve a prebuilt/loaded artifact)")
        self.backend = backend
        if backend is not None:
            dispatch.parse_backend(backend)      # fail fast on typos
            cfg = dataclasses.replace(cfg, kernel_backend=backend)
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.allocation = allocation
        # per-ITEM profile: conv rows exact, encdec encoder rows at
        # encoder_layers x n_tokens instances — the allocator and the
        # per-response breakdown both price in items, not tokens
        self.profile = costs.encoder_cost_profile(cfg)
        if not self.profile:
            raise ValueError(
                f"{cfg.name} ({cfg.family}) has no encode path: needs a "
                "conv_stem, encoder layers, or image tokens")
        self._macs_item = costs.encoder_macs_per_item(cfg)
        self.ladder = build_ladder(ladder_bits,
                                   d=float(mse_dim or cfg.d_model),
                                   allocation=allocation,
                                   profile=self.profile)
        self.rungs = {op.bits: op for op in self.ladder}
        needs_planes = (backend is not None
                        and dispatch.parse_backend(backend)[0] == "packed")
        rung_specs = {op.bits: (op.tree if op.tree is not None
                                else (op.r, op.b_x_tilde))
                      for op in self.ladder}
        if weight_store is not None:
            missing = [b for b in rung_specs if b not in weight_store.views]
            if missing:
                raise ValueError(
                    f"weight_store has no view for rung(s) {missing}; "
                    f"available: {sorted(weight_store.views)}")
            ws = serving.device_put_weight_store(
                serving.WeightStore(
                    store=weight_store.store,
                    views={b: weight_store.views[b] for b in rung_specs}),
                mesh=mesh, par=par)
        else:
            quant_spec = serving.ServingQuantSpec(pack_planes=needs_planes)
            ws = serving.build_weight_store(params, cfg, rung_specs,
                                            mesh=mesh, par=par,
                                            spec=quant_spec)
        self.weight_store = ws.store
        self.variants = ws.views
        # ONE jitted whole-sequence encode — every rung's view shares its
        # avals, so the ladder shares this single compilation
        self._step = jax.jit(lambda v, x: MD.encode(v, cfg, x))
        self.compilations_after_warmup: Optional[int] = None
        self.items_by_rung = {op.bits: 0 for op in self.ladder}
        self.rung_switches = 0
        self._last_bits: Optional[int] = None

    # -- shapes -------------------------------------------------------------

    def item_shape(self) -> tuple:
        """The per-item input shape this engine encodes."""
        cfg = self.cfg
        if cfg.conv_stem:
            h, w = cfg.frontend_hw
            return (h, w, cfg.conv_stem[0].c_in)
        return (costs.encoder_tokens(cfg), cfg.d_model)

    def _batch(self, items: Sequence[np.ndarray]) -> jnp.ndarray:
        want = self.item_shape()
        rows = []
        for it in items:
            a = np.asarray(it, np.float32)
            if a.shape != want:
                raise ValueError(
                    f"item shape {a.shape} != engine item shape {want}")
            rows.append(a)
        # pad the batch dim to max_batch (repeating row 0) so every wave
        # presents identical avals to the jitted step
        while len(rows) < self.max_batch:
            rows.append(rows[0])
        return jnp.asarray(np.stack(rows))

    # -- jit bookkeeping (same protocol as the decode engine) ---------------

    def _jit_cache_size(self) -> int:
        try:
            return int(self._step._cache_size())
        except Exception:
            return -1

    def warmup(self) -> None:
        """One encode per rung so the single expected compilation happens
        before traffic."""
        x = jnp.zeros((self.max_batch,) + self.item_shape(), jnp.float32)
        for op in self.ladder:
            jax.block_until_ready(self._step(self.variants[op.bits], x))
        self.compilations_after_warmup = self._jit_cache_size()

    def assert_no_recompile(self) -> None:
        if self.compilations_after_warmup is None:
            raise RuntimeError("call warmup() first")
        now = self._jit_cache_size()
        if now < 0 or self.compilations_after_warmup < 0:
            raise RuntimeError(
                "cannot verify the no-recompilation claim: jit cache "
                "introspection (_cache_size) is unavailable on this jax")
        if now > self.compilations_after_warmup:
            raise AssertionError(
                f"encode step recompiled while serving: "
                f"{self.compilations_after_warmup} -> {now} cache entries")

    # -- energy accounting --------------------------------------------------

    def _rung_tree(self, rung) -> pol.PolicyTree:
        if rung.tree is not None:
            return rung.tree
        return pol.uniform_policy(pol.ModuleQuant(
            mode="pann", r=rung.r, b_x_tilde=rung.b_x_tilde))

    def ledger_for(self, rung) -> pw.EnergyLedger:
        """Per-ITEM energy ledger: the per-module breakdown (conv roles
        included) prices this engine's per-item profile under the rung's
        tree; act MACs are the encoder's bidirectional T² attention. The
        'per_token' unit in the ledger's field names reads 'per item'
        here — one charge() per encoded image/utterance."""
        total, breakdown = pol.tree_power_per_token(
            self.profile, self._rung_tree(rung),
            act_macs=self._macs_item.act_macs)
        if rung.tree is None:
            # uniform rung: headline number from the closed-form account,
            # same convention as the decode engine's fp-cache headline
            total = pw.pann_token_bitflips(self._macs_item, rung.r,
                                           rung.b_x_tilde)
        return pw.EnergyLedger(total, breakdown_per_token=breakdown)

    def item_flips(self, bits: int) -> float:
        """Estimated bit flips of encoding ONE item at rung ``bits``."""
        return self.ledger_for(self.rungs[bits]).bitflips_per_token

    # -- serving ------------------------------------------------------------

    def _encode_wave(self, rung, reqs: Sequence[EncodeRequest]
                     ) -> list[EncodeResponse]:
        if self._last_bits is not None and rung.bits != self._last_bits:
            self.rung_switches += 1
        self._last_bits = rung.bits
        self.items_by_rung[rung.bits] += len(reqs)
        x = self._batch([r.item for r in reqs])
        out = np.asarray(self._step(self.variants[rung.bits], x))
        responses = []
        for i, req in enumerate(reqs):
            ledger = self.ledger_for(rung)
            ledger.charge(1)
            meta = {
                "rung_bits": rung.bits,
                "b_x_tilde": rung.b_x_tilde,
                "r": rung.r,
                "allocation": rung.allocation,
                "power_per_weight_mac": rung.power,
                **ledger.report(),
            }
            responses.append(EncodeResponse(uid=req.uid, encoded=out[i],
                                            rung_bits=rung.bits,
                                            metadata=meta))
        return responses

    def encode(self, requests: Sequence[EncodeRequest]
               ) -> list[EncodeResponse]:
        """Serve a batch of mixed-budget encode requests.

        Requests resolve to rungs up front (any infeasible budget/floor
        pair fails the whole call before any work), then group into
        per-rung waves of ``max_batch`` whole-sequence forwards.
        """
        resolved = [select_rung(self.ladder, r.power_budget_bits,
                                r.min_score) for r in requests]
        by_rung: dict[int, list[EncodeRequest]] = {}
        for req, rung in zip(requests, resolved):
            by_rung.setdefault(rung.bits, []).append(req)
        responses: list[EncodeResponse] = []
        for bits in sorted(by_rung):
            reqs = by_rung[bits]
            for i in range(0, len(reqs), self.max_batch):
                responses.extend(
                    self._encode_wave(self.rungs[bits],
                                      reqs[i:i + self.max_batch]))
        return sorted(responses, key=lambda r: r.uid)

    # -- reporting ----------------------------------------------------------

    def describe(self) -> dict:
        total_macs = sum(m.macs for m in self.profile)
        return {
            "workload": "encode",
            "allocation": self.allocation,
            "backend": self.backend,
            "item_shape": list(self.item_shape()),
            "encoder_tokens": costs.encoder_tokens(self.cfg),
            "ladder": [{"bits": op.bits, "b_x_tilde": op.b_x_tilde,
                        "r": round(op.r, 3),
                        "power_per_weight_mac": round(op.power, 2),
                        "total_gbitflips_per_item":
                            round(pw.giga(op.power * total_macs), 3)}
                       for op in self.ladder],
            "max_batch": self.max_batch,
            "compilations_after_warmup": self.compilations_after_warmup,
            "items_by_rung": dict(self.items_by_rung),
            "rung_switches": self.rung_switches,
        }
