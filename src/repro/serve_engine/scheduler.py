"""Continuous-batching scheduler over operating-point rungs.

Requests declare *what they can afford* (a power budget in unsigned-MAC
bits) or *what they must achieve* (an accuracy-proxy floor); the scheduler
resolves each to a ladder rung at admission and keeps one FIFO per rung.
Waves (rung, up-to-max_batch requests of equal prompt length) are handed to
the engine round-robin across rungs, so a burst on one rung can't starve
the others and the engine demonstrably switches operating points between
decode steps.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Optional, Sequence

import numpy as np

from repro.serve_engine.ladder import OperatingPoint, select_rung


@dataclasses.dataclass
class Request:
    """One generation request with its declared power/accuracy constraint."""
    uid: int
    prompt: np.ndarray                        # (prompt_len,) int32 token ids
    max_new_tokens: int = 16
    power_budget_bits: Optional[int] = None   # "spend at most this much"
    min_score: Optional[float] = None         # "be at least this good"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class Response:
    """Generated tokens plus the energy/operating-point metadata the issue
    promises: which rung served the request and what it cost per token."""
    uid: int
    tokens: list                              # generated token ids
    rung_bits: int
    metadata: dict                            # plan + EnergyLedger report


@dataclasses.dataclass(frozen=True)
class Wave:
    """A schedulable unit: requests sharing a rung and a prompt length."""
    rung: OperatingPoint
    requests: tuple


class Scheduler:
    def __init__(self, ladder: Sequence[OperatingPoint], max_batch: int):
        self.ladder = tuple(sorted(ladder, key=lambda op: op.power))
        self.max_batch = int(max_batch)
        self._queues: "OrderedDict[int, deque]" = OrderedDict(
            (op.bits, deque()) for op in self.ladder)
        self._rungs = {op.bits: op for op in self.ladder}
        self._rr = 0                      # round-robin cursor over rung index

    def submit(self, req: Request,
               rung: Optional[OperatingPoint] = None) -> OperatingPoint:
        """Resolve the request's constraint to a rung and enqueue it; pass a
        pre-resolved ``rung`` to skip re-selection (the engine validates the
        whole batch before enqueueing anything)."""
        if rung is None:
            rung = select_rung(self.ladder, req.power_budget_bits,
                               req.min_score)
        self._queues[rung.bits].append(req)
        return rung

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def drain(self) -> list[Request]:
        """Remove and return every queued request, FIFO within each rung,
        rungs in ladder (ascending-power) order. The fleet governor uses
        this on a ceiling change: queued work was resolved under the OLD
        ceiling, so it is drained and re-submitted through the new one —
        re-selection is the governor's actuator, and it must reach work
        that has not started yet, not only new arrivals."""
        out: list[Request] = []
        for q in self._queues.values():
            out.extend(q)
            q.clear()
        return out

    def next_wave(self, eligible_bits: Optional[set] = None
                  ) -> Optional[Wave]:
        """Pop the next wave, round-robin over rungs with queued work.

        Within a rung's FIFO we take the head request and every request
        behind it with the same prompt length (up to max_batch), so a wave
        prefills as one rectangular batch without padding bookkeeping.

        ``eligible_bits`` restricts which rungs may form a wave this call —
        the fleet hands in the rungs that currently have a free decode slot
        on some live host, so a busy (or dead) rung's queue waits without
        blocking the others, and the round-robin cursor only advances past
        rungs that actually produced work.
        """
        n = len(self.ladder)
        for off in range(n):
            bits = self.ladder[(self._rr + off) % n].bits
            if eligible_bits is not None and bits not in eligible_bits:
                continue
            q = self._queues[bits]
            if not q:
                continue
            self._rr = (self._rr + off + 1) % n
            head = q.popleft()
            picked = [head]
            rest = deque()
            while q and len(picked) < self.max_batch:
                r = q.popleft()
                if r.prompt_len == head.prompt_len:
                    picked.append(r)
                else:
                    rest.append(r)
            rest.extend(q)
            q.clear()
            q.extend(rest)
            return Wave(rung=self._rungs[bits], requests=tuple(picked))
        return None
