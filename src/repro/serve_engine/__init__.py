"""``repro.serve_engine`` — power-budget-aware multi-operating-point serving.

The paper's deployment claim is that PANN "seamlessly traverses the
power-accuracy trade-off at deployment time": moving along a Fig.-3
equal-power curve only changes ``(b~x, R)``, never the architecture. This
package turns that claim into a serving runtime:

  ladder      plan a small set of equal-power operating points (2/3/4/6-bit
              unsigned-MAC budgets by default) from ``planner.plan_ladder``
  scheduler   continuous-batching request scheduler that picks the rung per
              request from a declared power budget or accuracy floor
  engine      ``ServeEngine``: one bf16 checkpoint in, ONE max-budget
              weight store with a zero-copy view per rung
              (models/serving.build_weight_store), ONE jitted decode step
              shared by every rung, per-token bit-flip accounting in every
              response
  encoder     ``EncodeEngine``: the same ladder / weight store / no-retrace
              invariants for ITEM-oriented encoder workloads (vision conv
              stems, speech frontends) — whole-sequence waves, no KV cache,
              per-image/per-utterance power budgets (docs/encoder.md)
  artifact    the mmap-able on-disk form of the weight store
              (manifest.json + weights.bin; docs/artifact.md)
  fleet       ``Fleet``: ServeEngine replicated across simulated hosts on
              ``repro.dist`` — prefill/decode disaggregation, rung-sharded
              variant caches served from ONE mmap artifact, and a
              telemetry-driven power governor holding the whole fleet
              under a global Gbit-flips/sec cap (docs/fleet.md)

Design notes live in DESIGN.md §6, §11 and §12; the end-to-end traversal
benchmark is ``benchmarks/serve_traversal.py`` and the fleet simulation is
``benchmarks/fleet_sim.py``.
"""
from repro.serve_engine.artifact import (ArtifactError, load_artifact,
                                         write_artifact)
from repro.serve_engine.encoder import (EncodeEngine, EncodeRequest,
                                        EncodeResponse)
from repro.serve_engine.engine import Lane, ServeEngine
from repro.serve_engine.fleet import (Fleet, FleetConfig, FleetTrace,
                                      PowerGovernor, TrafficSpec,
                                      make_trace, verify_streams)
from repro.serve_engine.ladder import OperatingPoint, build_ladder, select_rung
from repro.serve_engine.scheduler import Request, Response, Scheduler

__all__ = ["ServeEngine", "Lane", "OperatingPoint", "build_ladder",
           "select_rung", "Request", "Response", "Scheduler",
           "EncodeEngine", "EncodeRequest", "EncodeResponse",
           "ArtifactError", "load_artifact", "write_artifact",
           "Fleet", "FleetConfig", "FleetTrace", "PowerGovernor",
           "TrafficSpec", "make_trace", "verify_streams"]
