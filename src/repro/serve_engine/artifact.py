"""The mmap-able serving artifact: one weight store, zero-copy rung views.

On-disk layout (one directory):

    manifest.json   — magic, version, per-leaf {dtype, shape, offset,
                      nbytes} records, and the per-rung view tables
    weights.bin     — every array back to back, 64-byte-aligned offsets

``write_artifact`` persists a ``models.serving.WeightStore``;
``load_artifact`` maps ``weights.bin`` ONCE (``np.memmap``) and hands every
leaf out as a view into that single mapping — no Python-side copy, however
many rungs the ladder has. View leaves that alias the store in memory
(the big w_q / plane / scale leaves) are stored once and recorded as
``{"ref": <store path>}`` in the manifest; loading resolves the ref back to
the SAME mmap view, so the on-disk artifact and the loaded tree both stay
flat in ladder depth (DESIGN.md §11, benchmarks/table14_footprint.py).

Leaf paths use the checkpoint convention (``ckpt.checkpoint``): "/"-joined
dict keys, ``#i`` for sequence positions — an artifact is greppable next to
a checkpoint. The manifest is written LAST, so a directory with a readable
manifest is complete; a truncated or doctored blob fails ``load_artifact``
with ``ArtifactError`` (size and bounds checks), never with garbage
weights. Version history: v1 — initial schema.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

from repro.ckpt.checkpoint import _key_str
from repro.models.serving import WeightStore

ARTIFACT_MAGIC = "repro-pann-weight-store"
ARTIFACT_VERSION = 1
MANIFEST = "manifest.json"
BLOB = "weights.bin"
_ALIGN = 64


class ArtifactError(ValueError):
    """Unreadable, foreign-version, or corrupt serving artifact."""


def _flatten(tree: Any) -> list[tuple[str, Any]]:
    return [("/".join(_key_str(k) for k in path), leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _unflatten(flat: dict) -> Any:
    """Rebuild nested dicts/lists from "/"-joined paths (#i = list index)."""
    root: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf

    def listify(node):
        if isinstance(node, dict):
            if node and all(k.startswith("#") for k in node):
                return [listify(node[f"#{i}"]) for i in range(len(node))]
            return {k: listify(v) for k, v in node.items()}
        return node

    return listify(root)


def write_artifact(directory: str, ws: WeightStore, meta: dict | None = None
                   ) -> str:
    """Persist a weight store + its rung views; returns the directory.

    Rung keys must be JSON scalars (the engine's are bit widths). The blob
    is written first and the manifest last, so readers never observe a
    manifest without its bytes; the manifest itself is replaced atomically.
    """
    os.makedirs(directory, exist_ok=True)
    chunks: list[bytes] = []
    offset = 0

    def add(leaf) -> dict:
        nonlocal offset
        arr = np.asarray(jax.device_get(leaf))
        pad = -offset % _ALIGN
        if pad:
            chunks.append(b"\0" * pad)
            offset += pad
        # record the shape BEFORE any contiguity copy: ascontiguousarray
        # promotes 0-d scalars to 1-d (tobytes is layout-identical either
        # way, but the manifest must reproduce the leaf's true aval)
        ent = {"dtype": arr.dtype.name, "shape": list(arr.shape),
               "offset": offset, "nbytes": int(arr.nbytes)}
        arr = np.ascontiguousarray(arr)
        chunks.append(arr.tobytes())
        offset += arr.nbytes
        return ent

    store_flat = _flatten(ws.store)
    id2path = {id(leaf): path for path, leaf in store_flat}
    store_entries = {path: add(leaf) for path, leaf in store_flat}
    views = []
    for key, view in ws.views.items():
        leaves = {}
        for path, leaf in _flatten(view):
            ref = id2path.get(id(leaf))
            leaves[path] = {"ref": ref} if ref is not None else add(leaf)
        views.append({"key": key, "leaves": leaves})

    manifest = {
        "magic": ARTIFACT_MAGIC,
        "version": ARTIFACT_VERSION,
        "blob": BLOB,
        "blob_bytes": offset,
        "store": store_entries,
        "views": views,
        "meta": meta or {},
    }
    with open(os.path.join(directory, BLOB), "wb") as f:
        for c in chunks:
            f.write(c)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(directory, MANIFEST))
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return directory


def load_artifact(directory: str) -> WeightStore:
    """mmap ``weights.bin`` once; return the store + views as zero-copy
    numpy views into that mapping (view leaves marked ``ref`` resolve to
    the SAME objects as the store's). Raises ``ArtifactError`` on a
    missing/corrupt manifest, a foreign version, or a blob whose size or
    leaf bounds disagree with the manifest."""
    try:
        with open(os.path.join(directory, MANIFEST)) as f:
            m = json.load(f)
    except OSError as e:
        raise ArtifactError(f"no readable {MANIFEST} in {directory}: {e}")
    except ValueError as e:
        raise ArtifactError(f"corrupt {MANIFEST} in {directory}: {e}")
    if m.get("magic") != ARTIFACT_MAGIC:
        raise ArtifactError(f"not a serving artifact: magic "
                            f"{m.get('magic')!r}")
    if m.get("version") != ARTIFACT_VERSION:
        raise ArtifactError(
            f"artifact version {m.get('version')!r} not supported by this "
            f"loader (wants {ARTIFACT_VERSION})")
    blob_path = os.path.join(directory, m.get("blob", BLOB))
    try:
        size = os.path.getsize(blob_path)
    except OSError as e:
        raise ArtifactError(f"missing blob {blob_path}: {e}")
    if size != m["blob_bytes"]:
        raise ArtifactError(
            f"blob size mismatch: {size} bytes on disk vs "
            f"{m['blob_bytes']} in the manifest (truncated artifact?)")
    mm = np.memmap(blob_path, dtype=np.uint8, mode="r")

    def leaf_of(path: str, ent: dict):
        off, n = int(ent["offset"]), int(ent["nbytes"])
        if off < 0 or off + n > mm.size:
            raise ArtifactError(
                f"leaf {path!r} spans [{off}, {off + n}) outside the "
                f"{mm.size}-byte blob")
        try:
            return (mm[off:off + n].view(np.dtype(ent["dtype"]))
                    .reshape(ent["shape"]))
        except (TypeError, ValueError) as e:
            raise ArtifactError(f"leaf {path!r} unreadable: {e}")

    store_leaves = {p: leaf_of(p, e) for p, e in m["store"].items()}
    views = {}
    for v in m["views"]:
        leaves = {}
        for p, e in v["leaves"].items():
            if "ref" in e:
                if e["ref"] not in store_leaves:
                    raise ArtifactError(
                        f"view leaf {p!r} refs unknown store path "
                        f"{e['ref']!r}")
                leaves[p] = store_leaves[e["ref"]]
            else:
                leaves[p] = leaf_of(p, e)
        views[v["key"]] = _unflatten(leaves)
    return WeightStore(store=_unflatten(store_leaves), views=views)


def read_meta(directory: str) -> dict:
    """The manifest's metadata block (validates magic/version)."""
    try:
        with open(os.path.join(directory, MANIFEST)) as f:
            m = json.load(f)
    except (OSError, ValueError) as e:
        raise ArtifactError(f"no readable {MANIFEST} in {directory}: {e}")
    if m.get("magic") != ARTIFACT_MAGIC or \
            m.get("version") != ARTIFACT_VERSION:
        raise ArtifactError("not a loadable serving artifact")
    return dict(m.get("meta", {}))
