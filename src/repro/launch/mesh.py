"""Production mesh construction (function, not module-level constant, so
importing never touches jax device state).

Single pod:  (16, 16)      axes ("data", "model")   — 256 chips (TPU v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

The dry-run launcher sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; everything else in the repo sees the real device
count (1 on this CPU container).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1) -> Mesh:
    """Whatever this host actually has — used by trainers/tests."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
