"""Train→serve export: freeze a trained checkpoint into a serving artifact
and PROVE the hand-off (CLI).

Loads a checkpoint written by ``launch/train.py``, rebuilds the training
run's final operating point (re-running the deterministic budget annealer /
layer-wise allocator when the run used ``--budget_schedule``), quantizes
the params for serving with the EMA-calibrated activation ranges frozen in
(``models.serving.quantize_params_for_serving(calib=...)``), and asserts
that the exported artifact reproduces the training-time held-out eval loss
to fp32 tolerance — the train→serve loop closes on numbers, not vibes.

    python -m repro.launch.train --arch llama3-8b --reduced --steps 120 \
        --quant pann --budget_schedule 0:fp,20:8,60:6 --ckpt_dir /tmp/ck
    python -m repro.launch.export --ckpt_dir /tmp/ck --out /tmp/artifact

The ``--out`` artifact directory uses the checkpoint layout (arrays.npz +
meta.json, atomic COMMITTED marker) so ``ckpt.checkpoint.restore`` loads it
straight into a serving tree; ``examples/serve_lm.py`` / the serve engine
consume it via ``build_variant_cache``-shaped params.

``--artifact_out`` additionally writes the mmap-able LADDER artifact
(``serve_engine.artifact``: manifest.json + weights.bin): one max-budget
weight store quantized from the same calibrated params, with a zero-copy
rung view per ``--artifact_ladder`` bit budget — the deployment form whose
weight HBM is independent of ladder depth (DESIGN.md §11, docs/artifact.md).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from types import SimpleNamespace

import jax
import numpy as np

from repro.ckpt import checkpoint as ck
from repro.core import anneal
from repro.launch import steps as ST
from repro.launch import train as TR
from repro.launch.mesh import make_local_mesh
from repro.models import serving
from repro.serve_engine import artifact
from repro.serve_engine.ladder import build_ladder


def _final_operating_point(cfg, tcfg, targs, step: int):
    """(eval config, policy tree, uniform point, bits) at the end of
    training — the rung the artifact is exported at."""
    annealer = anneal.BudgetAnnealer.from_train_config(cfg, tcfg)
    if annealer is not None:
        bits = annealer.schedule.bits_at(max(step - 1, 0))
        if bits <= 0:
            raise SystemExit(
                "[export] the schedule ends in a full-precision segment — "
                "nothing to quantize; extend the schedule past its last "
                "fp knot or export an earlier checkpoint")
        tree = annealer.tree_for(bits)
        return dataclasses.replace(cfg, policy=tree), tree, None, bits
    # fixed operating point: the global (R, b~x) the run was configured with
    return cfg, None, (targs.r, targs.act_bits), 0


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt_dir", required=True)
    ap.add_argument("--step", type=int, default=0,
                    help="checkpoint step to export (default: latest)")
    ap.add_argument("--out", default="",
                    help="write the serving artifact here (ckpt layout)")
    ap.add_argument("--artifact_out", default="",
                    help="write the mmap-able ladder weight store here "
                         "(manifest.json + weights.bin; "
                         "serve_engine.artifact)")
    ap.add_argument("--artifact_ladder", default="",
                    help="comma-separated bit budgets for the artifact's "
                         "rung views, e.g. 2,4,6 (default: the training "
                         "run's final operating point alone)")
    ap.add_argument("--tol", type=float, default=1e-3,
                    help="max |exported - training| eval-loss gap "
                         "(relative to the training loss)")
    args = ap.parse_args(argv)

    step = args.step or ck.latest_step(args.ckpt_dir)
    if step is None:
        raise SystemExit(f"[export] no checkpoint in {args.ckpt_dir}")
    meta = ck.read_meta(args.ckpt_dir, step)
    if "train_args" not in meta:
        raise SystemExit("[export] checkpoint meta lacks train_args "
                         "(written by a pre-export trainer?)")
    targs = SimpleNamespace(**meta["train_args"])
    cfg, tcfg, par = TR.build(targs)
    train_quant = TR.resolve_train_quant(targs)
    if targs.quant != "pann":
        raise SystemExit(f"[export] serving artifacts are PANN "
                         f"(checkpoint trained with --quant {targs.quant})")
    if cfg.tie_embeddings:
        raise SystemExit("[export] tied-embedding unembed has no separate "
                         "lm_head weight to quantize; untie to export")
    qat = train_quant == "qat"

    mesh = make_local_mesh(1)
    with mesh:
        key = jax.random.PRNGKey(targs.seed)
        template = jax.tree_util.tree_map(
            np.asarray, ST.make_train_state(key, cfg, tcfg, calibrate=qat))
        state = ck.restore(args.ckpt_dir, step, template,
                           strict=("calib/",))

        cfg_eval, tree, uniform_pt, bits = _final_operating_point(
            cfg, tcfg, targs, step)
        batch = TR.make_eval_batch(cfg, targs)

        # the training-time reference: the forward exactly as training ran
        # it — QAT fake-quant at the final operating point with activations
        # frozen to the calibrated EMA ranges, or plain fp for PTQ runs
        if qat:
            loss_train = ST.eval_loss(state.params, cfg_eval, batch,
                                      calib=state.calib)
        else:
            loss_train = ST.eval_loss(state.params,
                                      anneal.strip_quant(cfg), batch)

        calib = state.calib if qat else None
        if tree is not None:
            qspec = serving.ServingQuantSpec(policy=tree, calib=calib)
        else:
            qspec = serving.ServingQuantSpec(r=float(uniform_pt[0]),
                                             act_bits=int(uniform_pt[1]),
                                             calib=calib)
        variant = serving.quantize_params_for_serving(state.params, cfg,
                                                      spec=qspec)

        # the exported artifact through the SERVING forward (w_q dequant +
        # frozen static activation ranges) on the same held-out batch
        loss_serve = ST.eval_loss(variant, cfg_eval, batch)

    abs_diff = abs(loss_serve - loss_train)
    rel_diff = abs_diff / max(abs(loss_train), 1e-8)
    meta_eval = meta.get("eval_loss")
    summary = {
        "step": step, "bits": bits,
        "allocation": tcfg.budget_allocation if tcfg.budget_schedule
        else "uniform",
        "train_quant": train_quant,
        "loss_train_eval": loss_train, "loss_serve_eval": loss_serve,
        "abs_diff": abs_diff, "rel_diff": rel_diff,
        "meta_eval_loss": meta_eval,
    }
    if args.out:
        out_meta = {k: v for k, v in summary.items() if v is not None}
        out_meta["source_ckpt"] = args.ckpt_dir
        out_meta["train_args"] = meta["train_args"]
        path = ck.save(args.out, step, variant, meta=out_meta)
        summary["out"] = path
    if args.artifact_out:
        # the mmap-able ladder form: quantize ONCE at the max budget, one
        # zero-copy view per rung (models/serving.build_weight_store)
        if args.artifact_ladder:
            lad = build_ladder([int(b) for b in
                                args.artifact_ladder.split(",")],
                               d=float(cfg.d_model))
            specs = {op.bits: (op.tree if op.tree is not None
                               else (op.r, op.b_x_tilde)) for op in lad}
        elif tree is not None:
            specs = {bits: tree}
        else:
            specs = {0: (float(uniform_pt[0]),
                         None if uniform_pt[1] is None
                         else int(uniform_pt[1]))}
        ws = serving.build_weight_store(
            state.params, cfg, specs,
            spec=serving.ServingQuantSpec(pack_planes=True, calib=calib))
        summary["artifact_out"] = artifact.write_artifact(
            args.artifact_out, ws,
            meta={"source_ckpt": args.ckpt_dir, "step": step,
                  "rungs": sorted(specs),
                  "train_args": meta["train_args"]})
    print("[export] " + json.dumps(summary))

    if meta_eval is not None and qat and \
            abs(meta_eval - loss_train) > args.tol * max(abs(meta_eval), 1.0):
        raise SystemExit(
            f"[export] re-evaluated training loss {loss_train:.6f} drifted "
            f"from the checkpoint's recorded eval loss {meta_eval:.6f} — "
            f"the training forward is not reproducible")
    if qat and rel_diff > args.tol:
        raise SystemExit(
            f"[export] exported rung does NOT reproduce the training-time "
            f"eval loss: {loss_serve:.6f} vs {loss_train:.6f} "
            f"(rel {rel_diff:.2e} > tol {args.tol:.0e})")
    if qat:
        print(f"[export] round-trip OK: serving artifact reproduces the "
              f"training eval loss (rel diff {rel_diff:.2e})")
    else:
        print("[export] PTQ export (fp training reference; loss gap "
              f"{rel_diff:.2e} is the quantization cost, not gated)")
    return summary


if __name__ == "__main__":
    main()
