"""Batched serving driver: prefill + decode loop with KV/state caches and
optional PANN-quantized weights (the deployment story of the paper: pick a
power budget, plan (b~x, R) with Algorithm 1, serve).

Single operating point (legacy path):

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt_len 32 --gen 16 --quant pann --power_bits 4

Power-accuracy traversal (repro.serve_engine): plan a ladder of equal-power
operating points once, then pick the rung PER REQUEST from a declared power
budget — one process, one compiled step, many power levels:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --power_ladder 2,4,6 --budgets 4,2,6,6 --batch 4 --gen 16

Fleet under a global power cap (repro.serve_engine.fleet): N rung-sharded
decode hosts + a prefill host serving ONE mmap artifact, a telemetry-driven
governor holding aggregate Gbit-flips/sec under --global_budget:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --fleet_hosts 4 --global_budget 0.25 --ticks 12
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import costs, planner
from repro.data.pipeline import frontend_raw_stub, frontend_stub
from repro.models import model as MD
from repro.models import serving
from repro.serve_engine import (EncodeEngine, EncodeRequest, Request,
                                ServeEngine)
from repro.serve_engine.fleet import (Fleet, FleetConfig, TrafficSpec,
                                      make_trace)


def plan_quant(args, total_macs: float | None = None) -> QuantConfig:
    if args.quant == "none":
        return QuantConfig(mode="none")
    if args.quant == "pann":
        budget = planner.budget_from_bits(args.power_bits)
        plan = planner.plan_with_theory(budget)
        # total network price (MACs x per-MAC power), not just per-MAC:
        # directly comparable with ladder / layerwise startup logs
        print(f"[serve] {plan.describe(total_macs=total_macs)}")
        return QuantConfig(mode="pann", r=plan.r,
                           act_bits_tilde=plan.b_x_tilde)
    return QuantConfig(mode=args.quant, weight_bits=args.power_bits,
                       act_bits=args.power_bits)


def serve_fleet(args) -> dict:
    """The fleet path: N simulated hosts under one global Gbit-flips/s cap."""
    ladder_bits = tuple(int(b) for b in
                        (args.power_ladder or "2,4,6").split(","))
    cfg = configs.get_config(args.arch, quant=QuantConfig(mode="none"))
    if args.reduced:
        cfg = configs.reduced(cfg)
    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)

    fc = FleetConfig(
        n_decode_hosts=args.fleet_hosts,
        n_prefill_hosts=1,
        ladder_bits=ladder_bits,
        allocation=args.allocation,
        cap_gbitflips_per_s=args.global_budget,
        max_batch=args.batch,
        max_len=args.prompt_len + args.gen + 2,
        backend=args.backend or None,
    )
    spec = TrafficSpec(seed=args.seed + 7, n_ticks=args.ticks,
                       prompt_lens=(args.prompt_len,),
                       gen_tokens=(max(args.gen - 4, 2), args.gen),
                       budget_mix=ladder_bits + (max(ladder_bits),))
    art_dir = args.artifact_dir or tempfile.mkdtemp(prefix="fleet_serve_")
    fleet = Fleet(cfg, fc, art_dir, params=params)
    trace = make_trace(spec, cfg.vocab_size, fleet.ladder)

    t0 = time.monotonic()
    report = fleet.run(trace)
    dt = time.monotonic() - t0
    fleet.assert_no_recompile()

    summary = {
        "arch": cfg.name,
        "mode": "fleet",
        "hosts": report["hosts"],
        "artifact_dir": art_dir,
        "cap_gbitflips_per_s": args.global_budget,
        "requests": report["requests"],
        "served": report["served"],
        "realized_gbitflips": report["realized_gbitflips"],
        "realized_gbitflips_per_s": report["realized_gbitflips_per_s"],
        "cap_violations": report["cap_violations"],
        "rung_token_histogram": report["rung_token_histogram"],
        "governor_replans": len(report["governor"]["replans"]),
        "wall_s": round(dt, 3),
    }
    print("[serve] " + json.dumps(summary))
    return summary


def serve_encode(args) -> dict:
    """The encoder path: batch-oriented item serving (no KV cache) through
    ``serve_engine.EncodeEngine`` — same ladder, same one-weight-store
    views, per-image/per-utterance power budgets."""
    ladder_bits = [int(b) for b in
                   (args.power_ladder or "2,4,6").split(",")]
    budgets = [int(b) for b in args.budgets.split(",")] if args.budgets \
        else ladder_bits
    cfg = configs.get_config(args.arch, quant=QuantConfig(mode="none"))
    if args.reduced:
        cfg = configs.reduced(cfg)
    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)

    engine = EncodeEngine(cfg, params, ladder_bits=ladder_bits,
                          max_batch=args.batch,
                          allocation=args.allocation,
                          backend=args.backend or None)
    engine.warmup()
    total_macs = sum(m.macs for m in engine.profile)
    for op in engine.ladder:
        if op.lw is not None:
            print(f"[serve] {op.describe()}")
        else:
            print(f"[serve] rung[{op.bits}b] "
                  f"{op.plan.describe(total_macs=total_macs)}")

    n = args.requests or args.batch
    raw = frontend_raw_stub(cfg, n, 0, args.seed)
    if raw is None:                 # no conv stem: stub embeddings
        raw = frontend_stub(cfg, n, 0, args.seed)
    reqs = [EncodeRequest(uid=i, item=raw[i],
                          power_budget_bits=budgets[i % len(budgets)])
            for i in range(n)]

    t0 = time.monotonic()
    responses = engine.encode(reqs)
    dt = time.monotonic() - t0
    engine.assert_no_recompile()

    summary = {
        "arch": cfg.name,
        "mode": "encode",
        "engine": engine.describe(),
        "items": [{"uid": r.uid, "rung_bits": r.rung_bits,
                   "encoded_shape": list(r.encoded.shape), **r.metadata}
                  for r in responses],
        "encoded": len(responses),
        "wall_s": round(dt, 3),
        "items_per_s": round(len(responses) / max(dt, 1e-9), 1),
    }
    print("[serve] " + json.dumps(summary))
    return summary


def serve_ladder(args) -> dict:
    """The traversal path: one ServeEngine, per-request rung selection."""
    ladder_bits = [int(b) for b in args.power_ladder.split(",")]
    budgets = [int(b) for b in args.budgets.split(",")] if args.budgets \
        else ladder_bits
    cfg = configs.get_config(args.arch, quant=QuantConfig(mode="none"))
    if args.reduced:
        cfg = configs.reduced(cfg)
    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)

    fe_fn = None
    if cfg.family in ("encdec", "vlm"):
        def fe_fn(batch):
            fe = frontend_stub(cfg, batch, 0, args.seed)
            key = "enc_inputs" if cfg.family == "encdec" else "image_embeds"
            return {key: jnp.asarray(fe)}

    max_len = args.prompt_len + args.gen
    cache_bits = None
    if args.cache_bits:
        cache_bits = "auto" if args.cache_bits == "auto" \
            else int(args.cache_bits)
    engine = ServeEngine(cfg, params, ladder_bits=ladder_bits,
                         max_batch=args.batch, max_len=max_len,
                         allocation=args.allocation,
                         backend=args.backend or None,
                         autotune=args.autotune,
                         cache_bits=cache_bits,
                         artifact_format=args.artifact_format,
                         frontend_kwargs_fn=fe_fn)
    engine.warmup()
    total_macs = sum(m.macs for m in engine.profile)
    for op in engine.ladder:
        if op.lw is not None:
            print(f"[serve] {op.describe()}")
        else:
            # same unit as the layerwise log: total network Gbit-flips
            print(f"[serve] rung[{op.bits}b] "
                  f"{op.plan.describe(total_macs=total_macs)}")

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.gen,
                    power_budget_bits=budgets[i % len(budgets)])
            for i in range(args.requests or args.batch)]

    t0 = time.monotonic()
    responses = engine.generate(reqs)
    dt = time.monotonic() - t0
    engine.assert_no_recompile()

    n_tok = sum(len(r.tokens) for r in responses)
    summary = {
        "arch": cfg.name,
        "mode": "ladder",
        "engine": engine.describe(),
        "requests": [{"uid": r.uid, "rung_bits": r.rung_bits,
                      "sample": r.tokens[:8], **r.metadata}
                     for r in responses],
        "generated": n_tok,
        "wall_s": round(dt, 3),
        "tok_per_s": round(n_tok / max(dt, 1e-9), 1),
    }
    print("[serve] " + json.dumps(summary))
    return summary


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant", default="none",
                    choices=["none", "ruq", "ruq_unsigned", "pann"])
    ap.add_argument("--power_bits", type=int, default=4,
                    help="power budget expressed as an unsigned-MAC bit width")
    ap.add_argument("--power_ladder", default="",
                    help="comma-separated bit budgets, e.g. 2,4,6 — serve a "
                         "multi-operating-point ladder (repro.serve_engine)")
    ap.add_argument("--allocation", default="uniform",
                    choices=["uniform", "layerwise"],
                    help="ladder rung allocation: one global (b~x, R) per "
                         "rung, or a per-module PolicyTree spending the "
                         "same total power layer-wise "
                         "(planner.allocate_layerwise)")
    ap.add_argument("--backend", default="",
                    choices=["", "ref", "fused", "packed", "fused:force",
                             "packed:force"],
                    help="serving-matmul backend (repro.kernels.dispatch): "
                         "ref (jnp integer oracle), fused (Pallas bit-plane "
                         "MXU matmul), packed (bit-packed planes, 8 "
                         "codes/byte along K); ':force' runs Pallas in "
                         "interpret mode off-TPU. Empty = legacy float "
                         "dequant. With --quant pann (no ladder) the "
                         "weights are materialized as the serving artifact "
                         "and decode runs through the chosen backend.")
    ap.add_argument("--autotune", action="store_true",
                    help="measure-and-cache the best Pallas block shapes "
                         "per projection before warmup (kernels/autotune; "
                         "persistent per-device cache, $REPRO_AUTOTUNE_CACHE "
                         "overrides the location). Off-TPU the VMEM "
                         "heuristic is recorded untimed. Ladder mode only.")
    ap.add_argument("--cache_bits", default="",
                    help="quantize the decode-time KV cache (ladder mode): "
                         "an int in [2,7] pins every rung's cache width; "
                         "'auto' lets each rung pick — uniform rungs cache "
                         "at their own b~x, layerwise rungs let the "
                         "allocator trade cache bits against weight bits "
                         "under one budget. Decode attention then reads the "
                         "packed bit-plane cache directly "
                         "(kernels/pann_attention via --backend, jnp ref "
                         "oracle otherwise). Empty = fp cache.")
    ap.add_argument("--artifact_format", default="views",
                    help="ladder materialization (DESIGN.md §11): 'views' "
                         "(the only format) quantizes once at the "
                         "per-module max budget and serves every rung as a "
                         "zero-copy view over one weight store (HBM flat "
                         "in ladder depth; rung budgets snapped to powers "
                         "of two). The per-rung 'legacy' format was "
                         "retired.")
    ap.add_argument("--encode", action="store_true",
                    help="serve the ENCODER workload (vision/speech "
                         "frontends) instead of decode: whole-sequence "
                         "waves through serve_engine.EncodeEngine, no KV "
                         "cache, per-item power budgets resolved on the "
                         "same ladder. encdec/vlm archs only.")
    ap.add_argument("--budgets", default="",
                    help="per-request power budgets (bits), cycled over the "
                         "request stream; defaults to the ladder itself")
    ap.add_argument("--requests", type=int, default=0,
                    help="number of requests in ladder mode (default: --batch)")
    ap.add_argument("--fleet_hosts", type=int, default=0,
                    help="serve a simulated multi-host fleet with this many "
                         "rung-sharded decode hosts (+1 prefill host) under "
                         "--global_budget (repro.serve_engine.fleet)")
    ap.add_argument("--global_budget", type=float, default=0.25,
                    help="fleet mode: global power cap in Gbit-flips/sec, "
                         "enforced per tick by the fleet governor")
    ap.add_argument("--ticks", type=int, default=12,
                    help="fleet mode: length of the synthetic traffic trace")
    ap.add_argument("--artifact_dir", default="",
                    help="fleet mode: write/reuse the mmap serving artifact "
                         "here (default: a fresh temp dir)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.artifact_format == "legacy":
        raise SystemExit(
            "--artifact_format legacy was retired: the ladder is always "
            "materialized as one weight store with zero-copy rung views "
            "(DESIGN.md §11). Budget-snapping drift is bounded in closed "
            "form by benchmarks/artifact_parity.py; drop the flag.")
    if args.artifact_format != "views":
        raise SystemExit(
            f"unknown --artifact_format {args.artifact_format!r}; "
            "the only format is 'views'")
    if args.encode:
        return serve_encode(args)
    if args.fleet_hosts:
        return serve_fleet(args)
    if args.power_ladder:
        return serve_ladder(args)
    if args.allocation != "uniform":
        # only the ladder path consumes --allocation; refuse rather than
        # silently serve a uniform single point the user didn't ask for
        raise SystemExit(
            "--allocation layerwise requires --power_ladder (the "
            "single-point path has no per-module rungs)")
    if args.cache_bits:
        raise SystemExit(
            "--cache_bits requires --power_ladder (the quantized KV cache "
            "rides in the serve-engine variant cache)")
    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    qc = plan_quant(args,
                    total_macs=costs.macs_per_token(cfg).weight_macs)
    cfg = dataclasses.replace(cfg, quant=qc)

    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.backend:
        # route the single operating point through a kernel backend: weights
        # become the serving artifact (int8 codes; packed plane leaves for
        # 'packed' at the module's value-exact b_R) and every projection in
        # the decode loop below dispatches through repro.kernels.dispatch
        if args.quant != "pann":
            raise SystemExit("--backend serves the PANN deployment artifact;"
                             " combine it with --quant pann (or use "
                             "--power_ladder)")
        params = serving.quantize_params_for_serving(
            params, cfg, spec=serving.ServingQuantSpec(
                r=qc.r, act_bits=qc.act_bits_tilde,
                pack_planes=args.backend.startswith("packed")))
        cfg = dataclasses.replace(cfg, kernel_backend=args.backend)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    kwargs = {}
    fe = frontend_stub(cfg, args.batch, 0, args.seed)
    if fe is not None:
        kwargs["enc_inputs" if cfg.family == "encdec" else
               "image_embeds"] = jnp.asarray(fe)

    max_len = args.prompt_len + args.gen
    state = MD.init_decode_state(params, cfg, args.batch, max_len, **kwargs)
    step = jax.jit(lambda p, s, t: MD.decode_step(p, cfg, s, t))

    # prefill via teacher-forced decode (correct for every cache family)
    t0 = time.monotonic()
    logits = None
    for i in range(args.prompt_len):
        logits, state = step(params, state, prompts[:, i:i + 1])
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0

    # greedy decode
    t0 = time.monotonic()
    tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    for _ in range(args.gen - 1):
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    summary = {
        "arch": cfg.name,
        "quant": qc.mode,
        "backend": args.backend or "legacy",
        "batch": args.batch,
        "generated": int(gen.shape[1]),
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "tok_per_s": round(args.batch * (args.gen - 1) / max(t_decode, 1e-9),
                           1),
        "sample": np.asarray(gen[0, :8]).tolist(),
    }
    print("[serve] " + json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
