import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove the sharding config is coherent, and extract
the roofline terms from the compiled artifact.

MUST be run as its own process (the XLA flag above is set before any jax
initialization):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out benchmarks/results

Per cell it records: per-device bytes (memory_analysis), HLO FLOPs / bytes
(cost_analysis), and collective-op bytes parsed from the post-SPMD HLO —
the inputs to EXPERIMENTS.md §Roofline.
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import (ModelConfig, ParallelConfig, QuantConfig,
                                ShapeConfig, TrainConfig)
from repro.core import costs
from repro.dist import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models import model as MD
from repro.optim import optimizers as OPT

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def parallel_for(cfg: ModelConfig, kind: str = "train") -> ParallelConfig:
    """FSDP when parameters don't fit otherwise.

    Training: fp32 params + Adam state (12 B/param) must fit per data
    shard -> FSDP above ~3B params. Serving: weights are bf16 and only
    TP-sharded (16-way); FSDP would re-gather them EVERY step (measured
    1.9e9 B/device/step on llama3 decode — §Perf iteration 4c), so it is
    enabled only when the TP shard alone exceeds ~8 GB (dbrx, vision-90b).
    """
    if kind == "train":
        return ParallelConfig(fsdp=costs.param_count(cfg) > 3e9,
                              remat="block")
    per_dev = costs.param_count(cfg) * 2 / 16
    return ParallelConfig(fsdp=per_dev > 8e9, remat="none")


# ---------------------------------------------------------------------------
# input_specs: weak-type-correct ShapeDtypeStruct stand-ins, no allocation
# ---------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract model inputs for one cell (train/prefill: full sequences;
    decode: one new token per sequence)."""
    b = shape.global_batch
    t = 1 if shape.kind == "decode" else shape.seq_len
    out = {"tokens": sds((b, t), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = sds((b, t), jnp.int32)
    if cfg.family == "encdec":
        out["enc_inputs"] = sds((b, cfg.encoder_seq_len, cfg.d_model),
                                jnp.float32)
    if cfg.family == "vlm":
        out["image_embeds"] = sds((b, cfg.num_image_tokens, cfg.d_model),
                                  jnp.float32)
    return out


def batch_shardings(batch: dict, mesh) -> dict:
    out = {}
    for k, v in batch.items():
        out[k] = NamedSharding(mesh, SH.input_sharding(mesh, v.shape))
    return out


# ---------------------------------------------------------------------------
# HLO parsing: collective bytes
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_hlo_shape(text: str) -> int:
    """Sum bytes of every array shape in an HLO result-type string
    (handles tuples '(f32[8,4], u32[])')."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax, a one-element
    list of dicts on older releases; normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind output bytes (per device), from post-SPMD HLO."""
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?\S+\s*=\s*(\(?.*?\)?)\s*(" + "|".join(COLLECTIVES)
                     + r")(-start)?\(", line)
        if m:
            out[m.group(2)] += _bytes_of_hlo_shape(m.group(1))
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------

def _jit_train(cfg, shape, mesh, par) -> tuple[Any, tuple, dict]:
    tcfg = TrainConfig()
    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(
        lambda k: ST.make_train_state(k, cfg, tcfg), key)
    pspecs = SH.param_specs(state_shapes.params, mesh, par)
    state_specs = ST.TrainState(
        params=pspecs,
        opt=OPT.AdamWState(mu=pspecs, nu=pspecs, count=P()),
        step=P())
    state_sh = SH.to_named(state_specs, mesh)
    batch = input_specs(cfg, shape)
    batch_sh = batch_shardings(batch, mesh)

    def fn(state, batch):
        return ST.train_step(state, batch, cfg=cfg, tcfg=tcfg, par=par)

    jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    return jitted, (state_shapes, batch), {"state": state_specs}


def _jit_prefill(cfg, shape, mesh, par):
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda k: MD.init_params(k, cfg), key)
    pspecs = SH.param_specs(params_shapes, mesh, par)
    params_sh = SH.to_named(pspecs, mesh)
    batch = input_specs(cfg, shape)
    batch_sh = batch_shardings(batch, mesh)

    def fn(params, batch):
        return ST.prefill_step(params, cfg, batch["tokens"],
                               enc_inputs=batch.get("enc_inputs"),
                               image_embeds=batch.get("image_embeds"))

    jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                     out_shardings=None)
    return jitted, (params_shapes, batch), {"params": pspecs}


def _jit_decode(cfg, shape, mesh, par, serve_quant: bool = False):
    key = jax.random.PRNGKey(0)
    if serve_quant:
        from repro.models.serving import quantize_params_for_serving
        params_shapes = jax.eval_shape(
            lambda k: quantize_params_for_serving(
                MD.init_params(k, cfg), cfg), key)
    else:
        params_shapes = jax.eval_shape(lambda k: MD.init_params(k, cfg), key)
    pspecs = SH.param_specs(params_shapes, mesh, par)
    params_sh = SH.to_named(pspecs, mesh)
    batch = input_specs(cfg, shape)
    b = shape.global_batch

    kwargs = {}
    if "enc_inputs" in batch:
        kwargs["enc_inputs"] = batch["enc_inputs"]
    if "image_embeds" in batch:
        kwargs["image_embeds"] = batch["image_embeds"]
    state_shapes = jax.eval_shape(
        lambda p, **kw: MD.init_decode_state(p, cfg, b, shape.seq_len, **kw),
        params_shapes, **kwargs)
    dspecs = SH.cache_specs(state_shapes, mesh)   # greedy; scalars -> P()
    state_sh = SH.to_named(dspecs, mesh)
    tokens_sh = NamedSharding(mesh, SH.input_sharding(
        mesh, batch["tokens"].shape))

    def fn(params, state, tokens):
        return ST.serve_step(params, cfg, state, tokens)

    jitted = jax.jit(fn, in_shardings=(params_sh, state_sh, tokens_sh),
                     out_shardings=(None, state_sh), donate_argnums=(1,))
    return jitted, (params_shapes, state_shapes, batch["tokens"]), \
        {"params": pspecs, "state": dspecs}


# ---------------------------------------------------------------------------
# FLOPs probes: XLA's cost_analysis counts while-loop bodies ONCE, so the
# full (scanned) program under-reports FLOPs/bytes. We lower shallow UNROLLED
# variants (1 group, 2 groups, [+tail]) and extrapolate linearly:
#     total = overhead + n_groups * delta (+ tail)
# Inner attention/MoE scans are unrolled in probe mode (cfg.unroll_loops);
# the remaining per-token recurrences (RWKV wkv update, SSD state passing)
# are O(d*hd) per token vs O(d^2) projections — <2% and noted in
# EXPERIMENTS.md.
# ---------------------------------------------------------------------------

def _probe_metrics(cfg, shape, mesh, par, n_layers, enc_layers=None,
                   serve_quant: bool = False) -> dict:
    import dataclasses as dc
    pcfg = dc.replace(cfg, num_layers=n_layers, unroll_loops=True,
                      **({"encoder_layers": enc_layers}
                         if enc_layers is not None else {}))
    with mesh:
        if shape.kind == "train":
            jitted, args, _ = _jit_train(pcfg, shape, mesh, par)
        elif shape.kind == "prefill":
            jitted, args, _ = _jit_prefill(pcfg, shape, mesh, par)
        else:
            jitted, args, _ = _jit_decode(pcfg, shape, mesh, par,
                                          serve_quant=serve_quant)
        compiled = jitted.lower(*args).compile()
    cost = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["total"]}


def probe_extrapolate(cfg, shape, mesh, par, serve_quant: bool = False
                      ) -> dict:
    """Per-device FLOPs/bytes/collective-bytes with scan trip counts folded
    back in via shallow unrolled probes."""
    from repro.models.transformer import group_layout
    pattern, n_groups, n_tail = group_layout(cfg)
    plen = len(pattern)
    if cfg.family == "encdec":
        p1 = _probe_metrics(cfg, shape, mesh, par, plen, enc_layers=1,
                            serve_quant=serve_quant)
        p2 = _probe_metrics(cfg, shape, mesh, par, 2 * plen, enc_layers=1,
                            serve_quant=serve_quant)
        pe = _probe_metrics(cfg, shape, mesh, par, plen, enc_layers=2,
                            serve_quant=serve_quant)
        out = {}
        for k in ("flops", "bytes", "coll"):
            d_dec = p2[k] - p1[k]
            d_enc = pe[k] - p1[k]
            overhead = p1[k] - d_dec - d_enc
            out[k] = overhead + n_groups * d_dec \
                + cfg.encoder_layers * d_enc
        return out
    p1 = _probe_metrics(cfg, shape, mesh, par, plen, serve_quant=serve_quant)
    p2 = _probe_metrics(cfg, shape, mesh, par, 2 * plen,
                        serve_quant=serve_quant)
    probes = {"p1": p1, "p2": p2}
    if n_tail:
        probes["pt"] = _probe_metrics(cfg, shape, mesh, par, plen + n_tail,
                                      serve_quant=serve_quant)
    out = {}
    for k in ("flops", "bytes", "coll"):
        delta = p2[k] - p1[k]
        overhead = p1[k] - delta
        tail = (probes["pt"][k] - p1[k]) if n_tail else 0.0
        out[k] = overhead + n_groups * delta + tail
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant_mode: str = "none", verbose: bool = True,
             probe: bool = True,
             extra_parallel: Optional[dict] = None,
             reduced: bool = False) -> dict:
    """Lower + compile one cell; return the roofline record.

    ``reduced`` swaps in the tiny same-family config so the full 512-device
    lower+compile pipeline can be smoke-tested on a CPU container (the mesh,
    sharding rules, and HLO parsing are identical — only widths shrink).
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = configs.SHAPES_BY_NAME[shape_name]
    serve_quant = quant_mode == "pann_serve"
    qc = QuantConfig(mode="none" if serve_quant else quant_mode,
                     qat=(shape.kind == "train"))
    cfg = configs.get_config(arch, dtype="bfloat16", quant=qc)
    # parallel strategy comes from the FULL config so reduced smoke runs
    # compile the same (FSDP or not) sharding path as the real cell
    par = parallel_for(cfg, shape.kind)
    if reduced:
        cfg = configs.reduced(cfg)
    if extra_parallel:
        extra = dict(extra_parallel)
        moe_impl = extra.pop("moe_impl", None)
        kv_dtype = extra.pop("kv_cache_dtype", None)
        if extra:
            par = dataclasses.replace(par, **extra)
        if moe_impl:
            cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
        if kv_dtype:
            cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)

    if shape.name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": "pure full attention (DESIGN.md §5)"}

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            jitted, args, _ = _jit_train(cfg, shape, mesh, par)
            lowered = jitted.lower(*args)
        elif shape.kind == "prefill":
            jitted, args, _ = _jit_prefill(cfg, shape, mesh, par)
            lowered = jitted.lower(*args)
        else:
            jitted, args, _ = _jit_decode(cfg, shape, mesh, par,
                                          serve_quant=serve_quant)
            lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = cost_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.size

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "quant": quant_mode,
        "fsdp": par.fsdp,
        "compile_s": round(t1 - t0, 1),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_per_device": coll,
        "model_flops_global": costs.model_flops(cfg, shape),
        "params": costs.param_count(cfg),
        "params_active": costs.param_count(cfg, active_only=True),
    }
    if mem is not None:
        # NOTE: on the CPU backend memory_analysis reports whole-program
        # (all-device) totals; per-device = value / n_devices.
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                record[k] = int(v)

    if probe:
        try:
            ext = probe_extrapolate(cfg, shape, mesh, par,
                                    serve_quant=serve_quant)
            record["flops_per_device_corrected"] = ext["flops"]
            record["bytes_per_device_corrected"] = ext["bytes"]
            record["collective_bytes_corrected"] = ext["coll"]
        except Exception as e:  # noqa: BLE001
            record["probe_error"] = repr(e)[:300]
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}: compiled in "
              f"{record['compile_s']}s")
        print(f"  memory_analysis: "
              f"temp={record.get('temp_size_in_bytes', 'n/a')} "
              f"args={record.get('argument_size_in_bytes', 'n/a')} "
              f"out={record.get('output_size_in_bytes', 'n/a')}")
        print(f"  cost_analysis: flops/dev={record['flops_per_device']:.3e} "
              f"bytes/dev={record['bytes_per_device']:.3e}")
        print(f"  collectives/dev: " + ", ".join(
            f"{k}={v:.3e}" for k, v in coll.items() if v))
    return record


ALL_CELLS = [(a, s.name) for a in configs.ARCH_NAMES for s in configs.SHAPES]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--quant", default="none",
                    choices=["none", "ruq", "ruq_unsigned", "pann",
                             "pann_serve"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family configs (CPU smoke of the full "
                         "512-device lower/compile path)")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the unrolled FLOPs extrapolation probes")
    ap.add_argument("--retry-failed-probes", action="store_true",
                    help="re-run cells whose record carries probe_error "
                         "(by default they count as done to avoid "
                         "recompiling deterministic failures every run)")
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = ALL_CELLS
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    tag = args.mesh + ("" if args.quant == "none" else f"_{args.quant}") \
        + ("_reduced" if args.reduced else "")
    path = os.path.join(args.out, f"dryrun_{tag}.json")

    # resumable: skip cells already recorded, write after every cell. A cell
    # only counts as done if it already has what THIS invocation would add:
    # when probing is requested, a record lowered without probe data is
    # re-run (so a --no-probe fast pass can be upgraded later). Stale
    # records are only replaced once their re-run SUCCEEDS — a crash or
    # failure mid-upgrade never destroys previously recorded results.
    def cell_complete(r) -> bool:
        if "skipped" in r:
            return True
        if r.get("mesh", "single") == "single" and not args.no_probe:
            return ("flops_per_device_corrected" in r
                    or ("probe_error" in r
                        and not args.retry_failed_probes))
        return True

    def rec_key(r):
        return (r["arch"], r["shape"], r.get("mesh", "single"))

    records, failures = [], []
    if os.path.exists(path):
        with open(path) as f:
            prev = json.load(f)
        records = prev.get("records", [])
        print(f"[dryrun] resuming: {len(records)} records already present")
    done = {rec_key(r) for r in records if cell_complete(r)}

    def flush():
        # atomic: a crash mid-write must never corrupt the resume file
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"records": records, "failures": failures}, f,
                      indent=1)
        os.replace(tmp, path)

    for arch, shape in cells:
        for mp in meshes:
            key = (arch, shape, "multi" if mp else "single")
            if key in done:
                continue
            try:
                # FLOPs probes feed the single-pod roofline table only
                rec = run_cell(arch, shape, mp, args.quant,
                               probe=not mp and not args.no_probe,
                               reduced=args.reduced)
                records[:] = [r for r in records if rec_key(r) != key]
                records.append(rec)
                done.add(key)
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures.append((arch, shape, mp, repr(e)[:400]))
                print(f"[dryrun][FAIL] {arch} x {shape} x "
                      f"{'multi' if mp else 'single'}: {e!r}")
            flush()

    print(f"[dryrun] wrote {path}: {len(records)} records, "
          f"{len(failures)} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
