"""The jittable train / prefill / decode step functions shared by the real
trainer, the server, and the multi-pod dry-run."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import model as MD
from repro.optim import optimizers as OPT


class TrainState(NamedTuple):
    params: Any
    opt: OPT.AdamWState
    step: jax.Array


def make_train_state(key, cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    params = MD.init_params(key, cfg)
    opt = OPT.AdamW(tcfg).init(params)
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32))


def train_step(state: TrainState, batch: dict, *, cfg: ModelConfig,
               tcfg: TrainConfig, par: ParallelConfig
               ) -> tuple[TrainState, dict]:
    """One optimizer step (data-parallel mean over the global batch is
    implicit in the batch-sharded loss; GSPMD inserts the reduce)."""
    remat = par.remat != "none"

    def loss_fn(params):
        return MD.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                          enc_inputs=batch.get("enc_inputs"),
                          image_embeds=batch.get("image_embeds"),
                          remat=remat)

    if par.microbatches > 1:
        b = batch["tokens"].shape[0]
        assert b % par.microbatches == 0
        mb = b // par.microbatches

        def micro_loss(params, i):
            sl = {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=0)
                  for k, v in batch.items() if v is not None}
            return MD.lm_loss(params, cfg, sl["tokens"], sl["labels"],
                              enc_inputs=sl.get("enc_inputs"),
                              image_embeds=sl.get("image_embeds"),
                              remat=remat)

        def loss_and_grad(params):
            def body(acc, i):
                l, g = jax.value_and_grad(micro_loss)(params, i)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            zero = (jnp.zeros(()),
                    jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (l, g), _ = jax.lax.scan(body, zero,
                                     jnp.arange(par.microbatches))
            n = float(par.microbatches)
            return l / n, jax.tree_util.tree_map(lambda t: t / n, g)

        loss, grads = loss_and_grad(state.params)
    else:
        loss, grads = jax.value_and_grad(loss_fn)(state.params)

    new_params, new_opt, metrics = OPT.AdamW(tcfg).update(
        grads, state.opt, state.params)
    metrics = {"loss": loss, **metrics}
    return TrainState(new_params, new_opt, state.step + 1), metrics


def prefill_step(params, cfg: ModelConfig, tokens, *, enc_inputs=None,
                 image_embeds=None):
    out = MD.forward(params, cfg, tokens, enc_inputs=enc_inputs,
                     image_embeds=image_embeds, remat=False)
    return out.logits


def serve_step(params, cfg: ModelConfig, state: MD.DecodeState, tokens):
    """One decode tick: (B, 1) tokens -> (B, 1, V) logits + new caches."""
    return MD.decode_step(params, cfg, state, tokens)
