"""The jittable train / prefill / decode step functions shared by the real
trainer, the server, and the multi-pod dry-run."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.core import calibrate as CAL
from repro.models import model as MD
from repro.optim import optimizers as OPT


class TrainState(NamedTuple):
    params: Any
    opt: OPT.AdamWState
    step: jax.Array
    # EMA activation-range collection ({path: [lo, hi]}, core/calibrate.py)
    # for power-aware QAT; None when calibration is off. Checkpointed with
    # the rest of the state so a mid-anneal resume is bit-exact.
    calib: Any = None


def make_train_state(key, cfg: ModelConfig, tcfg: TrainConfig,
                     *, calibrate: bool = False) -> TrainState:
    params = MD.init_params(key, cfg)
    opt = OPT.AdamW(tcfg).init(params)
    calib = CAL.init_calib(cfg) if calibrate else None
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), calib=calib)


def train_step(state: TrainState, batch: dict, *, cfg: ModelConfig,
               tcfg: TrainConfig, par: ParallelConfig
               ) -> tuple[TrainState, dict]:
    """One optimizer step (data-parallel mean over the global batch is
    implicit in the batch-sharded loss; GSPMD inserts the reduce).

    With a calibration collection on the state, the forward quantizes
    activations against the EMA ranges and reports this batch's observed
    ranges, which fold back into the collection (``calibrate.ema_update``)
    — quant/calibration state is part of the train state proper, so it is
    donated, sharded, and checkpointed like params and optimizer moments.
    """
    remat = par.remat != "none"
    calib = state.calib
    collect = calib is not None

    def loss_fn(params):
        loss, obs = MD.lm_loss(params, cfg, batch["tokens"],
                               batch["labels"],
                               enc_inputs=batch.get("enc_inputs"),
                               image_embeds=batch.get("image_embeds"),
                               remat=remat, calib=calib, return_calib=True)
        return loss, obs

    if par.microbatches > 1:
        b = batch["tokens"].shape[0]
        assert b % par.microbatches == 0
        mb = b // par.microbatches

        def micro_loss(params, i):
            sl = {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=0)
                  for k, v in batch.items() if v is not None}
            return MD.lm_loss(params, cfg, sl["tokens"], sl["labels"],
                              enc_inputs=sl.get("enc_inputs"),
                              image_embeds=sl.get("image_embeds"),
                              remat=remat, calib=calib, return_calib=True)

        def loss_and_grad(params):
            def body(acc, i):
                (l, obs), g = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, i)
                acc_l, acc_g, acc_obs = acc
                merged = CAL.merge(acc_obs, obs) if collect else None
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g),
                        merged), None

            zero = (jnp.zeros(()),
                    jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params),
                    CAL.unseen_like(calib) if collect else None)
            (l, g, obs), _ = jax.lax.scan(body, zero,
                                          jnp.arange(par.microbatches))
            n = float(par.microbatches)
            return l / n, jax.tree_util.tree_map(lambda t: t / n, g), obs

        loss, grads, observed = loss_and_grad(state.params)
    else:
        (loss, observed), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)

    new_params, new_opt, metrics = OPT.AdamW(tcfg).update(
        grads, state.opt, state.params)
    new_calib = CAL.ema_update(calib, observed, tcfg.calib_decay) \
        if collect else None
    metrics = {"loss": loss, **metrics}
    return TrainState(new_params, new_opt, state.step + 1, new_calib), \
        metrics


def eval_loss(params: Any, cfg: ModelConfig, batch: dict,
              calib: Optional[dict] = None) -> float:
    """Deterministic eval loss of ``params`` on one batch — the number the
    train→serve export round-trip is asserted against (launch/export.py).
    ``calib`` freezes activation quantizers to the EMA ranges, matching
    what the export bakes into the serving artifact. Works on training
    params (fake-quant forward) and on serving artifacts ("w_q" trees)
    alike, since both route through ``layers.apply_linear``.
    """
    @jax.jit
    def f(params, batch, calib):
        return MD.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                          enc_inputs=batch.get("enc_inputs"),
                          image_embeds=batch.get("image_embeds"),
                          remat=False, calib=calib)

    return float(f(params, batch, calib))


def prefill_step(params, cfg: ModelConfig, tokens, *, enc_inputs=None,
                 image_embeds=None):
    out = MD.forward(params, cfg, tokens, enc_inputs=enc_inputs,
                     image_embeds=image_embeds, remat=False)
    return out.logits


def serve_step(params, cfg: ModelConfig, state: MD.DecodeState, tokens):
    """One decode tick: (B, 1) tokens -> (B, 1, V) logits + new caches."""
    return MD.decode_step(params, cfg, state, tokens)
