"""End-to-end training driver (CLI).

Runs on whatever devices exist (1 CPU here; a pod slice in production):
deterministic synthetic data, AdamW, checkpoint/restart via the Supervisor,
straggler telemetry, optional PANN QAT, optional pipeline parallelism.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 200 --quant pann --r 2.0
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import ParallelConfig, QuantConfig, TrainConfig
from repro.ckpt import checkpoint as ck
from repro.data.pipeline import SyntheticLM, frontend_stub
from repro.dist import sharding as SH
from repro.dist.fault import StepMonitor
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh


def build(args):
    qc = QuantConfig(mode=args.quant, r=args.r,
                     act_bits_tilde=args.act_bits, act_bits=args.act_bits,
                     weight_bits=args.weight_bits, qat=args.quant != "none")
    cfg = configs.get_config(args.arch, quant=qc)
    if args.reduced:
        cfg = configs.reduced(cfg)
        cfg = dataclasses.replace(cfg, quant=qc)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model,
                                  d_ff=args.d_ff or 4 * args.d_model,
                                  num_layers=args.layers or cfg.num_layers)
    horizon = args.total_steps or args.steps
    tcfg = TrainConfig(lr=args.lr, total_steps=horizon,
                       warmup_steps=max(horizon // 20, 5), seed=args.seed)
    par = ParallelConfig(fsdp=False, remat="block" if args.remat else "none",
                         microbatches=args.microbatches)
    return cfg, tcfg, par


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d_model", type=int, default=0)
    ap.add_argument("--d_ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100,
                    help="steps to run in THIS invocation")
    ap.add_argument("--total_steps", type=int, default=0,
                    help="LR-schedule horizon (defaults to --steps); set it "
                         "when resuming so the schedule stays consistent")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant", default="none",
                    choices=["none", "ruq", "ruq_unsigned", "pann"])
    ap.add_argument("--r", type=float, default=2.0)
    ap.add_argument("--act_bits", type=int, default=8)
    ap.add_argument("--weight_bits", type=int, default=8)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model_axis", type=int, default=1)
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--log_every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, tcfg, par = build(args)
    mesh = make_local_mesh(args.model_axis)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)

    pspec_fn = lambda tree: SH.param_specs(tree, mesh, par)
    key = jax.random.PRNGKey(args.seed)

    with mesh:
        state = ST.make_train_state(key, cfg, tcfg)
        pspecs = pspec_fn(jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params))
        from repro.optim.optimizers import AdamWState
        state_specs = ST.TrainState(
            params=pspecs, opt=AdamWState(mu=pspecs, nu=pspecs, count=P()),
            step=P())
        state_sh = SH.to_named(state_specs, mesh)
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, state_sh)

        step_fn = jax.jit(
            partial(ST.train_step, cfg=cfg, tcfg=tcfg, par=par),
            in_shardings=(state_sh, None), out_shardings=(state_sh, None),
            donate_argnums=(0,))

        monitor = StepMonitor()
        start_step = 0
        if args.ckpt_dir:
            last = ck.latest_step(args.ckpt_dir)
            if last is not None:
                tmpl = jax.tree_util.tree_map(np.asarray, state)
                state = ck.restore(args.ckpt_dir, last, tmpl, state_sh)
                start_step = last
                print(f"[train] resumed from step {last}")

        losses = []
        for step in range(start_step, args.steps):
            batch = {"tokens": None, "labels": None}
            host = data.global_batch_arrays(step)
            batch = {k: jnp.asarray(v) for k, v in host.items()}
            fe = frontend_stub(cfg, args.batch, step, args.seed)
            if fe is not None:
                key_name = ("enc_inputs" if cfg.family == "encdec"
                            else "image_embeds")
                batch[key_name] = jnp.asarray(fe)
            t0 = time.monotonic()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            monitor.record(step, time.monotonic() - t0)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ck.save(args.ckpt_dir, step + 1, state,
                        meta={"arch": cfg.name, "loss": loss})

        if args.ckpt_dir:
            ck.save(args.ckpt_dir, args.steps, state,
                    meta={"arch": cfg.name, "loss": losses[-1]})
    summary = {"first_loss": losses[0], "last_loss": losses[-1],
               "steps": args.steps, **monitor.summary()}
    print("[train] " + json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
