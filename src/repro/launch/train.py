"""End-to-end training driver (CLI).

Runs on whatever devices exist (1 CPU here; a pod slice in production):
deterministic synthetic data, AdamW, checkpoint/restart via the Supervisor,
straggler telemetry, power-aware QAT with budget annealing, optional
pipeline parallelism.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 200 --quant pann --r 2.0

Power-aware QAT (DESIGN.md §9): ``--train_quant`` picks how quantization
meets training — ``none`` (fp), ``ptq`` (train fp, quantize only at
export/serve time), ``qat`` (STE fake-quant in the train step, activation
ranges EMA-calibrated into the train state). ``--budget_schedule`` anneals
the bit-flip budget through the run, re-running the layer-wise allocator at
every knot:

    python -m repro.launch.train --arch llama3-8b --reduced --steps 200 \
        --quant pann --train_quant qat --budget_schedule 0:fp,40:8,120:6 \
        --ckpt_dir /tmp/ck
    python -m repro.launch.export --ckpt_dir /tmp/ck --out /tmp/artifact
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import ParallelConfig, QuantConfig, TrainConfig
from repro.ckpt import checkpoint as ck
from repro.core import anneal
from repro.core import calibrate as CAL
from repro.data.pipeline import SyntheticLM, frontend_stub
from repro.dist import sharding as SH
from repro.dist.fault import StepMonitor
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh

# held-out eval stream: same generator family as training, disjoint seed
EVAL_SEED_OFFSET = 1


def resolve_train_quant(args) -> str:
    """The explicit tri-state replacing the old ``args.quant != "none"``
    string-compare: none (fp training) | ptq (train fp, quantize at
    export) | qat (fake-quant in the train step). Unset derives the
    legacy behavior: qat whenever a quant mode is configured."""
    tq = args.train_quant or ("qat" if args.quant != "none" else "none")
    if tq != "none" and args.quant == "none":
        raise ValueError(
            f"--train_quant {tq} needs a quantization scheme; pass "
            f"--quant pann (or ruq/ruq_unsigned)")
    if tq == "none" and args.quant != "none":
        raise ValueError(
            f"--quant {args.quant} with --train_quant none is ambiguous: "
            f"use ptq (train fp, quantize at export) or qat")
    if args.budget_schedule:
        if tq != "qat":
            raise ValueError("--budget_schedule anneals QAT operating "
                             "points; requires --train_quant qat")
        if args.quant != "pann":
            raise ValueError("--budget_schedule plans PANN (b~x, R) "
                             "points; requires --quant pann")
    return tq


def build(args):
    tq = resolve_train_quant(args)
    qc = QuantConfig(mode=args.quant, r=args.r,
                     act_bits_tilde=args.act_bits, act_bits=args.act_bits,
                     weight_bits=args.weight_bits, qat=tq == "qat")
    cfg = configs.get_config(args.arch, quant=qc)
    if args.reduced:
        cfg = configs.reduced(cfg)
        cfg = dataclasses.replace(cfg, quant=qc)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model,
                                  d_ff=args.d_ff or 4 * args.d_model,
                                  num_layers=args.layers or cfg.num_layers)
    horizon = args.total_steps or args.steps
    schedule = anneal.BudgetSchedule.parse(args.budget_schedule) \
        if args.budget_schedule else None
    tcfg = TrainConfig(lr=args.lr, total_steps=horizon,
                       warmup_steps=max(horizon // 20, 5), seed=args.seed,
                       budget_schedule=args.budget_schedule or None,
                       budget_allocation=args.allocation,
                       calib_decay=args.calib_decay,
                       anneal_warmup_steps=args.anneal_warmup,
                       lr_rewarmup_knots=schedule.knot_steps()
                       if schedule and args.anneal_warmup else ())
    par = ParallelConfig(fsdp=False, remat="block" if args.remat else "none",
                         microbatches=args.microbatches)
    return cfg, tcfg, par


TRAIN_ARG_KEYS = (
    "arch", "reduced", "d_model", "d_ff", "layers", "steps", "total_steps",
    "batch", "seq", "lr", "seed", "quant", "train_quant", "r", "act_bits",
    "weight_bits", "budget_schedule", "allocation", "calib_decay",
    "anneal_warmup", "remat", "microbatches",
)


def make_eval_batch(cfg, args) -> dict:
    """The deterministic held-out batch both the trainer and the exporter
    evaluate on (seed offset keeps it off the training stream)."""
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch,
                      seed=args.seed + EVAL_SEED_OFFSET)
    batch = {k: jnp.asarray(v)
             for k, v in data.global_batch_arrays(0).items()}
    fe = frontend_stub(cfg, args.batch, 0, args.seed + EVAL_SEED_OFFSET)
    if fe is not None:
        key_name = ("enc_inputs" if cfg.family == "encdec"
                    else "image_embeds")
        batch[key_name] = jnp.asarray(fe)
    return batch


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d_model", type=int, default=0)
    ap.add_argument("--d_ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100,
                    help="steps to run in THIS invocation")
    ap.add_argument("--total_steps", type=int, default=0,
                    help="LR-schedule horizon (defaults to --steps); set it "
                         "when resuming so the schedule stays consistent")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant", default="none",
                    choices=["none", "ruq", "ruq_unsigned", "pann"])
    ap.add_argument("--train_quant", default="",
                    choices=["", "none", "ptq", "qat"],
                    help="none: fp training | ptq: train fp, quantize at "
                         "export | qat: STE fake-quant + EMA activation "
                         "calibration in the train step (default: qat "
                         "when --quant is set)")
    ap.add_argument("--r", type=float, default=2.0)
    ap.add_argument("--act_bits", type=int, default=8)
    ap.add_argument("--weight_bits", type=int, default=8)
    ap.add_argument("--budget_schedule", default="",
                    help="power-annealing knots 'step:bits,...' (bits = "
                         "unsigned-MAC budget, 'fp' = unquantized), e.g. "
                         "'0:fp,40:8,120:6'; replans the layer-wise "
                         "allocator at every knot (core/anneal.py)")
    ap.add_argument("--allocation", default="layerwise",
                    choices=["uniform", "layerwise"],
                    help="how annealed budgets are spent across modules")
    ap.add_argument("--calib_decay", type=float, default=0.99)
    ap.add_argument("--anneal_warmup", type=int, default=0,
                    help="LR re-warmup ramp (steps) after each budget knot")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model_axis", type=int, default=1)
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--log_every", type=int, default=10)
    args = ap.parse_args(argv)

    try:
        cfg, tcfg, par = build(args)
    except ValueError as e:
        raise SystemExit(f"[train] {e}")
    train_quant = resolve_train_quant(args)
    qat = train_quant == "qat"
    annealer = anneal.BudgetAnnealer.from_train_config(cfg, tcfg)
    if annealer is not None:
        print(f"[train] budget schedule {annealer.schedule.describe()} "
              f"({tcfg.budget_allocation} allocation)")

    mesh = make_local_mesh(args.model_axis)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)

    pspec_fn = lambda tree: SH.param_specs(tree, mesh, par)
    key = jax.random.PRNGKey(args.seed)

    def cfg_for_step(step):
        """The (config, plan, bits) governing ``step``: annealed when a
        schedule is set; stripped of quantization for fp/ptq training."""
        if annealer is not None:
            return annealer.config_at(cfg, step)
        if not qat:
            return anneal.strip_quant(cfg), None, None
        return cfg, None, None

    meta_args = {k: getattr(args, k) for k in TRAIN_ARG_KEYS}

    with mesh:
        state = ST.make_train_state(key, cfg, tcfg, calibrate=qat)
        pspecs = pspec_fn(jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params))
        from repro.optim.optimizers import AdamWState
        calib_specs = jax.tree_util.tree_map(lambda _: P(), state.calib)
        state_specs = ST.TrainState(
            params=pspecs, opt=AdamWState(mu=pspecs, nu=pspecs, count=P()),
            step=P(), calib=calib_specs)
        state_sh = SH.to_named(state_specs, mesh)
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, state_sh)

        monitor = StepMonitor()
        start_step = 0
        if args.ckpt_dir:
            last = ck.latest_step(args.ckpt_dir)
            if last is not None:
                tmpl = jax.tree_util.tree_map(np.asarray, state)
                state = ck.restore(args.ckpt_dir, last, tmpl, state_sh,
                                   strict=("calib/",))
                start_step = last
                print(f"[train] resumed from step {last}")
                if start_step >= args.steps:
                    raise SystemExit(
                        f"[train] checkpoint is already at step "
                        f"{start_step} >= --steps {args.steps}; raise "
                        f"--steps to continue or point --ckpt_dir at a "
                        f"fresh directory")

        segments = annealer.schedule.segments(start_step, args.steps) \
            if annealer is not None else ((start_step, args.steps, None),)

        losses = []
        plans_meta = []
        for seg_start, seg_end, seg_bits in segments:
            cfg_seg, plan, bits = cfg_for_step(seg_start)
            if annealer is not None:
                gbf = annealer.gbitflips_per_token(bits)
                label = "fp" if not bits else f"{bits}b"
                print(f"[train] segment [{seg_start}, {seg_end}): "
                      f"budget {label}, planned "
                      f"{gbf:.3f} Gbit-flips/token")
                if plan is not None:
                    print("[train] " + plan.describe())
                plans_meta.append({"step": seg_start, "bits": bits or 0,
                                   "gbitflips_per_token": gbf,
                                   "allocation": tcfg.budget_allocation})
            step_fn = jax.jit(
                partial(ST.train_step, cfg=cfg_seg, tcfg=tcfg, par=par),
                in_shardings=(state_sh, None), out_shardings=(state_sh, None),
                donate_argnums=(0,))

            for step in range(seg_start, seg_end):
                host = data.global_batch_arrays(step)
                batch = {k: jnp.asarray(v) for k, v in host.items()}
                fe = frontend_stub(cfg, args.batch, step, args.seed)
                if fe is not None:
                    key_name = ("enc_inputs" if cfg.family == "encdec"
                                else "image_embeds")
                    batch[key_name] = jnp.asarray(fe)
                t0 = time.monotonic()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                monitor.record(step, time.monotonic() - t0)
                losses.append(loss)
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(f"[train] step {step:5d} loss {loss:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.3f}")
                if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                    ck.save(args.ckpt_dir, step + 1, state,
                            meta={"arch": cfg.name, "loss": loss,
                                  "train_args": meta_args})

        # deterministic held-out eval at the final operating point — the
        # number launch/export.py must reproduce from the serving artifact
        cfg_final, _, final_bits = cfg_for_step(max(args.steps - 1, 0))
        eval_l = ST.eval_loss(state.params, cfg_final,
                              make_eval_batch(cfg, args),
                              calib=state.calib)
        print(f"[train] eval loss {eval_l:.6f} (held-out batch, final "
              f"operating point)")
        if qat:
            host_calib = jax.tree_util.tree_map(np.asarray, state.calib)
            print("[train] " + CAL.describe(host_calib))

        if args.ckpt_dir:
            ck.save(args.ckpt_dir, args.steps, state,
                    meta={"arch": cfg.name, "loss": losses[-1],
                          "eval_loss": eval_l,
                          "final_bits": final_bits or 0,
                          "train_args": meta_args})
    summary = {"first_loss": losses[0], "last_loss": losses[-1],
               "steps": args.steps, "eval_loss": eval_l,
               "losses": [round(v, 6) for v in losses],
               "plans": plans_meta, **monitor.summary()}
    print("[train] " + json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
