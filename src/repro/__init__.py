"""repro: PANN (power-aware neural networks) as a production JAX framework."""
__version__ = "0.1.0"
