"""Deterministic synthetic data pipeline, shard-aware and replayable.

Every batch is a pure function of (seed, step), so after a node failure the
pipeline replays exactly from the restored step — no data-loss bookkeeping.
On a real cluster each host materializes only its addressable shard
(``host_local_batch``); on this single-process container ``global_batch``
returns fully-addressable arrays placed with the right sharding.

The synthetic stream is a Zipf-ish token distribution with a deterministic
structure (short "grammar" of bigram cycles) so small models actually have
something learnable — losses must visibly decrease in the examples/tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8   # P(next token follows the bigram cycle)

    def _rng(self, step: int, shard: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))

    def host_local_batch(self, step: int, shard: int, num_shards: int
                         ) -> dict[str, np.ndarray]:
        """The (batch/num_shards) slice owned by ``shard``."""
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = self._rng(step, shard)
        v = self.vocab_size
        t = self.seq_len
        # bigram cycle: next = (5 * cur + 1) % v, with noise
        start = rng.integers(0, v, size=(b, 1))
        noise = rng.integers(0, v, size=(b, t))
        follow = rng.random((b, t)) < self.structure
        toks = np.empty((b, t), np.int32)
        cur = start[:, 0]
        for i in range(t):
            nxt = (5 * cur + 1) % v
            cur = np.where(follow[:, i], nxt, noise[:, i]).astype(np.int64)
            toks[:, i] = cur
        labels = np.concatenate([toks[:, 1:], toks[:, :1] * 0 - 1], axis=1)
        return {"tokens": toks, "labels": labels.astype(np.int32)}

    def global_batch_arrays(self, step: int) -> dict[str, np.ndarray]:
        return self.host_local_batch(step, 0, 1)

    def device_batch(self, step: int, shardings: Optional[dict] = None
                     ) -> dict[str, jax.Array]:
        host = self.global_batch_arrays(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(jnp.asarray(v), shardings[k])
                for k, v in host.items()}


def make_lm_data(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0
                 ) -> SyntheticLM:
    return SyntheticLM(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                       global_batch=shape.global_batch, seed=seed)


def frontend_stub(cfg: ModelConfig, batch: int, step: int, seed: int = 0
                  ) -> Optional[np.ndarray]:
    """Precomputed modality-frontend embeddings (audio frames / image
    patches) — the stub mandated by the assignment for [audio]/[vlm]."""
    if cfg.family == "encdec":
        n = cfg.encoder_seq_len
    elif cfg.family == "vlm":
        n = cfg.num_image_tokens
    else:
        return None
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 77]))
    return rng.standard_normal((batch, n, cfg.d_model)).astype(np.float32)


def frontend_raw_stub(cfg: ModelConfig, batch: int, step: int, seed: int = 0
                      ) -> Optional[np.ndarray]:
    """Deterministic RAW frontend input for configs with a real conv stem:
    (B, H, W, 3) pixels in [0, 1) for vision, (B, frames, 1, mels) standard-
    normal fbank features for speech — fed to ``models.model.encode`` (or
    ``forward``, which routes 4-D input through the stem). None when the
    config has no stem (use ``frontend_stub`` embeddings instead)."""
    if not cfg.conv_stem:
        return None
    h, w = cfg.frontend_hw
    c = cfg.conv_stem[0].c_in
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 78]))
    if cfg.family == "vlm":
        return rng.random((batch, h, w, c)).astype(np.float32)
    return rng.standard_normal((batch, h, w, c)).astype(np.float32)
