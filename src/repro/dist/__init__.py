"""``repro.dist`` — the distribution substrate.

Everything the model/launch layers need to run the same program on one CPU
device or a 512-chip ("pod", "data", "model") mesh:

  constrain    mesh-aware ``with_sharding_constraint`` wrappers that no-op
               cleanly when no mesh is active (single-device smoke tests)
  sharding     greedy PartitionSpec assignment for params / caches / inputs
  collectives  compressed (int8 + error feedback) gradient all-reduce
  pipeline     GPipe-style microbatch pipelining over a mesh axis
  moe_ep       expert-parallel capacity routing for MoE layers
  fault        straggler telemetry + checkpoint/restart supervision

Module layout and invariants are documented in DESIGN.md §3.
"""
from repro.dist import compat as _compat

_compat.install()
