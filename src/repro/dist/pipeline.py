"""GPipe-style microbatch pipelining over a mesh axis.

``pipeline_stack`` splits a stacked group of layers over the devices of one
mesh axis (each device owns ``n_groups / n_stages`` consecutive groups) and
streams microbatches through the stages with ``ppermute``. The schedule is
the classic GPipe diagonal: at step ``t`` stage ``s`` processes microbatch
``t - s``; the ``n_stages - 1`` bubble steps compute on garbage that is never
written to the output, which keeps the loop straight-line and fully
differentiable (the backward pass is the reverse diagonal, derived by AD).
"""
from __future__ import annotations

from typing import Callable, Hashable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.compat import shard_map


def pipeline_stack(block: Callable, ws: jax.Array, x: jax.Array, *,
                   mesh: Mesh, axis: Hashable, n_micro: int) -> jax.Array:
    """Run ``block(stage_weights, h)`` as a pipeline over ``mesh[axis]``.

    ws: (n_groups, ...) stacked per-group weights, consumed in order.
    x:  (batch, ...) activations; batch is split into ``n_micro`` microbatches.
    Equivalent to folding ``block`` over all groups sequentially.
    """
    n_stages = dict(mesh.shape)[axis]
    n_groups = ws.shape[0]
    if n_groups % n_stages:
        raise ValueError(f"{n_groups} groups not divisible by "
                         f"{n_stages} pipeline stages")
    batch = x.shape[0]
    if batch % n_micro:
        raise ValueError(f"batch {batch} not divisible by n_micro {n_micro}")
    per_stage = n_groups // n_stages
    mb = batch // n_micro
    ws_staged = ws.reshape((n_stages, per_stage) + ws.shape[1:])
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])

    def run_stage(ws_local, xm):
        # ws_local: (1, per_stage, ...) — this device's stage weights.
        # xm: (n_micro, mb, ...) — replicated microbatches.
        stage = jax.lax.axis_index(axis)
        stage_ws = ws_local[0]
        last = n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)
        for t in range(n_micro + n_stages - 1):
            inp = jnp.where(stage == 0, xm[min(t, n_micro - 1)], buf)
            out = block(stage_ws, inp)
            m = t - last
            if m >= 0:  # microbatch m leaves the last stage at step t
                outs = outs.at[m].set(
                    jnp.where(stage == last, out, outs[m]))
            buf = jax.lax.ppermute(out, axis, fwd)
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(
            jnp.where(stage == last, outs, jnp.zeros_like(outs)), axis)

    spec_ws = P(axis)
    out = shard_map(run_stage, mesh=mesh, in_specs=(spec_ws, P()),
                    out_specs=P(), check_vma=False)(ws_staged, x_micro)
    return out.reshape(x.shape)
