"""Mesh-aware ``with_sharding_constraint`` wrappers.

Model code calls these unconditionally; they only emit a constraint when

  * a mesh context is active (``with mesh:``),
  * the named mesh axis exists and has size > 1, and
  * the constrained dimension is divisible by the axis size,

so the exact same forward runs unmodified on a single CPU device, under
``jax.eval_shape``, and on the 512-chip production mesh. The decode cache
layout (batch -> "data", seq -> "model") lives in :func:`dp_model_plan`; see
DESIGN.md §3 for why it must match ``sharding.cache_specs``.
"""
from __future__ import annotations

import warnings
from typing import Optional, Union

import jax
from jax.sharding import Mesh, PartitionSpec as P

Axis = Union[str, tuple, None]


def _resolve_thread_resources():
    try:
        from jax._src import mesh as mesh_lib
        return mesh_lib.thread_resources
    except (ImportError, AttributeError):  # pragma: no cover - old/new jax
        try:
            from jax.interpreters import pxla
            return pxla.thread_resources
        except (ImportError, AttributeError):
            return None


_THREAD_RESOURCES = _resolve_thread_resources()
if _THREAD_RESOURCES is None:  # pragma: no cover
    # distinguish "no mesh active" (normal, silent) from "this jax moved its
    # mesh-context internals" — the latter silently no-ops EVERY sharding
    # constraint (16x FLOP bloat class of regressions), so say it loudly once
    warnings.warn(
        "repro.dist.constrain: cannot locate jax's mesh-context internals "
        "in this jax version; all sharding constraints will be no-ops. "
        "Update _resolve_thread_resources for this jax release.",
        RuntimeWarning, stacklevel=2)


def _context_mesh() -> Optional[Mesh]:
    """The ambient mesh installed by ``with mesh:``, or None outside one."""
    if _THREAD_RESOURCES is None:  # pragma: no cover
        return None
    m = _THREAD_RESOURCES.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def _axis_size(mesh: Mesh, name: Axis) -> int:
    """Product of mesh-axis sizes for a (possibly tuple) assignment; 0 when
    any named axis is missing from the mesh."""
    names = name if isinstance(name, tuple) else (name,)
    size = 1
    shape = dict(mesh.shape)
    for n in names:
        if n not in shape:
            return 0
        size *= shape[n]
    return size


def _ok(mesh: Mesh, name: Axis, dim: int) -> bool:
    size = _axis_size(mesh, name)
    return size > 1 and dim % size == 0


def constrain_spec(x: jax.Array, plan: dict) -> jax.Array:
    """Constrain ``x`` per ``plan`` ({dim index -> mesh axis name | None}).

    Dims not in the plan (and plan entries that fail the divisibility /
    existence checks) stay unconstrained; a fully empty plan is a no-op.
    """
    mesh = _context_mesh()
    if mesh is None:
        return x
    entries: list[Axis] = [None] * x.ndim
    for d, name in plan.items():
        if name is None:
            continue
        d = d % x.ndim
        if _ok(mesh, name, x.shape[d]):
            entries[d] = name
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


def constrain_axis(x: jax.Array, axis: int, name: str = "model") -> jax.Array:
    """Constrain one dimension of ``x`` to a mesh axis (default TP)."""
    return constrain_spec(x, {axis: name})


def batch_axis(mesh: Mesh, dim: int) -> Axis:
    """The data-parallel assignment for a global-batch dim: the first of
    ("pod","data") combined, "data", "pod" that divides it, else None. The
    single definition used both for in-model constraints (constrain_batch)
    and jit-boundary input shardings (sharding.input_sharding)."""
    for cand in (("pod", "data"), "data", "pod"):
        if _ok(mesh, cand, dim):
            return cand
    return None


def constrain_batch(x: jax.Array) -> jax.Array:
    """Constrain the leading (batch) dim over the data-parallel axes,
    combining ("pod", "data") on multi-pod meshes when divisibility allows."""
    mesh = _context_mesh()
    if mesh is None:
        return x
    ax = batch_axis(mesh, x.shape[0])
    return constrain_spec(x, {0: ax}) if ax is not None else x


def dp_model_plan(batch: int, seq: int) -> tuple[Axis, Axis]:
    """The sequence-parallel decode layout: (batch axis, seq axis).

    Batch goes to "data"; the cached sequence dim goes to "model" (each TP
    shard holds a slice of the KV cache and computes a local partial softmax).
    When batch can't use "data" (e.g. the long_500k cell with batch 1) the
    sequence falls back to "data" so the cache is still distributed.
    Returns (None, None) when no mesh is active.
    """
    mesh = _context_mesh()
    if mesh is None:
        return None, None
    batch_ax: Axis = "data" if _ok(mesh, "data", batch) else None
    if _ok(mesh, "model", seq):
        seq_ax: Axis = "model"
    elif batch_ax is None and _ok(mesh, "data", seq):
        seq_ax = "data"
    else:
        seq_ax = None
    return batch_ax, seq_ax
