"""PartitionSpec assignment for params, decode caches, and input batches.

All functions take the mesh as an argument and only read ``mesh.axis_names``
and ``mesh.shape`` (a name -> size mapping), so they work with abstract
mesh stand-ins in tests as well as real ``jax.sharding.Mesh`` objects.

Invariants (property-tested in tests/test_sharding_properties.py):
  * a mesh axis is used at most once per spec;
  * an assigned dimension is always divisible by the axis size;
  * KV-cache / recurrent-state *stack* dims (the vmapped per-group leading
    dim) are never sharded — sharding them would make the decode scan
    all-gather the entire global cache every step (§Perf iteration 4b);
  * norm/bias parameters are replicated.

Parameter rules follow Megatron column/row duality: projections that *expand*
(wq/wk/wv/w_gate/w_up/...) shard their output dim over "model"; projections
that *contract* back to d_model (wo/w_down/out_proj) shard their input dim,
so the pair needs exactly one all-reduce. FSDP additionally shards the
largest remaining dim over "data" (ZeRO-3).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.dist.constrain import _axis_size, _ok, batch_axis

# column-parallel (shard dim -1) / row-parallel (shard dim -2) parents
_ROW = {"wo", "w_down", "out_proj"}
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "router", "in_proj", "wr",
        "wg", "decay_a", "decay_b", "lm_head"}
# dict keys that hold the actual weight array under a projection parent
# (w_planes_pos/neg: the bit-packed plane artifact for the 'packed' kernel
# backend — (..., P, K/8, N), sharded by the parent's col/row rule on N/K8)
_WEIGHT_KEYS = {"w", "w_q", "w_planes_pos", "w_planes_neg"}
# leaves that are always replicated (act_*: per-projection activation-
# quantizer scalars — levels, frozen calibration range, and the hoisted
# (s, z) the fused-prologue kernels read; plane_shift: the rung view's
# dropped-low-plane count, a per-module data scalar)
_REPLICATED_KEYS = {"b", "bias", "scale", "w_scale", "act_n", "act_nlvl",
                    "act_lo", "act_hi", "act_s", "act_z", "w_colsum",
                    "plane_shift"}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        name = getattr(k, "key", getattr(k, "name", None))
        if name is None:
            name = str(getattr(k, "idx", k))
        out.append(str(name))
    return out


def greedy_spec(dims: Sequence[int], mesh) -> P:
    """Assign mesh axes (in mesh order, so "data" lands on the batch dim
    first) to the first divisible unassigned dim each."""
    entries: list[Any] = [None] * len(dims)
    for ax in mesh.axis_names:
        size = _axis_size(mesh, ax)
        if size <= 1:
            continue
        for i, d in enumerate(dims):
            if entries[i] is None and d % size == 0:
                entries[i] = ax
                break
    return P(*entries)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _fsdp_dim(shape, entries, stacked: bool) -> int | None:
    """Largest unassigned dim (preferring non-stack dims) for ZeRO sharding."""
    cands = [i for i in range(len(shape))
             if entries[i] is None and not (stacked and i == 0)]
    if not cands:
        return None
    return max(cands, key=lambda i: shape[i])


def param_specs(shapes: Any, mesh, par: ParallelConfig) -> Any:
    """PartitionSpec tree matching a param (ShapeDtypeStruct) tree."""

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        names = _path_names(path)
        leaf_key = names[-1]
        stacked = "groups" in names[:-1]
        entries: list[Any] = [None] * len(shape)
        if leaf_key in _REPLICATED_KEYS or any("norm" in n for n in names):
            return P(*entries)
        parent = None
        for n in reversed(names):
            if n in _COL or n in _ROW or n == "embed":
                parent = n
                break
        is_weight = (leaf_key in _WEIGHT_KEYS or leaf_key in _COL
                     or leaf_key in _ROW or leaf_key == "table")
        if parent is None and leaf_key != "table":
            return P(*entries)
        if not is_weight or len(shape) < 2:
            return P(*entries)
        if leaf_key == "table":           # embedding: shard the vocab dim
            if _ok(mesh, "model", shape[-2]):
                entries[-2] = "model"
        elif parent in _ROW:
            if _ok(mesh, "model", shape[-2]):
                entries[-2] = "model"
        else:                             # column-parallel default
            if _ok(mesh, "model", shape[-1]):
                entries[-1] = "model"
        if par.fsdp:
            i = _fsdp_dim(shape, entries, stacked)
            if i is not None and _ok(mesh, "data", shape[i]):
                entries[i] = "data"
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, shapes)


# ---------------------------------------------------------------------------
# Decode caches / recurrent state
# ---------------------------------------------------------------------------

def cache_specs(tree: Any, mesh) -> Any:
    """Greedy specs for decode state trees. Mirrors ``constrain.dp_model_plan``:
    batch -> "data", first divisible later dim (the cached sequence) ->
    "model"; stack dims (under "groups"/"cross_kv") stay unsharded; scalars
    map to P()."""

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        names = _path_names(path)
        stacked = "groups" in names or "cross_kv" in names
        entries: list[Any] = [None] * len(shape)
        start = 1 if stacked else 0
        if start >= len(shape):
            return P(*entries)
        if _ok(mesh, "data", shape[start]):
            entries[start] = "data"
        model_at = None
        for i in range(start + 1, len(shape)):
            if _ok(mesh, "model", shape[i]):
                entries[i] = "model"
                model_at = i
                break
        if entries[start] is None and model_at is None:
            for i in range(start + 1, len(shape)):
                if _ok(mesh, "data", shape[i]):
                    entries[i] = "data"
                    break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, tree)


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------

def input_sharding(mesh, arr_shape: Sequence[int]) -> P:
    """Batch-shard model inputs; same axis resolution as constrain_batch
    (one definition — ``constrain.batch_axis``), so what the jit boundary
    pins and what the model constrains can never drift apart."""
    if len(arr_shape) == 0:
        return P()
    entries: list[Any] = [None] * len(arr_shape)
    entries[0] = batch_axis(mesh, arr_shape[0])
    return P(*entries)


# ---------------------------------------------------------------------------
# Specs -> shardings
# ---------------------------------------------------------------------------

def to_named(specs: Any, mesh) -> Any:
    """Map a PartitionSpec tree to NamedShardings on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Fleet: rung shards (host-level sharding of the ladder's variant cache)
# ---------------------------------------------------------------------------

def rung_shard(ladder_bits: Sequence[int], n_hosts: int
               ) -> dict[int, tuple[int, ...]]:
    """Assign ladder rungs to decode hosts, round-robin.

    The device-level specs above shard ONE variant's leaves across a mesh;
    this is the HOST-level rule of the serving fleet
    (``repro.serve_engine.fleet``): each decode host materializes (and
    warms up) only its shard of the per-rung view cache, so fleet-wide
    variant memory is flat in ladder depth x hosts rather than their
    product. Deterministic and total: every rung lands on at least one
    host and every host serves at least one rung — with more hosts than
    rungs the extra hosts replicate the ladder cyclically (capacity), with
    more rungs than hosts a host serves several rungs.
    """
    bits = sorted({int(b) for b in ladder_bits})
    if not bits or n_hosts <= 0:
        raise ValueError(f"need >=1 rung and >=1 host, got {bits!r} x "
                         f"{n_hosts}")
    shards: dict[int, set] = {h: set() for h in range(n_hosts)}
    for i in range(max(n_hosts, len(bits))):
        shards[i % n_hosts].add(bits[i % len(bits)])
    return {h: tuple(sorted(s)) for h, s in shards.items()}
