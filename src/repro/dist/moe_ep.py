"""Expert-parallel capacity routing for MoE layers.

The baseline ``repro.models.mlp.apply_moe`` scans over experts and runs every
expert on every token (E/k redundant FLOPs). This module implements the
GShard/Switch capacity dispatch: tokens are gathered into an
(experts, capacity, d) buffer, each expert runs only on its own tokens, and
the expert dim is sharded over the mesh so experts compute in parallel.
Wherever no token overflows capacity the result is numerically identical to
the dense scan (tested in tests/test_moe.py).

Sharding is expressed with explicit NamedShardings (not the ambient-mesh
constraint wrappers) so the function also works eagerly outside a ``with
mesh:`` block, e.g. under ``jax.grad`` in tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.constrain import _ok
from repro.models import mlp as M

Array = jax.Array


def _constrain(x: Array, mesh: Mesh, entries: tuple) -> Array:
    """with_sharding_constraint with per-dim divisibility guards."""
    checked = [name if name is not None and _ok(mesh, name, dim) else None
               for dim, name in zip(x.shape, entries)]
    if all(e is None for e in checked):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*checked)))


def apply_moe_capacity(x: Array, p: dict, cfg: ModelConfig, mesh: Mesh
                       ) -> tuple[Array, Array]:
    """x: (B, T, d) -> (y, aux_loss), matching ``mlp.apply_moe`` semantics.

    Experts are parallelized over the "data" axis (expert-parallelism reuses
    the DP axis: gradients are already reduced over it) and each expert's
    hidden dim is TP-sharded over "model" (inside ``mlp.expert_ffn``). When
    the expert count doesn't divide the axis (mixtral: 8 experts on a
    16-wide axis) the capacity dim is sharded instead, so the dispatch still
    computes in parallel. Tokens beyond an expert's capacity
    ``ceil(cf * n_tokens * top_k / E)`` are dropped (their residual passes
    through), exactly as in GShard.
    """
    assert cfg.moe is not None
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    b, t, d = x.shape
    n = b * t

    gates, mask, aux = M.route(x, p, cfg)   # shared router + aux loss

    capacity = int(math.ceil(cfg.moe.capacity_factor * n * k / e))
    capacity = max(1, min(capacity, n))

    xf = x.reshape(n, d)
    gates_f = gates.reshape(n, e).astype(x.dtype)
    mask_f = mask.reshape(n, e)
    # position of each token within its expert's buffer, in token order
    pos = jnp.cumsum(mask_f.astype(jnp.int32), axis=0) - 1
    keep = mask_f & (pos < capacity)
    disp = (keep[..., None].astype(x.dtype)
            * jax.nn.one_hot(jnp.where(keep, pos, 0), capacity,
                             dtype=x.dtype))                    # (n, E, C)

    xe = jnp.einsum("nec,nd->ecd", disp, xf)                    # (E, C, d)
    # prefer sharding the expert dim ("data" doubles as the EP axis); when E
    # doesn't divide it (e.g. mixtral's 8 experts on a 16-wide axis), fall
    # back to sharding capacity so the dispatch still computes in parallel
    if _ok(mesh, "data", e):
        ep_entries = ("data", None, None)
    elif _ok(mesh, "data", capacity):
        ep_entries = (None, "data", None)
    else:
        ep_entries = (None, None, None)
    xe = _constrain(xe, mesh, ep_entries)

    ye = jax.vmap(lambda xe_e, wg, wu, wd: M.expert_ffn(xe_e, wg, wu, wd,
                                                        cfg))(
        xe, p["w_gate"], p["w_up"], p["w_down"])
    ye = _constrain(ye, mesh, ep_entries)

    combine = disp * gates_f[..., None]                         # (n, E, C)
    y = jnp.einsum("nec,ecd->nd", combine, ye)
    return y.reshape(b, t, d).astype(x.dtype), aux
