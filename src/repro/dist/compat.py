"""Forward-compatibility shims for jax APIs the repo programs against.

The distribution substrate is written against the modern ``jax.shard_map``
entry point (mesh/in_specs/out_specs keywords, ``check_vma``). Older jax
releases (<= 0.4.x) only ship ``jax.experimental.shard_map.shard_map`` with a
``check_rep`` keyword. ``shard_map`` below accepts the modern signature and
dispatches to whichever implementation exists; importing ``repro.dist``
installs it as ``jax.shard_map`` when the attribute is missing, so call sites
(and tests) can use one spelling everywhere.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

try:  # pragma: no cover - depends on the installed jax
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
except ImportError:  # pragma: no cover
    _legacy_shard_map = None

_NATIVE = getattr(jax, "shard_map", None)


def shard_map(f: Callable, mesh: Any = None, in_specs: Any = None,
              out_specs: Any = None, check_vma: bool | None = None,
              check_rep: bool | None = None, **kwargs) -> Callable:
    """Modern-signature shard_map that runs on old and new jax alike."""
    check = True
    if check_vma is not None:
        check = check_vma
    elif check_rep is not None:
        check = check_rep
    if _NATIVE is not None:
        try:
            return _NATIVE(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=check, **kwargs)
        except TypeError:  # native API predates check_vma
            return _NATIVE(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=check, **kwargs)
    if _legacy_shard_map is None:  # pragma: no cover
        raise ImportError("no shard_map implementation available in this jax")
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check)


def install() -> None:
    """Expose :func:`shard_map` as ``jax.shard_map`` on old jax releases."""
    if getattr(jax, "shard_map", None) is None:
        jax.shard_map = shard_map
