"""Compressed cross-replica gradient reduction.

``compressed_psum_mean`` simulates an int8 wire format for the data-parallel
gradient all-reduce with *error feedback* (Karimireddy et al., 2019): each
round adds the residual it failed to transmit last round before quantizing,
so the quantization bias telescopes away and the running average of the
compressed means converges on the true mean. Runs inside ``shard_map`` over
the reduction axis.
"""
from __future__ import annotations

from typing import Any, Hashable

import jax
import jax.numpy as jnp

_WIRE_MAX = 127.0  # int8 symmetric code range


def _compress_one(g: jax.Array, err: jax.Array, axis_name: Hashable
                  ) -> tuple[jax.Array, jax.Array]:
    val = g.astype(jnp.float32) + err.astype(jnp.float32)
    # shared scale: one extra scalar pmax, so every shard's codes dequantize
    # identically and the mean of codes is the code of the mean
    amax = jax.lax.pmax(jnp.max(jnp.abs(val)), axis_name)
    scale = jnp.maximum(amax, 1e-30) / _WIRE_MAX
    codes = jnp.clip(jnp.round(val / scale), -_WIRE_MAX, _WIRE_MAX)
    codes = codes.astype(jnp.int8)                    # the wire payload
    deq = codes.astype(jnp.float32) * scale
    mean = jax.lax.pmean(deq, axis_name)
    new_err = val - deq                               # residual stays local
    return mean.astype(g.dtype), new_err.astype(err.dtype)


def compressed_psum_mean(grads: Any, err: Any, axis_name: Hashable
                         ) -> tuple[Any, Any]:
    """int8-compressed mean over ``axis_name`` with error feedback.

    ``grads``/``err`` are matching pytrees of per-shard arrays. Returns
    (mean tree — replicated, new error-feedback tree — per shard).
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    means, errs = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = _compress_one(g, e, axis_name)
        means.append(m)
        errs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, means),
            jax.tree_util.tree_unflatten(treedef, errs))
