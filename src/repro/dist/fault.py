"""Fault tolerance: straggler telemetry and checkpoint/restart supervision.

``StepMonitor`` keeps a running baseline of healthy step times and flags any
step slower than ``threshold`` x the baseline (SDC / preemption / slow-host
detection at the trainer level). ``Supervisor`` wraps a step loop with
periodic checkpointing and restart-from-latest-checkpoint on crashes — the
single-process stand-in for the pod-level supervisor that restarts failed
workers against the same checkpoint stream.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional


class StepMonitor:
    """Flags straggler steps against a running mean of healthy steps."""

    def __init__(self, warmup: int = 5, threshold: float = 2.0):
        self.warmup = warmup
        self.threshold = threshold
        self.times: list[float] = []
        self.stragglers = 0
        self._baseline_sum = 0.0
        self._baseline_n = 0

    def record(self, step: int, seconds: float) -> bool:
        """Record one step duration; True iff the step is a straggler."""
        flagged = False
        if self._baseline_n >= self.warmup:
            baseline = self._baseline_sum / self._baseline_n
            flagged = seconds > self.threshold * baseline
        if flagged:
            self.stragglers += 1
        else:  # stragglers don't poison the baseline
            self._baseline_sum += seconds
            self._baseline_n += 1
        self.times.append(seconds)
        return flagged

    def summary(self) -> dict:
        n = len(self.times)
        mean = (self._baseline_sum / self._baseline_n
                if self._baseline_n else 0.0)
        return {
            "steps_recorded": n,
            "stragglers": self.stragglers,
            "mean_step_s": round(mean, 6),
            "max_step_s": round(max(self.times), 6) if self.times else 0.0,
        }


class Supervisor:
    """Run a step loop with periodic checkpoints; on a crash, restore from
    the newest checkpoint and continue.

    At-least-once semantics: a crash replays the (up to ``ckpt_every - 1``)
    steps since the last checkpoint, and a crash before the first checkpoint
    re-runs ``init_fn`` from step 0 — ``step_fn`` side effects must be
    idempotent or keyed by step. The *state* trajectory is exact: the final
    state equals an uninterrupted run's."""

    def __init__(self, ckpt_dir: str, ckpt_every: int = 5,
                 max_restarts: int = 3, backoff_s: float = 0.0):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0

    def run(self, total_steps: int, *,
            init_fn: Callable[[], Any],
            resume_fn: Callable[[int], Any],
            step_fn: Callable[[Any, int], Any],
            save_fn: Callable[[Any, int], None]) -> Any:
        from repro.ckpt import checkpoint as ck

        state = init_fn()
        step = 0
        while step < total_steps:
            try:
                while step < total_steps:
                    state = step_fn(state, step)
                    step += 1
                    if step % self.ckpt_every == 0:
                        save_fn(state, step)
                return state
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if self.backoff_s:
                    time.sleep(self.backoff_s)
                last: Optional[int] = ck.latest_step(self.ckpt_dir)
                if last is None:
                    state = init_fn()
                    step = 0
                else:
                    state = resume_fn(last)
                    step = last
        return state
