"""Fault tolerance: straggler telemetry and checkpoint/restart supervision.

``StepMonitor`` keeps a running baseline of healthy step times and flags any
step slower than ``threshold`` x the baseline (SDC / preemption / slow-host
detection at the trainer level). ``Supervisor`` wraps a step loop with
periodic checkpointing and restart-from-latest-checkpoint on crashes — the
single-process stand-in for the pod-level supervisor that restarts failed
workers against the same checkpoint stream.

``HostFailure``/``FleetSupervisor`` are the serving-fleet analogues at HOST
granularity: a fleet host dying mid-decode raises ``HostFailure``; the
supervisor absorbs it by rebuilding that one host (in the fleet, from the
shared mmap serving artifact — docs/fleet.md) while the rest of the fleet
keeps serving. In-flight work on the dead host is resumed by prefix replay,
which is bit-exact by the same argument as mid-stream rung switching
(DESIGN.md §6), so a kill costs latency and restart energy but never
changes a single served token (tests/test_fleet.py).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional


class HostFailure(RuntimeError):
    """One fleet host died (simulated kill or a real crash mid-step)."""

    def __init__(self, host_id: int, reason: str = "killed"):
        super().__init__(f"host {host_id}: {reason}")
        self.host_id = int(host_id)
        self.reason = reason


class FleetSupervisor:
    """Restart failed hosts against the shared serving artifact.

    ``restart_fn(host_id)`` must return the replacement host; ``absorb``
    enforces a per-host restart budget (a host that keeps dying is a real
    outage, not a blip — re-raise rather than flap forever). The fleet
    calls ``absorb`` from its tick loop, so supervision is synchronous with
    simulated time and the restart count is deterministic for a fixed
    kill schedule.
    """

    def __init__(self, restart_fn: Callable[[int], Any],
                 max_restarts_per_host: int = 3):
        self.restart_fn = restart_fn
        self.max_restarts_per_host = int(max_restarts_per_host)
        self.restarts: dict[int, int] = {}

    @property
    def total_restarts(self) -> int:
        return sum(self.restarts.values())

    def absorb(self, failure: HostFailure) -> Any:
        """Handle one host failure: count it and rebuild the host."""
        n = self.restarts.get(failure.host_id, 0) + 1
        if n > self.max_restarts_per_host:
            raise failure
        self.restarts[failure.host_id] = n
        return self.restart_fn(failure.host_id)


class StepMonitor:
    """Flags straggler steps against a running mean of healthy steps."""

    def __init__(self, warmup: int = 5, threshold: float = 2.0):
        self.warmup = warmup
        self.threshold = threshold
        self.times: list[float] = []
        self.stragglers = 0
        self._baseline_sum = 0.0
        self._baseline_n = 0

    def record(self, step: int, seconds: float) -> bool:
        """Record one step duration; True iff the step is a straggler."""
        flagged = False
        if self._baseline_n >= self.warmup:
            baseline = self._baseline_sum / self._baseline_n
            flagged = seconds > self.threshold * baseline
        if flagged:
            self.stragglers += 1
        else:  # stragglers don't poison the baseline
            self._baseline_sum += seconds
            self._baseline_n += 1
        self.times.append(seconds)
        return flagged

    def summary(self) -> dict:
        n = len(self.times)
        mean = (self._baseline_sum / self._baseline_n
                if self._baseline_n else 0.0)
        return {
            "steps_recorded": n,
            "stragglers": self.stragglers,
            "mean_step_s": round(mean, 6),
            "max_step_s": round(max(self.times), 6) if self.times else 0.0,
        }


class Supervisor:
    """Run a step loop with periodic checkpoints; on a crash, restore from
    the newest checkpoint and continue.

    At-least-once semantics: a crash replays the (up to ``ckpt_every - 1``)
    steps since the last checkpoint, and a crash before the first checkpoint
    re-runs ``init_fn`` from step 0 — ``step_fn`` side effects must be
    idempotent or keyed by step. The *state* trajectory is exact: the final
    state equals an uninterrupted run's."""

    def __init__(self, ckpt_dir: str, ckpt_every: int = 5,
                 max_restarts: int = 3, backoff_s: float = 0.0):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0

    def run(self, total_steps: int, *,
            init_fn: Callable[[], Any],
            resume_fn: Callable[[int], Any],
            step_fn: Callable[[Any, int], Any],
            save_fn: Callable[[Any, int], None]) -> Any:
        from repro.ckpt import checkpoint as ck

        state = init_fn()
        step = 0
        while step < total_steps:
            try:
                while step < total_steps:
                    state = step_fn(state, step)
                    step += 1
                    if step % self.ckpt_every == 0:
                        save_fn(state, step)
                return state
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if self.backoff_s:
                    time.sleep(self.backoff_s)
                last: Optional[int] = ck.latest_step(self.ckpt_dir)
                if last is None:
                    state = init_fn()
                    step = 0
                else:
                    state = resume_fn(last)
                    step = last
        return state
