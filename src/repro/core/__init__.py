"""Core PANN library: power models, bit-flip simulators, quantizers, the
unsigned-arithmetic conversion, PANN weight quantization, the Algorithm-1
planner, and the quantization-error theory."""
from repro.core import (bitflip, mse, pann, planner, policy, power,  # noqa: F401
                        quant, unsigned)
