"""EMA activation-range calibration for power-aware QAT (DESIGN.md §9).

PANN's operating points quantize activations at b̃x bits; the quantizer
needs a range. During training the range of every projection input is
*observed* (per-tensor min/max, merged across the depth of the scanned
stack — module paths are roles, so all layers of a role share one range,
exactly like they share one ``ModuleQuant``) and folded into an exponential
moving average that lives in the train state as its own collection:

    state.calib = {"attn.wq": [lo, hi], "mlp.w_down": [lo, hi], ...}

The EMA range is fed back into the QAT forward (``quant.affine_from_range``)
so training converges onto *static* activation quantizers, and is frozen
into the serving artifact at export time (``models.serving.
quantize_params_for_serving(calib=...)``) — the train→serve loop closes on
the same numbers.

Ranges start at the *unseen* sentinel [+inf, -inf]; every consumer treats
lo > hi as "fall back to the dynamic per-tensor range" (bit-exact with the
uncalibrated behavior), so warm-up needs no special casing and a module
role that never runs (e.g. ``moe.router`` on a dense model) stays inert.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import costs

Array = jax.Array

# module roles that are not ``models.layers.apply_linear`` call sites and
# therefore never observe activations (the depthwise conv reads no shared
# activation tensor)
_NON_LINEAR_PATHS = frozenset({"ssm.conv"})

UNSEEN = (float("inf"), float("-inf"))


def calib_paths(cfg: ModelConfig) -> Tuple[str, ...]:
    """The module-path vocabulary calibrated for ``cfg``: every projection
    role in the cost profile (plus ``lm_head``, present even when the
    embedding is tied — the unembed matmul quantizes its input too).
    Attention-bearing configs also calibrate the KV-cache roles
    (``policy.CACHE_PATHS``): training observes post-RoPE K and V so
    serving can freeze the cache quantizer ranges the same way it freezes
    the projection-input ranges."""
    from repro.core.policy import CACHE_PATHS
    paths = {m.path for m in costs.module_cost_profile(cfg)}
    paths.add("lm_head")
    if any(p.startswith("attn.") for p in paths):
        paths.update(CACHE_PATHS)
    return tuple(sorted(paths - _NON_LINEAR_PATHS))


def init_calib(cfg: ModelConfig) -> Dict[str, Array]:
    """Fresh calibration collection: every role at the unseen sentinel."""
    return {p: jnp.asarray(UNSEEN, jnp.float32) for p in calib_paths(cfg)}


def unseen_like(calib: Dict[str, Array]) -> Dict[str, Array]:
    """An all-unseen observation accumulator with ``calib``'s structure —
    the zero element of :func:`merge` (used as scan-carry init)."""
    return {p: jnp.asarray(UNSEEN, jnp.float32) for p in calib}


def seen(entry: Array) -> Array:
    """Whether a [lo, hi] entry has observed anything (lo <= hi)."""
    return entry[0] <= entry[1]


def merge(into: Dict[str, Array], observed: Dict[str, Array]
          ) -> Dict[str, Array]:
    """Union of two observation dicts: elementwise min-lo / max-hi.

    ``observed`` may cover a subset of ``into``'s keys (a stack only sees
    its own roles); extra observed keys are ignored so the carry structure
    stays fixed.
    """
    out = dict(into)
    for path, obs in observed.items():
        if path not in out:
            continue
        cur = out[path]
        out[path] = jnp.stack([jnp.minimum(cur[0], obs[0]),
                               jnp.maximum(cur[1], obs[1])])
    return out


def ema_update(calib: Dict[str, Array], observed: Optional[Dict[str, Array]],
               decay: float) -> Dict[str, Array]:
    """One EMA step of the calibration collection.

    Per role: unseen observation -> keep the current range; first real
    observation -> adopt it outright (no bias toward the inf sentinel);
    otherwise new = decay * old + (1 - decay) * observed, elementwise on
    [lo, hi]. Pure and deterministic — resuming from a checkpoint replays
    the identical trajectory (asserted in tests/test_train_power.py).
    """
    if observed is None:
        return calib
    d = jnp.float32(decay)
    out = {}
    for path, cur in calib.items():
        obs = observed.get(path)
        if obs is None:
            out[path] = cur
            continue
        ema = d * cur + (1.0 - d) * obs
        new = jnp.where(seen(cur), ema, obs)
        out[path] = jnp.where(seen(obs), new, cur)
    return out


def describe(calib: Optional[Dict[str, Array]]) -> str:
    """Host-side rendering of a concrete collection (trainer end-of-run
    log / export inspection — not for traced values)."""
    if not calib:
        return "calibration: off"
    rows = []
    for path, entry in sorted(calib.items()):
        lo, hi = float(entry[0]), float(entry[1])
        rows.append(f"  {path}: unseen" if lo > hi
                    else f"  {path}: [{lo:+.4f}, {hi:+.4f}]")
    return "\n".join(["calibration ranges:"] + rows)
