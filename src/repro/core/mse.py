"""Section 5.3: quantization-error theory.

Closed forms under the paper's simplistic uniform model
(activations ~ U[0, M_x], weights ~ U[-M_w/2, M_w/2]):

  Eq. (14)  MSE ~= d (sigma_w^2 sigma_ex^2 + sigma_x^2 sigma_ew^2)
  Eq. (16)  MSE_RUQ  = d Mx^2 Mw^2 / 144 * (2^-2bx + 4 * 2^-2bw)
  Eq. (18)  MSE_PANN = d Mx^2 Mw^2 / 144 * (2^-2bx~ + 1/(4R^2))
  Eq. (19)  MSE_PANN(P) with R = P/bx~ - 0.5 substituted.

Plus the numeric optimal-bit-width search the paper runs over Eq. (19), and
Monte-Carlo counterparts used by the tests and Fig.-4 benchmark.
"""
from __future__ import annotations

import math
from typing import Iterable, Tuple

import numpy as np

from repro.core import power as pw


def mse_ruq(d: float, b_x: float, b_w: float,
            m_x: float = 1.0, m_w: float = 1.0) -> float:
    """Eq. (16)."""
    return d * m_x ** 2 * m_w ** 2 / 144.0 * (2.0 ** (-2 * b_x)
                                              + 4.0 * 2.0 ** (-2 * b_w))


def mse_pann(d: float, b_x_tilde: float, r: float,
             m_x: float = 1.0, m_w: float = 1.0) -> float:
    """Eq. (18)."""
    return d * m_x ** 2 * m_w ** 2 / 144.0 * (2.0 ** (-2 * b_x_tilde)
                                              + 1.0 / (4.0 * r * r))


def mse_pann_at_budget(d: float, power: float, b_x_tilde: float,
                       m_x: float = 1.0, m_w: float = 1.0) -> float:
    """Eq. (19): substitute R = P / b_x~ - 0.5."""
    r = pw.pann_r_for_budget(power, b_x_tilde)
    if r <= 0:
        return math.inf
    return mse_pann(d, b_x_tilde, r, m_x, m_w)


def optimal_bx_tilde(power: float, d: float = 1.0,
                     candidates: Iterable[int] = range(2, 9)
                     ) -> Tuple[int, float]:
    """Numerically minimize Eq. (19) over integer activation bit widths."""
    best_b, best_mse = None, math.inf
    for b in candidates:
        m = mse_pann_at_budget(d, power, b)
        if m < best_mse:
            best_b, best_mse = b, m
    assert best_b is not None
    return best_b, best_mse


def mse_ratio_at_budget(b: int, d: float = 1.0) -> float:
    """Fig. 4: MSE_RUQ(b) / MSE_PANN at the same power budget.

    The RUQ uses b_x = b_w = b (its multiplier power is dominated by the max
    anyway); the matched budget is the unsigned MAC power 0.5 b^2 + 4b.
    """
    budget = pw.p_mac_unsigned(b)
    _, m_pann = optimal_bx_tilde(budget, d)
    return mse_ruq(d, b, b) / m_pann


# ---------------------------------------------------------------------------
# Monte-Carlo counterparts (validation instrument)
# ---------------------------------------------------------------------------

def mc_mse_ruq(rng: np.random.Generator, d: int, b_x: int, b_w: int,
               n: int = 2048, m_x: float = 1.0, m_w: float = 1.0,
               dist: str = "uniform") -> float:
    """Monte-Carlo MSE of RUQ on w^T x under the paper's §5.3 model."""
    if dist == "uniform":
        x = rng.uniform(0, m_x, size=(n, d))
        w = rng.uniform(-m_w / 2, m_w / 2, size=(n, d))
    else:  # gaussian weights, ReLU'd gaussian activations
        x = np.maximum(rng.standard_normal((n, d)) * m_x, 0.0)
        w = rng.standard_normal((n, d)) * m_w
    # mid-rise uniform quantizers with the §5.3 step sizes
    gx = m_x / 2 ** b_x if dist == "uniform" else np.abs(x).max() / 2 ** b_x
    gw = m_w / 2 ** b_w if dist == "uniform" else np.abs(w).max() / 2 ** b_w
    xq = np.round(x / gx) * gx
    wq = np.round(w / gw) * gw
    err = (w * x).sum(-1) - (wq * xq).sum(-1)
    return float(np.mean(err ** 2))


def mc_mse_pann(rng: np.random.Generator, d: int, b_x_tilde: int, r: float,
                n: int = 2048, m_x: float = 1.0, m_w: float = 1.0,
                dist: str = "uniform") -> float:
    """Monte-Carlo MSE of PANN weight quantization (Eq. 12) + b~x-bit RUQ."""
    if dist == "uniform":
        x = rng.uniform(0, m_x, size=(n, d))
        w = rng.uniform(-m_w / 2, m_w / 2, size=(n, d))
    else:
        x = np.maximum(rng.standard_normal((n, d)) * m_x, 0.0)
        w = rng.standard_normal((n, d)) * m_w
    gx = m_x / 2 ** b_x_tilde if dist == "uniform" \
        else np.abs(x).max() / 2 ** b_x_tilde
    xq = np.round(x / gx) * gx
    gw = np.abs(w).sum(-1, keepdims=True) / (r * d)   # Eq. (12), per row
    wq = np.round(w / gw) * gw
    err = (w * x).sum(-1) - (wq * xq).sum(-1)
    return float(np.mean(err ** 2))
