"""Analytic parameter / FLOP / MAC counting per architecture config.

Used for (a) the paper-style power accounting (MACs x bit-flips/MAC), and
(b) the roofline's MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) yardstick
against compiled HLO FLOPs.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import power as pw
from repro.core.power import MacBreakdown
from repro.models.transformer import group_layout


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    n = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
        + cfg.num_heads * hd * d
    if cfg.qkv_bias:
        n += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
    return n


def _mlp_params(cfg: ModelConfig) -> int:
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * cfg.d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    d_inner = cfg.ssm_expand * cfg.d_model
    h = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    proj_out = 2 * d_inner + 2 * n + h
    return cfg.d_model * proj_out + d_inner * cfg.d_model \
        + cfg.ssm_conv_width * (d_inner + 2 * n)


def _rwkv_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    return 5 * d * d + d * 64 + 64 * d + 2 * d * cfg.d_ff


def _layer_params(cfg: ModelConfig, kind: str) -> int:
    if kind == "attn":
        return _attn_params(cfg) + _mlp_params(cfg)
    if kind == "attn_moe":
        e = cfg.moe.num_experts
        return _attn_params(cfg) + e * _mlp_params(cfg) \
            + cfg.d_model * e
    if kind == "cross_attn":
        return 2 * _attn_params(cfg) + _mlp_params(cfg)
    if kind == "mamba":
        return _ssm_params(cfg)
    if kind == "mamba_attn":
        return _ssm_params(cfg)  # shared block counted once, separately
    if kind == "rwkv":
        return _rwkv_params(cfg)
    raise ValueError(kind)


def _conv_stem_params(cfg: ModelConfig) -> int:
    return sum(s.fan_in * s.c_out + s.c_out for s in cfg.conv_stem)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count."""
    pattern, n_groups, n_tail = group_layout(cfg)
    total = cfg.padded_vocab * cfg.d_model          # embedding
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.padded_vocab     # lm head
    seq = [s.kind for s in pattern] * n_groups \
        + [pattern[i].kind for i in range(n_tail)]
    for kind in seq:
        if active_only and kind == "attn_moe":
            k = cfg.moe.top_k
            total += _attn_params(cfg) + k * _mlp_params(cfg) \
                + cfg.d_model * cfg.moe.num_experts
        else:
            total += _layer_params(cfg, kind)
    if cfg.family == "hybrid":
        total += _attn_params(cfg) + _mlp_params(cfg)   # shared block
    if cfg.family == "encdec":
        total += cfg.encoder_layers * (_attn_params(cfg) + _mlp_params(cfg))
    total += _conv_stem_params(cfg)
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The roofline yardstick: 6·N·D train / 2·N·D inference, with N the
    MoE-*active* parameter count (the assignment's §Roofline definition)."""
    n = param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one new token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# MAC counting for the power model (forward pass, per token)
# ---------------------------------------------------------------------------

def macs_per_token(cfg: ModelConfig, context_len: int = 4096) -> MacBreakdown:
    """Weight-MACs vs activation-MACs of one forward token.

    act_macs covers QK^T and attention·V (context_len keys) — products with
    no static weight operand, outside PANN's scope (DESIGN.md §4).

    A conv stem is NOT one MAC per param per token (spatial weight reuse:
    each kernel fires Ho·Wo times per item), so its param count is swapped
    out for the exact per-layer kh·kw·Cin·Cout·Ho·Wo account, amortized
    per produced frontend token — the same rows ``module_cost_profile``
    itemizes, keeping the two accounts equal to float precision.
    """
    weight = float(param_count(cfg, active_only=True))
    # embedding lookups are gathers, not MACs
    weight -= cfg.padded_vocab * cfg.d_model
    if cfg.conv_stem:
        weight -= float(_conv_stem_params(cfg))
        weight += sum(m.macs for m in conv_stem_token_costs(cfg))
    pattern, n_groups, n_tail = group_layout(cfg)
    seq = [s.kind for s in pattern] * n_groups \
        + [pattern[i].kind for i in range(n_tail)]
    hd = cfg.resolved_head_dim
    act = 0.0
    for i, kind in enumerate(seq):
        if kind in ("attn", "attn_moe", "cross_attn"):
            win = pattern[i % len(pattern)].window
            ctx = min(context_len, win) if win else context_len
            act += 2.0 * cfg.num_heads * hd * ctx   # QK^T + PV
        if kind == "mamba_attn":
            act += 2.0 * cfg.num_heads * hd * context_len
    return MacBreakdown(weight_macs=weight, act_macs=act)


# ---------------------------------------------------------------------------
# Per-module MAC profile (the layerwise allocator's input)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModuleCost:
    """One module role's aggregate forward cost per token.

    ``fan_in`` is one instance's reduction width — the d of Eq. (19)'s MSE
    and the k^2 C_in of Eq. (20)'s accumulator bound. ``macs`` sums over all
    ``instances`` of the role across the depth of the network (module paths
    are roles, not per-depth instances; see core/policy.py).
    """
    path: str
    macs: float          # weight MACs per token, all instances
    fan_in: int          # reduction width of one instance
    instances: int = 1

    def acc_bits(self, b_x: int, b_w: int) -> int:
        """Eq. (20) accumulator width for this module's fan-in, capped at
        the paper's 32-bit default (never wider than the hardware)."""
        return min(pw.DEFAULT_ACC_BITS,
                   pw.required_acc_bits(b_x, b_w, self.fan_in))


def module_cost_profile(cfg: ModelConfig) -> tuple[ModuleCost, ...]:
    """Weight-MAC profile by module path, consistent with ``macs_per_token``:
    the profile's total equals its ``weight_macs`` up to the tiny terms the
    analytic param count also ignores (qkv biases, norm vectors).

    MoE experts are counted at the *active* (top-k) rate, matching
    ``param_count(active_only=True)``. The embedding gather contributes no
    MACs and has no entry.
    """
    acc: dict[str, list] = {}     # path -> [macs, fan_in, instances]

    def add(path: str, d_in: int, d_out: int, count: float = 1.0) -> None:
        row = acc.setdefault(path, [0.0, int(d_in), 0])
        row[0] += float(d_in) * float(d_out) * count
        row[2] += max(int(round(count)), 1) if count else 0

    hd = cfg.resolved_head_dim
    d = cfg.d_model

    def add_attn(count: float = 1.0) -> None:
        add("attn.wq", d, cfg.num_heads * hd, count)
        add("attn.wk", d, cfg.num_kv_heads * hd, count)
        add("attn.wv", d, cfg.num_kv_heads * hd, count)
        add("attn.wo", cfg.num_heads * hd, d, count)

    def add_mlp(count: float = 1.0) -> None:
        if cfg.activation in ("swiglu", "geglu"):
            add("mlp.w_gate", d, cfg.d_ff, count)
        add("mlp.w_up", d, cfg.d_ff, count)
        add("mlp.w_down", cfg.d_ff, d, count)

    def add_ssm(count: float = 1.0) -> None:
        d_inner = cfg.ssm_expand * d
        h = d_inner // cfg.ssm_head_dim
        n = cfg.ssm_state
        add("ssm.in_proj", d, 2 * d_inner + 2 * n + h, count)
        add("ssm.out_proj", d_inner, d, count)
        # depthwise causal conv: conv_width MACs per channel per token
        add("ssm.conv", cfg.ssm_conv_width, d_inner + 2 * n, count)

    def add_rwkv(count: float = 1.0) -> None:
        for name in ("wr", "wk", "wv", "wg", "wo"):
            add(f"rwkv.tm.{name}", d, d, count)
        add("rwkv.tm.decay_a", d, 64, count)
        add("rwkv.tm.decay_b", 64, d, count)
        add("rwkv.cm.wk", d, cfg.d_ff, count)
        add("rwkv.cm.wv", cfg.d_ff, d, count)

    pattern, n_groups, n_tail = group_layout(cfg)
    seq = [s.kind for s in pattern] * n_groups \
        + [pattern[i].kind for i in range(n_tail)]
    for kind in seq:
        if kind == "attn":
            add_attn()
            add_mlp()
        elif kind == "attn_moe":
            add_attn()
            add("moe.router", d, cfg.moe.num_experts)
            k = cfg.moe.top_k
            if cfg.activation in ("swiglu", "geglu"):
                add("moe.w_gate", d, cfg.d_ff, k)
            add("moe.w_up", d, cfg.d_ff, k)
            add("moe.w_down", cfg.d_ff, d, k)
        elif kind == "cross_attn":
            add_attn(2.0)          # self + cross projections
            add_mlp()
        elif kind in ("mamba", "mamba_attn"):
            add_ssm()              # hybrid shared block counted once below
        elif kind == "rwkv":
            add_rwkv()
    if cfg.family == "hybrid":
        add_attn()
        add_mlp()
    if cfg.family == "encdec":
        add_attn(float(cfg.encoder_layers))
        add_mlp(float(cfg.encoder_layers))
    if not cfg.tie_embeddings:
        add("lm_head", d, cfg.padded_vocab)
    # conv-stem roles, amortized per produced frontend token (see
    # macs_per_token) — present so allocate_layerwise trades conv bits
    # against attention/cache bits under ONE budget, and so the engine's
    # EnergyLedger breakdown itemizes the stem like any other role
    for m in conv_stem_token_costs(cfg):
        acc[m.path] = [m.macs, m.fan_in, m.instances]
    return tuple(ModuleCost(path=p, macs=row[0], fan_in=row[1],
                            instances=row[2])
                 for p, row in sorted(acc.items()))


# ---------------------------------------------------------------------------
# Conv stems and the encoder (per-item) account
# ---------------------------------------------------------------------------

def conv_stem_item_costs(cfg: ModelConfig) -> tuple[ModuleCost, ...]:
    """EXACT per-ITEM (image / utterance) conv MACs, one role per stem
    layer: kh·kw·Cin · Cout · Ho·Wo — the Moons-et-al.-style per-layer conv
    energy account in the repo's MAC currency. Geometry walks forward from
    ``cfg.frontend_hw`` through each ``ConvSpec``. fan_in = kh·kw·Cin is
    both the Eq.-19 sensitivity d and the Eq.-20 accumulator bound, so the
    layerwise allocator prices conv roles with zero new code."""
    if not cfg.conv_stem:
        return ()
    h, w = cfg.frontend_hw
    rows = []
    for i, spec in enumerate(cfg.conv_stem):
        ho, wo = spec.out_hw(h, w)
        rows.append(ModuleCost(
            path=f"conv.s{i}",
            macs=float(spec.fan_in) * float(spec.c_out) * float(ho * wo),
            fan_in=spec.fan_in))
        h, w = ho, wo
    return tuple(rows)


def conv_stem_token_costs(cfg: ModelConfig) -> tuple[ModuleCost, ...]:
    """Conv-stem roles amortized per PRODUCED frontend token (item MACs /
    stem token count) — the form that composes with the per-token rows of
    ``module_cost_profile`` / ``macs_per_token``."""
    rows = conv_stem_item_costs(cfg)
    if not rows:
        return ()
    n_tok = float(max(cfg.stem_tokens, 1))
    return tuple(dataclasses.replace(m, macs=m.macs / n_tok) for m in rows)


def encoder_tokens(cfg: ModelConfig) -> int:
    """Length of the token sequence one encoded item produces."""
    if cfg.conv_stem:
        return cfg.stem_tokens
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    return cfg.encoder_seq_len


def encoder_cost_profile(cfg: ModelConfig) -> tuple[ModuleCost, ...]:
    """Per-ITEM weight-MAC profile of the ENCODE path — what one image /
    utterance costs, the unit the encoder serving ladder budgets in
    (per-item power budgets instead of per-token).

    Conv rows are exact (``conv_stem_item_costs``); for an encdec family
    the bidirectional encoder stack runs every layer over every produced
    token, so its attn/mlp roles carry encoder_layers · n_tokens instances
    of the per-token MACs. A vlm's encode path is the stem alone (its
    transformer is the cross-attending DECODER, priced per decoded token
    by ``module_cost_profile``)."""
    acc: dict[str, list] = {}
    for m in conv_stem_item_costs(cfg):
        acc[m.path] = [m.macs, m.fan_in, m.instances]
    if cfg.family == "encdec" and cfg.encoder_layers:
        d = cfg.d_model
        hd = cfg.resolved_head_dim
        count = float(cfg.encoder_layers) * float(encoder_tokens(cfg))

        def add(path: str, d_in: int, d_out: int) -> None:
            row = acc.setdefault(path, [0.0, int(d_in), 0])
            row[0] += float(d_in) * float(d_out) * count
            row[2] += cfg.encoder_layers

        add("attn.wq", d, cfg.num_heads * hd)
        add("attn.wk", d, cfg.num_kv_heads * hd)
        add("attn.wv", d, cfg.num_kv_heads * hd)
        add("attn.wo", cfg.num_heads * hd, d)
        if cfg.activation in ("swiglu", "geglu"):
            add("mlp.w_gate", d, cfg.d_ff)
        add("mlp.w_up", d, cfg.d_ff)
        add("mlp.w_down", cfg.d_ff, d)
    return tuple(ModuleCost(path=p, macs=row[0], fan_in=row[1],
                            instances=row[2])
                 for p, row in sorted(acc.items()))


def encoder_macs_per_item(cfg: ModelConfig) -> MacBreakdown:
    """Weight vs act MACs of encoding ONE item. act_macs is the encoder's
    bidirectional self-attention: 2·H·hd·T per query token over T tokens
    per layer (T², not T·ctx — whole-sequence waves, no KV cache)."""
    weight = sum(m.macs for m in encoder_cost_profile(cfg))
    act = 0.0
    if cfg.family == "encdec" and cfg.encoder_layers:
        t = float(encoder_tokens(cfg))
        act = 2.0 * cfg.num_heads * cfg.resolved_head_dim * t * t \
            * cfg.encoder_layers
    return MacBreakdown(weight_macs=weight, act_macs=act)


def cache_cost_modules(cfg: ModelConfig, context_len: int = 4096
                       ) -> tuple[ModuleCost, ...]:
    """The KV-cache roles as allocator pseudo-modules: ``attn.k_cache``
    (QK^T) and ``attn.v_cache`` (PV) each carry HALF of ``macs_per_token``'s
    act_macs — the two act x act streams of decode attention — with one
    head's reduction width as fan_in. Appending these to
    ``module_cost_profile``'s output lets ``allocate_layerwise`` trade
    cache bits against weight bits under ONE budget (priced by
    ``policy.tree_power_per_token``'s cache-role split)."""
    act = macs_per_token(cfg, context_len).act_macs
    if not act:
        return ()
    hd = cfg.resolved_head_dim
    return (ModuleCost(path="attn.k_cache", macs=0.5 * act, fan_in=hd),
            ModuleCost(path="attn.v_cache", macs=0.5 * act, fan_in=hd))


def network_macs(cfg: ModelConfig, shape: ShapeConfig) -> MacBreakdown:
    tokens = shape.seq_len * shape.global_batch if shape.kind != "decode" \
        else shape.global_batch
    ctx = shape.seq_len
    return macs_per_token(cfg, ctx).scale(float(tokens))
