"""Algorithm 1: determining the optimal PANN parameters for a power budget.

Given a power budget P (per-weight-MAC, in bit flips), sweep the activation
bit width b~x, set R = P / b~x - 0.5 (Eq. 13), evaluate the PANN-ified model
on a validation set, and keep the best-performing (b~x, R).

Two evaluation backends:
  * ``plan_with_eval``   — the paper's Algorithm 1 verbatim (needs an eval fn),
  * ``plan_with_theory`` — data-free fallback minimizing Eq. (19).

The planner is also the deployment-time knob: moving between equal-power
curves (Fig. 3) only changes (b~x, R) — no architecture change.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Sequence

from repro.core import mse as mse_theory
from repro.core import policy as pol
from repro.core import power as pw


@dataclasses.dataclass(frozen=True)
class PannPlan:
    power_budget: float      # per weight-MAC, bit flips
    b_x_tilde: int
    r: float
    score: float             # accuracy (eval backend) or -MSE (theory backend)
    candidates: tuple        # (b_x, r, score) for every candidate swept

    def describe(self, total_macs: Optional[float] = None) -> str:
        """``total_macs`` (network weight MACs per token) appends the total
        network price — MACs x per-MAC power — so uniform and layerwise
        plans compare in the same unit in logs."""
        text = (f"PANN plan @ P={self.power_budget:.1f} bit-flips/MAC: "
                f"b~x={self.b_x_tilde}, R={self.r:.2f} "
                f"(score {self.score:.4f})")
        if total_macs is not None:
            total = pw.giga(self.power_budget * total_macs)
            text += f" | total {total:.2f} Gbit-flips/token"
        return text


def candidate_bit_widths(power: float,
                         b_range: Sequence[int] = tuple(range(2, 9))
                         ) -> list[int]:
    """Bit widths for which the budget leaves a positive addition factor."""
    return [b for b in b_range if pw.pann_r_for_budget(power, b) > 0.05]


def plan_with_eval(power: float,
                   eval_fn: Callable[[int, float], float],
                   b_range: Sequence[int] = tuple(range(2, 9)),
                   ) -> PannPlan:
    """Algorithm 1. ``eval_fn(b_x_tilde, r) -> accuracy`` runs the quantized
    network on a validation set (lines 5-8)."""
    cands = []
    for b in candidate_bit_widths(power, b_range):
        r = pw.pann_r_for_budget(power, b)
        acc = float(eval_fn(b, r))
        cands.append((b, r, acc))
    if not cands:
        raise ValueError(f"power budget {power} too small for any bit width")
    b, r, acc = max(cands, key=lambda t: t[2])
    return PannPlan(power, b, r, acc, tuple(cands))


def plan_with_theory(power: float,
                     d: float = 4096.0,
                     b_range: Sequence[int] = tuple(range(2, 9)),
                     ) -> PannPlan:
    """Data-free planner: minimize the Eq. (19) MSE instead of evaluating."""
    cands = []
    for b in candidate_bit_widths(power, b_range):
        r = pw.pann_r_for_budget(power, b)
        m = mse_theory.mse_pann_at_budget(d, power, b)
        cands.append((b, r, -m))
    if not cands:
        raise ValueError(f"power budget {power} too small for any bit width")
    b, r, score = max(cands, key=lambda t: t[2])
    return PannPlan(power, b, r, score, tuple(cands))


def budget_from_bits(bits: int) -> float:
    """Power budget equal to a ``bits``-wide *unsigned* MAC (the paper's
    experimental protocol: PANN is always matched to the unsigned-MAC cost)."""
    return pw.p_mac_unsigned(bits)


def equal_power_curve(bits: int, b_range: Iterable[int] = range(2, 9)
                      ) -> list[tuple[int, float]]:
    """Fig. 3: (b~x, R) combinations matching a b_x-bit unsigned MAC."""
    p = budget_from_bits(bits)
    out = []
    for b in b_range:
        r = pw.pann_r_for_budget(p, b)
        if r > 0:
            out.append((b, r))
    return out


def plan_ladder(bits_ladder: Sequence[int] = (2, 3, 4, 6),
                d: float = 4096.0,
                b_range: Sequence[int] = tuple(range(2, 9)),
                eval_fn: Optional[Callable[[int, float], float]] = None,
                allocation: str = "uniform",
                profile: Optional[Sequence] = None,
                ) -> tuple:
    """The deployment ladder: one operating point per equal-power budget.

    For each unsigned-MAC bit budget in ``bits_ladder``, pick the best point
    on its Fig.-3 equal-power curve (Algorithm 1 when ``eval_fn`` is given,
    Eq.-19 theory otherwise). Returns plans sorted by ascending power — a
    pure function of its inputs, so ladder planning is deterministic and two
    servers configured alike materialize identical operating points.

    ``allocation="layerwise"`` (requires ``profile``, a
    ``costs.module_cost_profile``) returns ``LayerwisePlan``s instead: each
    rung spends the SAME total bit-flip budget non-uniformly across module
    paths via ``allocate_layerwise`` — every rung's power matches its
    uniform twin, its theory score never trails it.
    """
    if allocation not in ("uniform", "layerwise"):
        raise ValueError(f"unknown allocation {allocation!r}")
    if allocation == "layerwise" and profile is None:
        raise ValueError("layerwise allocation needs a module cost profile")
    if allocation == "layerwise" and eval_fn is not None:
        # never silently drop the eval backend: a per-(b,r) eval_fn cannot
        # score a tree; eval-backed layerwise planning takes a tree-level
        # judge via allocate_layerwise(eval_fn=tree -> score) directly
        raise ValueError(
            "plan_ladder(eval_fn=...) is the Algorithm-1 per-(b~x, R) "
            "backend and does not apply to layerwise allocation; call "
            "allocate_layerwise(..., eval_fn=tree -> score) instead")
    plans = []
    for bits in sorted({int(b) for b in bits_ladder}):
        p = budget_from_bits(bits)
        if allocation == "layerwise":
            plans.append(allocate_layerwise(p, profile, b_range=b_range))
        elif eval_fn is not None:
            plans.append(plan_with_eval(p, eval_fn, b_range))
        else:
            plans.append(plan_with_theory(p, d, b_range))
    return tuple(plans)


# ---------------------------------------------------------------------------
# Layer-wise power-budget allocation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerwisePlan:
    """A per-module spend of the network's total bit-flip budget.

    ``power_budget`` is the matched per-weight-MAC budget (same unit as
    ``PannPlan``): the plan's total power equals ``power_budget x
    total_macs`` — the SAME total as the uniform plan at this budget —
    spent non-uniformly across module paths.
    """
    power_budget: float          # per weight-MAC (matched to uniform)
    tree: pol.PolicyTree         # pann ModuleQuant per module path
    score: float                 # tree_theory_score (or eval_fn) of the tree
    uniform_score: float         # same metric, matched uniform tree
    uniform_tree: pol.PolicyTree
    total_macs: float            # weight MACs per token
    total_power: float           # bit flips per token (weight modules)
    per_module: tuple            # (path, macs, fan_in, b~x, R, p/MAC) rows

    def describe(self) -> str:
        total = pw.giga(self.total_power)
        gain = self.score - self.uniform_score
        return (f"layerwise plan @ P={self.power_budget:.1f} bit-flips/MAC "
                f"x {self.total_macs:.3e} MACs = {total:.2f} "
                f"Gbit-flips/token over {len(self.per_module)} modules "
                f"(score {self.score:.4f}, +{gain:.4f} vs uniform)")

    def bit_table(self) -> str:
        rows = [f"{'module':<16}{'MACs':>12}{'fan_in':>8}{'b~x':>5}"
                f"{'R':>8}{'bf/MAC':>8}{'Gbf/tok':>9}"]
        for path, macs, fan_in, b, r, p_mac in self.per_module:
            rows.append(f"{path:<16}{macs:>12.3e}{fan_in:>8d}{b:>5d}"
                        f"{r:>8.2f}{p_mac:>8.2f}"
                        f"{pw.giga(macs * p_mac):>9.3f}")
        return "\n".join(rows)


def _level_grid(power_budget: float, n_levels: int) -> list[float]:
    """Per-MAC power levels the knapsack moves between: geometric from just
    above the cheapest viable PANN point up to well past the budget (a
    module CAN exceed the per-MAC budget — that is the point of layerwise —
    as long as the network total stays inside)."""
    lo = pw.p_pann(0.25, 2)                      # 1.5 bit flips/MAC
    hi = max(4.0 * power_budget, pw.p_mac_unsigned(8))
    ratio = (hi / lo) ** (1.0 / (n_levels - 1))
    grid = [lo * ratio ** i for i in range(n_levels)]
    grid.append(float(power_budget))             # uniform point reachable
    return sorted(set(grid))


def _best_point_at(p: float, b_range: Sequence[int]
                   ) -> Optional[tuple[int, float, float]]:
    """Best (b~x, R, relative mse) on the equal-power curve at per-MAC
    power ``p`` — plan_with_theory's argmin, with the d=1 (signal-
    normalized) Eq.-18 MSE the tree score uses (see
    policy.tree_theory_score; the argmin over b is d-independent)."""
    best = None
    for b in b_range:
        r = pw.pann_r_for_budget(p, b)
        if r <= 0.05:
            continue
        m = mse_theory.mse_pann(1.0, b, r)
        if best is None or m < best[2]:
            best = (b, r, m)
    return best


# cache codes are unsigned affine with <= 7 planes (codes <= 127; see
# kernels/ref.CACHE_PLANES) — the allocator's cache ladder is the integer
# bit widths inside that envelope
CACHE_B_RANGE = tuple(range(2, 8))


def _cache_levels() -> list[tuple[float, int, float, float]]:
    """Candidate (per-MAC power, bits, R=0, relative mse) levels for a
    CACHE_PATHS pseudo-module: integer unsigned widths priced at
    ``p_mac_unsigned`` (the same split ``policy.tree_power_per_token``
    charges a cache-carrying tree) and scored with the Eq.-16 RUQ MSE at
    b_x = b_w = b (codes quantize both operand streams of the act x act
    MAC)."""
    return [(pw.p_mac_unsigned(b), b, 0.0, mse_theory.mse_ruq(1.0, b, b))
            for b in CACHE_B_RANGE]


def _uniform_cache_bits(power_budget: float) -> int:
    """Largest integer cache width an unsigned MAC at ``power_budget`` can
    pay for — the uniform twin's cache point (floor 2 keeps the twin
    constructible even under the smallest ladder budgets)."""
    fit = [b for b in CACHE_B_RANGE if pw.p_mac_unsigned(b)
           <= power_budget * (1 + 1e-9)]
    return max(fit) if fit else CACHE_B_RANGE[0]


def allocate_layerwise(power_budget: float,
                       profile: Sequence,
                       b_range: Sequence[int] = tuple(range(2, 9)),
                       n_levels: int = 48,
                       eval_fn: Optional[Callable] = None,
                       ) -> LayerwisePlan:
    """Spend ``power_budget x total_macs`` bit flips across modules.

    Greedy marginal-benefit knapsack over a shared grid of per-MAC power
    levels: every module starts at the cheapest viable PANN point; the
    upgrade with the best MSE-reduction per extra bit flip is applied until
    no upgrade fits the total budget. Two closing moves make the invariants
    (tests/test_policy_allocator.py) unconditional:

      * R-fill — the residual slack is spread over all modules as extra R
        at fixed b~x (Eq. 13 is linear in R), so total power equals the
        budget exactly, matching the uniform plan's total to float
        precision.
      * uniform fallback — if the greedy tree somehow scores below the
        matched uniform tree under ``tree_theory_score``, the uniform tree
        is returned instead: layerwise is never worse than uniform.

    ``eval_fn(tree) -> score`` mirrors ``plan_with_eval``: when given, the
    greedy and uniform candidate trees are both evaluated and the better
    one wins (the recorded score is then the eval score).

    ``profile`` is ``costs.module_cost_profile(cfg)`` (anything with
    .path/.macs/.fan_in works). Appending ``costs.cache_cost_modules`` rows
    puts the KV cache on the same knapsack: CACHE_PATHS entries move on the
    integer unsigned ladder (``_cache_levels``) instead of the PANN grid,
    and the closing R-fill — a PANN-only move (Eq. 13 has no cache
    analogue) — spreads the slack over the PANN modules alone.
    """
    modules = [m for m in profile if m.macs > 0]
    if not modules:
        raise ValueError("empty module cost profile")
    is_cache = [m.path in pol.CACHE_PATHS for m in modules]
    total_macs = sum(m.macs for m in modules)
    budget_total = power_budget * total_macs

    # the matched uniform twin: the global Algorithm-1 point everywhere
    # (cache roles: the widest integer width the budget pays for)
    uni = plan_with_theory(power_budget, b_range=b_range)
    uni_cache = pol.cache_module_quant(_uniform_cache_bits(power_budget))
    uniform_tree = pol.policy_tree(
        pol.pann_module_quant(uni.r, uni.b_x_tilde,
                              max(m.fan_in for m in modules)),
        {m.path: (uni_cache if c else
                  pol.pann_module_quant(uni.r, uni.b_x_tilde, m.fan_in))
         for m, c in zip(modules, is_cache)})

    # per-module candidate levels: (per-MAC power, b~x, R, mse), ascending
    grid = _level_grid(power_budget, n_levels)
    cands = []
    for m, c in zip(modules, is_cache):
        if c:
            cands.append(_cache_levels())
            continue
        levels = []
        for p in grid:
            pt = _best_point_at(p, b_range)
            if pt is not None:
                levels.append((p, pt[0], pt[1], pt[2]))
        if not levels:
            raise ValueError(
                f"power budget {power_budget} too small for any bit width "
                f"(module {m.path})")
        cands.append(levels)

    idx = [0] * len(modules)
    total = sum(m.macs * cands[i][0][0] for i, m in enumerate(modules))
    if total > budget_total * (1 + 1e-9):
        raise ValueError(
            f"power budget {power_budget} below the cheapest viable "
            f"layerwise plan ({total / total_macs:.2f} bit-flips/MAC)")
    # weight of one neuron's MSE: outputs per token = macs / fan_in
    w = [m.macs / max(float(m.fan_in), 1.0) for m in modules]
    while True:
        best, best_gain = None, 0.0
        for i, m in enumerate(modules):
            if idx[i] + 1 >= len(cands[i]):
                continue
            cur, nxt = cands[i][idx[i]], cands[i][idx[i] + 1]
            dcost = m.macs * (nxt[0] - cur[0])
            if total + dcost > budget_total * (1 + 1e-12):
                continue
            gain = w[i] * (cur[3] - nxt[3]) / max(dcost, 1e-30)
            if best is None or gain > best_gain:
                best, best_gain = i, gain
        if best is None:
            break
        total += modules[best].macs * (cands[best][idx[best] + 1][0]
                                       - cands[best][idx[best]][0])
        idx[best] += 1

    # R-fill: hand the residual slack to every PANN module as extra R at
    # fixed b~x — consumes the budget exactly and only lowers the Eq.-18
    # MSE. Cache modules sit on an integer ladder with no R axis, so they
    # keep their level and the slack goes to the PANN side.
    pann_macs = sum(m.macs for m, c in zip(modules, is_cache) if not c)
    slack_per_mac = (budget_total - total) / max(pann_macs, 1e-30)
    overrides = {}
    for i, (m, c) in enumerate(zip(modules, is_cache)):
        p, b, r, _ = cands[i][idx[i]]
        if c:
            overrides[m.path] = pol.cache_module_quant(b)
            continue
        p_eff = p + slack_per_mac
        overrides[m.path] = pol.pann_module_quant(
            pw.pann_r_for_budget(p_eff, b), b, m.fan_in)

    tree = pol.policy_tree(
        pol.pann_module_quant(uni.r, uni.b_x_tilde,
                              max(m.fan_in for m in modules)),
        overrides)

    score = pol.tree_theory_score(modules, tree)
    uniform_score = pol.tree_theory_score(modules, uniform_tree)
    if eval_fn is not None:
        score = float(eval_fn(tree))
        uniform_score = float(eval_fn(uniform_tree))
    if score < uniform_score:        # the unconditional guarantee
        tree, score = uniform_tree, uniform_score

    per_module = tuple(
        (m.path, m.macs, m.fan_in, tree.lookup(m.path).b_x_tilde,
         tree.lookup(m.path).r, tree.lookup(m.path).power_per_mac())
        for m in modules)
    total_power = sum(m.macs * tree.lookup(m.path).power_per_mac()
                      for m in modules)
    return LayerwisePlan(power_budget=power_budget, tree=tree, score=score,
                         uniform_score=uniform_score,
                         uniform_tree=uniform_tree,
                         total_macs=total_macs, total_power=total_power,
                         per_module=per_module)


def replan_for_rate(cap_bitflips_per_s: float,
                    tokens_per_s: float,
                    profile: Sequence,
                    b_range: Sequence[int] = tuple(range(2, 9)),
                    bits_envelope: tuple[int, int] = (2, 8),
                    ) -> LayerwisePlan:
    """Telemetry-driven replan: the per-MAC power budget a MEASURED token
    rate leaves under a fleet-wide bit-flips/sec cap, spent layerwise.

    This is the closed-loop heart of the fleet power governor
    (``repro.serve_engine.fleet``): aggregated ``EnergyLedger`` telemetry
    gives the fleet's realized tokens/sec; dividing the cap by (rate x
    total MACs/token) yields the affordable per-weight-MAC budget, which
    ``allocate_layerwise`` then spends across modules exactly as at plan
    time. The resulting plan's ``power_budget`` is what rung-ceiling
    selection compares against ladder rung powers.

    The budget is clamped to the constructible envelope
    ``[budget_from_bits(lo), budget_from_bits(hi)]`` — a cap far above
    what the traffic can spend replans at the top of the ladder instead
    of chasing unbounded R, and a cap below the cheapest viable point
    replans at the floor instead of raising from the knapsack.
    Deterministic: a pure function of its (finite) float inputs.
    """
    if cap_bitflips_per_s <= 0:
        raise ValueError(f"cap must be positive, got {cap_bitflips_per_s}")
    total_macs = sum(m.macs for m in profile if m.macs > 0)
    if total_macs <= 0:
        raise ValueError("empty module cost profile")
    rate = max(float(tokens_per_s), 1e-9)
    per_mac = cap_bitflips_per_s / (rate * total_macs)
    lo = budget_from_bits(bits_envelope[0])
    hi = budget_from_bits(bits_envelope[1])
    per_mac = min(max(per_mac, lo), hi)
    return allocate_layerwise(per_mac, profile, b_range=b_range)
