"""Algorithm 1: determining the optimal PANN parameters for a power budget.

Given a power budget P (per-weight-MAC, in bit flips), sweep the activation
bit width b~x, set R = P / b~x - 0.5 (Eq. 13), evaluate the PANN-ified model
on a validation set, and keep the best-performing (b~x, R).

Two evaluation backends:
  * ``plan_with_eval``   — the paper's Algorithm 1 verbatim (needs an eval fn),
  * ``plan_with_theory`` — data-free fallback minimizing Eq. (19).

The planner is also the deployment-time knob: moving between equal-power
curves (Fig. 3) only changes (b~x, R) — no architecture change.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Optional, Sequence

from repro.core import mse as mse_theory
from repro.core import power as pw


@dataclasses.dataclass(frozen=True)
class PannPlan:
    power_budget: float      # per weight-MAC, bit flips
    b_x_tilde: int
    r: float
    score: float             # accuracy (eval backend) or -MSE (theory backend)
    candidates: tuple        # (b_x, r, score) for every candidate swept

    def describe(self) -> str:
        return (f"PANN plan @ P={self.power_budget:.1f} bit-flips/MAC: "
                f"b~x={self.b_x_tilde}, R={self.r:.2f} "
                f"(score {self.score:.4f})")


def candidate_bit_widths(power: float,
                         b_range: Sequence[int] = tuple(range(2, 9))
                         ) -> list[int]:
    """Bit widths for which the budget leaves a positive addition factor."""
    return [b for b in b_range if pw.pann_r_for_budget(power, b) > 0.05]


def plan_with_eval(power: float,
                   eval_fn: Callable[[int, float], float],
                   b_range: Sequence[int] = tuple(range(2, 9)),
                   ) -> PannPlan:
    """Algorithm 1. ``eval_fn(b_x_tilde, r) -> accuracy`` runs the quantized
    network on a validation set (lines 5-8)."""
    cands = []
    for b in candidate_bit_widths(power, b_range):
        r = pw.pann_r_for_budget(power, b)
        acc = float(eval_fn(b, r))
        cands.append((b, r, acc))
    if not cands:
        raise ValueError(f"power budget {power} too small for any bit width")
    b, r, acc = max(cands, key=lambda t: t[2])
    return PannPlan(power, b, r, acc, tuple(cands))


def plan_with_theory(power: float,
                     d: float = 4096.0,
                     b_range: Sequence[int] = tuple(range(2, 9)),
                     ) -> PannPlan:
    """Data-free planner: minimize the Eq. (19) MSE instead of evaluating."""
    cands = []
    for b in candidate_bit_widths(power, b_range):
        r = pw.pann_r_for_budget(power, b)
        m = mse_theory.mse_pann_at_budget(d, power, b)
        cands.append((b, r, -m))
    if not cands:
        raise ValueError(f"power budget {power} too small for any bit width")
    b, r, score = max(cands, key=lambda t: t[2])
    return PannPlan(power, b, r, score, tuple(cands))


def budget_from_bits(bits: int) -> float:
    """Power budget equal to a ``bits``-wide *unsigned* MAC (the paper's
    experimental protocol: PANN is always matched to the unsigned-MAC cost)."""
    return pw.p_mac_unsigned(bits)


def equal_power_curve(bits: int, b_range: Iterable[int] = range(2, 9)
                      ) -> list[tuple[int, float]]:
    """Fig. 3: (b~x, R) combinations matching a b_x-bit unsigned MAC."""
    p = budget_from_bits(bits)
    out = []
    for b in b_range:
        r = pw.pann_r_for_budget(p, b)
        if r > 0:
            out.append((b, r))
    return out


def plan_ladder(bits_ladder: Sequence[int] = (2, 3, 4, 6),
                d: float = 4096.0,
                b_range: Sequence[int] = tuple(range(2, 9)),
                eval_fn: Optional[Callable[[int, float], float]] = None,
                ) -> tuple[PannPlan, ...]:
    """The deployment ladder: one best (b~x, R) point per equal-power curve.

    For each unsigned-MAC bit budget in ``bits_ladder``, pick the best point
    on its Fig.-3 equal-power curve (Algorithm 1 when ``eval_fn`` is given,
    Eq.-19 theory otherwise). Returns plans sorted by ascending power — a
    pure function of its inputs, so ladder planning is deterministic and two
    servers configured alike materialize identical operating points.
    """
    plans = []
    for bits in sorted({int(b) for b in bits_ladder}):
        p = budget_from_bits(bits)
        if eval_fn is not None:
            plans.append(plan_with_eval(p, eval_fn, b_range))
        else:
            plans.append(plan_with_theory(p, d, b_range))
    return tuple(plans)
