"""Power-budget annealing for quantization-aware training (DESIGN.md §9).

The curriculum starts training near full precision and tightens the
network's bit-flip budget at schedule knots, re-running the layer-wise
allocator (``planner.allocate_layerwise``) at every knot so each budget is
spent non-uniformly across module roles — training visits exactly the
per-module (b̃x, R) operating points the serving ladder deploys.

A schedule is a comma list of ``step:bits`` knots, bits being the
unsigned-MAC-equivalent budget of the equal-power protocol
(``planner.budget_from_bits``) or ``fp``/``0`` for an unquantized segment:

    "0:fp,200:8,600:6,900:4"

Everything here is a pure function of (schedule, model config): replanning
at a checkpoint resume reproduces the original PolicyTree bit-for-bit (the
allocator is deterministic Python float math), which is what makes
mid-anneal resume exact — asserted in tests/test_train_power.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import costs
from repro.core import planner
from repro.core import policy as pol
from repro.core import power as pw


def strip_quant(cfg: ModelConfig) -> ModelConfig:
    """The one definition of a full-precision forward config: no policy
    tree, global quant mode off. Used for fp annealing segments, for
    --train_quant none/ptq training, and as export's PTQ reference."""
    return dataclasses.replace(
        cfg, policy=None,
        quant=dataclasses.replace(cfg.quant, mode="none"))


@dataclasses.dataclass(frozen=True)
class Knot:
    step: int
    bits: int          # unsigned-MAC-equivalent budget; 0 = full precision


@dataclasses.dataclass(frozen=True)
class BudgetSchedule:
    """Ascending ``Knot``s; steps before the first knot run full precision."""
    knots: Tuple[Knot, ...]

    @classmethod
    def parse(cls, spec: str) -> "BudgetSchedule":
        knots = []
        for entry in spec.split(","):
            entry = entry.strip()
            if not entry:
                continue
            try:
                step_s, bits_s = entry.split(":")
                step = int(step_s)
                bits = 0 if bits_s.strip().lower() in ("fp", "none") \
                    else int(bits_s)
            except ValueError:
                raise ValueError(
                    f"bad budget-schedule entry {entry!r}; expected "
                    f"'step:bits' with bits an int or 'fp'") from None
            if step < 0 or bits < 0:
                raise ValueError(f"negative step/bits in {entry!r}")
            knots.append(Knot(step=step, bits=bits))
        if not knots:
            raise ValueError(f"empty budget schedule {spec!r}")
        steps = [k.step for k in knots]
        if sorted(set(steps)) != steps:
            raise ValueError(
                f"budget-schedule steps must be strictly ascending: {spec!r}")
        return cls(knots=tuple(knots))

    def bits_at(self, step: int) -> int:
        bits = 0
        for k in self.knots:
            if k.step <= step:
                bits = k.bits
            else:
                break
        return bits

    def segments(self, start: int, stop: int
                 ) -> Tuple[Tuple[int, int, int], ...]:
        """Constant-budget (seg_start, seg_end, bits) spans covering
        [start, stop) — the trainer jits one step function per span."""
        if stop <= start:
            return ()
        bounds = sorted({start, stop}
                        | {k.step for k in self.knots if start < k.step < stop})
        return tuple((s0, s1, self.bits_at(s0))
                     for s0, s1 in zip(bounds[:-1], bounds[1:]))

    def knot_steps(self) -> Tuple[int, ...]:
        """Steps at which the budget *changes* — LR re-warmup points."""
        out, prev = [], 0
        for k in self.knots:
            if k.bits != prev:
                out.append(k.step)
            prev = k.bits
        return tuple(s for s in out if s > 0)

    def describe(self) -> str:
        return " -> ".join(
            f"@{k.step}:{'fp' if k.bits == 0 else f'{k.bits}b'}"
            for k in self.knots)


class BudgetAnnealer:
    """Materializes the training config for each schedule segment.

    One allocator run per distinct budget (cached — the plan for 6 bits is
    the same object at step 600 and at a step-700 resume), spending the
    budget across module roles exactly like the serving ladder does, so the
    QAT forward and the exported artifact share their PolicyTrees.
    """

    def __init__(self, schedule: BudgetSchedule, cfg: ModelConfig,
                 allocation: str = "layerwise",
                 b_range: Sequence[int] = tuple(range(2, 9))):
        if allocation not in ("uniform", "layerwise"):
            raise ValueError(f"unknown allocation {allocation!r}")
        self.schedule = schedule
        self.allocation = allocation
        self.b_range = tuple(b_range)
        self.profile = costs.module_cost_profile(cfg)
        self._plans: dict[int, object] = {}

    def plan_for(self, bits: int):
        """The (cached) plan at an unsigned-MAC bit budget; None for fp."""
        if bits <= 0:
            return None
        if bits not in self._plans:
            budget = planner.budget_from_bits(bits)
            if self.allocation == "layerwise":
                self._plans[bits] = planner.allocate_layerwise(
                    budget, self.profile, b_range=self.b_range)
            else:
                self._plans[bits] = planner.plan_with_theory(
                    budget, b_range=self.b_range)
        return self._plans[bits]

    def tree_for(self, bits: int) -> Optional[pol.PolicyTree]:
        plan = self.plan_for(bits)
        if plan is None:
            return None
        if isinstance(plan, planner.LayerwisePlan):
            return plan.tree
        # uniform: the global Algorithm-1 point on every module, with each
        # module's own Eq.-20 accumulator width (same lift the ladder uses)
        return pol.policy_tree(
            pol.pann_module_quant(plan.r, plan.b_x_tilde,
                                  max(m.fan_in for m in self.profile)),
            {m.path: pol.pann_module_quant(plan.r, plan.b_x_tilde, m.fan_in)
             for m in self.profile})

    def config_at(self, cfg: ModelConfig, step: int
                  ) -> Tuple[ModelConfig, Optional[object], int]:
        """(training config, plan, bits) governing ``step``.

        fp segments strip quantization from the forward entirely; quantized
        segments install the allocator's PolicyTree (mode comes from the
        tree's per-module ModuleQuants — all 'pann').
        """
        bits = self.schedule.bits_at(step)
        plan = self.plan_for(bits)
        if plan is None:
            return strip_quant(cfg), None, bits
        return dataclasses.replace(cfg, policy=self.tree_for(bits)), plan, \
            bits

    @classmethod
    def from_train_config(cls, cfg: ModelConfig, tcfg
                          ) -> Optional["BudgetAnnealer"]:
        """The one construction path shared by the trainer and the exporter
        — both must materialize the SAME annealer from a TrainConfig or the
        exported operating point drifts from the trained one."""
        if not tcfg.budget_schedule:
            return None
        return cls(BudgetSchedule.parse(tcfg.budget_schedule), cfg,
                   allocation=tcfg.budget_allocation)

    def gbitflips_per_token(self, bits: int) -> float:
        """Planned network power at a knot (Gbit-flips/token, weight MACs)
        — the train-smoke CI gate compares this against its baseline."""
        plan = self.plan_for(bits)
        if plan is None:
            return 0.0
        if isinstance(plan, planner.LayerwisePlan):
            return pw.giga(plan.total_power)
        total_macs = sum(m.macs for m in self.profile)
        return pw.giga(plan.power_budget * total_macs)
