"""Bit-flip (switching-activity) simulators, vectorized with NumPy.

These reproduce the paper's "Python simulation" (App. A.2): dynamic power is
proportional to the number of bit toggles between consecutive instructions, so
we simulate the register/adder-input states of

  * a serial (long-multiplication) multiplier,
  * a radix-2 Booth-encoded multiplier,
  * a serial accumulator (adder + FF register),

and count `popcount(state_t XOR state_{t-1})` over all state words.

Conventions (matching App. A.2/A.4):
  * signed operands are drawn from [-2^(b-1), 2^(b-1)),
  * unsigned operands from [0, 2^(b-1)) — half range, so the *same* signed
    multiplier architecture can be reused (App. A.4),
  * a b_w x b_x multiply is simulated on a b x b multiplier with
    b = max(b_w, b_x); the *selecting* (recoded) operand is the activation and
    the *added* word is the weight, per the paper's long-multiplication
    description ("each bit of the multiplicand multiplies the multiplier word").

The simulators are the measurement instrument; the closed-form models the
paper fits to them live in ``repro.core.power``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

MultKind = Literal["serial", "booth"]


def _mask(width: int) -> int:
    return (1 << width) - 1


def popcount_xor(prev: np.ndarray, curr: np.ndarray, width: int) -> np.ndarray:
    """Per-element toggle count between two register states of ``width`` bits."""
    diff = np.bitwise_xor(prev, curr) & np.int64(_mask(width))
    return np.bitwise_count(diff.astype(np.uint64))


# ---------------------------------------------------------------------------
# Operand sampling
# ---------------------------------------------------------------------------

def draw_uniform_signed(rng: np.random.Generator, bits: int, n: int) -> np.ndarray:
    return rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), size=n, dtype=np.int64)


def draw_uniform_unsigned(rng: np.random.Generator, bits: int, n: int) -> np.ndarray:
    # Half range [0, 2^(b-1)) so the signed architecture is reused (App. A.4).
    return rng.integers(0, 1 << (bits - 1), size=n, dtype=np.int64)


def draw_gaussian(rng: np.random.Generator, bits: int, n: int,
                  signed: bool = True) -> np.ndarray:
    """App. A.2: N(0,1) scaled to the b-bit range, rounded, clipped."""
    z = rng.standard_normal(n)
    z = z / np.max(np.abs(z))
    if signed:
        v = np.clip(np.rint(z * (1 << (bits - 1))), -(1 << (bits - 1)),
                    (1 << (bits - 1)) - 1)
    else:
        v = np.clip(np.rint(np.abs(z) * ((1 << (bits - 1)) - 1)), 0,
                    (1 << (bits - 1)) - 1)
    return v.astype(np.int64)


# ---------------------------------------------------------------------------
# Multiplier
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiplierStats:
    internal_toggles: float   # adder-array inputs (partial-product rows)
    input_toggles: float      # the two operand registers
    n_ops: int

    @property
    def total(self) -> float:
        return self.internal_toggles + self.input_toggles


def _booth_digits(x: np.ndarray, bits: int) -> np.ndarray:
    """Radix-2 Booth recoding digits d_i = x_{i-1} - x_i in {-1, 0, +1}.

    Returns an array of shape (n, bits) of int64 digits.
    """
    xu = (x & np.int64(_mask(bits))).astype(np.uint64)
    shifts = np.arange(bits, dtype=np.uint64)
    cur = ((xu[:, None] >> shifts) & np.uint64(1)).astype(np.int64)
    prev = np.concatenate(
        [np.zeros((x.shape[0], 1), dtype=np.int64), cur[:, :-1]], axis=1)
    return prev - cur


def _serial_digits(x: np.ndarray, bits: int) -> np.ndarray:
    """Plain long-multiplication digits: bit i of x, in {0, 1}."""
    xu = (x & np.int64(_mask(bits))).astype(np.uint64)
    shifts = np.arange(bits, dtype=np.uint64)
    return ((xu[:, None] >> shifts) & np.uint64(1)).astype(np.int64)


def simulate_multiplier(
    w: np.ndarray,
    x: np.ndarray,
    b_w: int,
    b_x: int,
    kind: MultKind = "booth",
) -> MultiplierStats:
    """Count toggles across a stream of multiplies w[t] * x[t].

    The simulated array is b x b with b = max(b_w, b_x). The partial-product
    rows (the adder-array inputs) are registered as 2b-bit two's-complement
    words; the operand registers are b_w / b_x bits.
    """
    assert w.shape == x.shape
    b = max(b_w, b_x)
    out_bits = 2 * b

    digits = (_booth_digits if kind == "booth" else _serial_digits)(x, b)
    # rows[t, i] = (d_i * w) << i, as a 2b-bit word.
    rows = (digits * w[:, None]) << np.arange(b, dtype=np.int64)[None, :]
    rows &= np.int64(_mask(out_bits))

    internal = popcount_xor(rows[:-1], rows[1:], out_bits).sum(axis=1)
    inp = (popcount_xor(w[:-1], w[1:], b_w)
           + popcount_xor(x[:-1], x[1:], b_x))
    n = w.shape[0] - 1
    return MultiplierStats(float(internal.sum()) / n, float(inp.sum()) / n, n)


# ---------------------------------------------------------------------------
# Accumulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AccumulatorStats:
    input_toggles: float   # toggles at the adder input fed by the multiplier
    sum_toggles: float     # toggles at the adder output
    ff_toggles: float      # toggles in the FF register holding the sum
    n_ops: int

    @property
    def total(self) -> float:
        return self.input_toggles + self.sum_toggles + self.ff_toggles


def simulate_accumulator(addends: np.ndarray, acc_bits: int = 32,
                         count_input_changes: np.ndarray | None = None
                         ) -> AccumulatorStats:
    """Count toggles of a B-bit accumulator over a stream of addends.

    ``count_input_changes``: optional bool mask, True where the adder *input*
    register is rewritten before op t (PANN holds the input fixed for Q_w(w_i)
    consecutive additions, so only d of the R*d additions rewrite it).
    """
    a = addends.astype(np.int64)
    sums = np.cumsum(a.astype(object)) if acc_bits > 62 else np.cumsum(a)
    sums = (np.asarray(sums, dtype=np.int64)) & np.int64(_mask(acc_bits))

    inp = popcount_xor(a[:-1], a[1:], acc_bits)
    if count_input_changes is not None:
        inp = inp * count_input_changes[1:].astype(np.int64)
    s_tog = popcount_xor(sums[:-1], sums[1:], acc_bits)
    n = a.shape[0] - 1
    return AccumulatorStats(float(inp.sum()) / n, float(s_tog.sum()) / n,
                            float(s_tog.sum()) / n, n)


# ---------------------------------------------------------------------------
# End-to-end streams
# ---------------------------------------------------------------------------

def simulate_mac_stream(w: np.ndarray, x: np.ndarray, b_w: int, b_x: int,
                        acc_bits: int = 32, kind: MultKind = "booth"
                        ) -> float:
    """Average bit flips per MAC of the full multiply-accumulate datapath."""
    mult = simulate_multiplier(w, x, b_w, b_x, kind=kind)
    acc = simulate_accumulator(w * x, acc_bits)
    return mult.total + acc.total


def simulate_pann_stream(w_q: np.ndarray, x_q: np.ndarray, acc_bits: int = 32
                         ) -> tuple[float, float]:
    """Simulate PANN's Eq. (11): each product w_q[i] * x_q[i] is realized as
    w_q[i] repeated additions of x_q[i] (w_q must be non-negative ints).

    Returns (bit flips per input element, average additions per element R).
    """
    assert np.all(w_q >= 0)
    reps = w_q.astype(np.int64)
    addends = np.repeat(x_q.astype(np.int64), reps)
    # The accumulator input register is rewritten only when moving to the next
    # input element (d times in total).
    changes = np.zeros(addends.shape[0], dtype=bool)
    changes[np.cumsum(reps)[:-1][reps[:-1] > 0]] = True
    changes[0] = True
    acc = simulate_accumulator(addends, acc_bits, count_input_changes=changes)
    d = w_q.shape[0]
    n_adds = addends.shape[0]
    per_element = acc.total * (n_adds - 1) / max(d, 1)
    return per_element, n_adds / max(d, 1)
