"""Section 4: switching to unsigned arithmetic.

Any linear layer y = Wx + b with non-negative inputs (post-ReLU / post-quant
activations) splits exactly into two unsigned passes (Eq. 5-6):

    y+ = W+ x + b+,  y- = W- x + b-,  y = y+ - y-,
    W+ = ReLU(W), W- = ReLU(-W).

This changes nothing numerically (one extra subtraction per output element)
but removes the accumulator sign-extension toggling — Observation 1.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def unsigned_split(w: Array) -> Tuple[Array, Array]:
    """W -> (W+, W-), both non-negative, with W = W+ - W-."""
    return jnp.maximum(w, 0.0), jnp.maximum(-w, 0.0)


def unsigned_matmul(x: Array, w: Array, bias: Array | None = None) -> Array:
    """Exactly y = x @ W (+ bias), computed as two unsigned passes.

    ``x`` must be non-negative for the MACs to be genuinely unsigned; the
    function itself is exact regardless.
    """
    w_pos, w_neg = unsigned_split(w)
    y = x @ w_pos - x @ w_neg
    if bias is not None:
        y = y + bias
    return y


def is_unsigned_exact(x: Array, w: Array, rtol: float = 1e-5) -> bool:
    """Self-check helper: the split must match the direct product."""
    ref = x @ w
    got = unsigned_matmul(x, w)
    return bool(jnp.allclose(ref, got, rtol=rtol, atol=1e-5))
