"""Section 5: PANN — power-aware weight quantization + multiplier removal.

Weights are quantized with step gamma_w = ||w||_1 / (R d) (Eq. 12) so that the
*average number of additions per input element* equals the budget R; each
product Q_w(w) * Q_x(x) is then realizable as Q_w(w) repeated additions
(Eq. 10), i.e. no multiplier is needed.

TPU adaptation (see DESIGN.md §2): after the Sec.-4 unsigned split, the
non-negative integer weights need only b_R = ceil(log2(max w_q + 1)) bits, so
we decompose them into binary bit-planes

    w_q = sum_k 2^k B_k,   B_k in {0,1}
    w_q^T x = sum_k 2^k (B_k^T x)

and every plane-product B_k^T x is a pure addition network. This is exactly
Eq. (10) restructured for a systolic array and is bit-for-bit identical to the
repeated-addition semantics. ``repro.kernels.pann_matmul`` implements it as a
Pallas kernel; this module holds the model-level (jnp) definitions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.unsigned import unsigned_split

Array = jax.Array


# ---------------------------------------------------------------------------
# Weight quantization (Eq. 12)
# ---------------------------------------------------------------------------

def pann_gamma(w: Array, r: float, axis=None, eps: float = 1e-12) -> Array:
    """gamma_w = ||w||_1 / (R d), per-tensor (axis=None) or per-axis.

    ``axis`` indicates the *reduction* (fan-in) dimensions — the d in Eq. (12).
    Per-output-channel quantization (Table 14 measures per-neuron addition
    factors) passes the fan-in axis here.
    """
    dims = quant._reduce_dims(w, axis)
    d = 1
    for a in dims:
        d *= w.shape[a]
    l1 = jnp.sum(jnp.abs(w), axis=dims, keepdims=True)
    return jnp.maximum(l1, eps) / (r * d)


def pann_quantize(w: Array, r: float, axis=None) -> Tuple[Array, Array]:
    """Eq. (12): Q(w) = round(w / gamma_w). Returns (signed int codes, gamma).

    Codes are float-typed integers (exact for |code| < 2^24 in fp32).
    """
    gamma = pann_gamma(w, r, axis)
    return jnp.round(w / gamma), gamma


def pann_fake_quant(w: Array, r: float, axis=None) -> Array:
    """STE fake-quant with the PANN step — used for QAT."""
    q, gamma = pann_quantize(w, r, axis)
    wq = q * gamma
    return w + jax.lax.stop_gradient(wq - w)


def additions_per_element(w_q: Array, axis=None) -> Array:
    """||w_q||_1 / d — the realized addition factor (should be ~R)."""
    dims = quant._reduce_dims(w_q, axis)
    d = 1
    for a in dims:
        d *= w_q.shape[a]
    return jnp.sum(jnp.abs(w_q), axis=dims) / d


def weight_storage_bits(w_q: Array) -> int:
    """b_R: bits needed to store |w_q| after the unsigned split (Table 14)."""
    m = int(jnp.max(jnp.abs(w_q)))
    return max(int(jnp.ceil(jnp.log2(m + 1))), 1) if m > 0 else 1


# ---------------------------------------------------------------------------
# Bit-plane decomposition (TPU-native Eq. 10)
# ---------------------------------------------------------------------------

def bitplane_decompose(w_q_nonneg: Array, n_planes: Optional[int] = None
                       ) -> Array:
    """Non-negative integer weights -> stacked binary planes.

    Returns planes of shape (n_planes, *w.shape), plane k holding bit k, so
    that w_q = sum_k 2^k planes[k].
    """
    wi = w_q_nonneg.astype(jnp.int32)
    if n_planes is None:
        n_planes = int(weight_storage_bits(w_q_nonneg))
    ks = jnp.arange(n_planes, dtype=jnp.int32)
    planes = (wi[None, ...] >> ks.reshape((-1,) + (1,) * wi.ndim)) & 1
    return planes.astype(jnp.int8)


def truncate_codes(codes: Array, shift) -> Array:
    """Rung view of max-R signed codes: sign(c) * (|c| >> shift), int32.

    Because the unsigned split puts |c| entirely in one of pos/neg, this
    equals dropping the low ``shift`` bit-planes of BOTH plane stacks —
    the truncation-consistent scheme (DESIGN.md §11): the rung-b codes are
    by construction the top planes of the max-R codes, and the rung step
    is gamma_R * 2^shift. ``shift`` may be a traced integer scalar.
    """
    ci = jnp.asarray(codes).astype(jnp.int32)
    sh = jnp.asarray(shift, jnp.int32)
    return ((jnp.maximum(ci, 0) >> sh)
            - (jnp.maximum(-ci, 0) >> sh))


def masked_codes(codes: Array, shift) -> Array:
    """``truncate_codes(c, s) << s`` — the integer weight a plane-skipping
    kernel realizes when it keeps the STATIC plane weights 2^p and skips
    planes p < shift over the max-R plane stacks. Still int8-range
    (|masked| <= |c| <= 127), and dequantizes with the UNCHANGED max-R
    gamma: masked * gamma_R == truncated * (gamma_R * 2^shift)."""
    sh = jnp.asarray(shift, jnp.int32)
    return truncate_codes(codes, sh) << sh


def view_shift(r_max: float, r: float, max_shift: int = 6) -> int:
    """Plane shift realizing budget ``r`` as a view over a max-``r_max``
    store: the power of two nearest r_max / r, clipped to the plane count.
    The rung then runs at ``snapped_r(r_max, shift)`` — the truncation-
    consistent scheme trades exact per-rung budgets for one shared weight
    store (DESIGN.md §11); the accuracy cost of the snap is measured by
    ``benchmarks/artifact_parity.py``."""
    if r <= 0 or r_max <= 0:
        raise ValueError(f"budgets must be positive: r_max={r_max}, r={r}")
    return int(min(max(round(math.log2(r_max / r)), 0), max_shift))


def snapped_r(r_max: float, shift: int) -> float:
    """The budget a ``shift``-plane view actually realizes: r_max / 2^s."""
    return float(r_max) / float(1 << int(shift))


def bitplane_matmul(x: Array, planes_pos: Array, planes_neg: Array,
                    out_dtype=jnp.float32) -> Array:
    """y = x @ (W+ - W-) where W± are given as binary planes.

    Every plane product is an addition-only pass (binary matrix x vector);
    plane results are combined with shifts (powers of two) — the multiplier-
    free dataflow of Eq. (10) + the Sec.-4 split of Eq. (5)-(6).
    """
    n_planes = planes_pos.shape[0]
    weights = (2.0 ** jnp.arange(n_planes)).astype(out_dtype)

    def plane_term(k, acc):
        pp = planes_pos[k].astype(out_dtype)
        pn = planes_neg[k].astype(out_dtype)
        return acc + weights[k] * (x @ pp - x @ pn)

    y0 = jnp.zeros(x.shape[:-1] + (planes_pos.shape[-1],), out_dtype)
    return jax.lax.fori_loop(0, n_planes, plane_term, y0)


# ---------------------------------------------------------------------------
# Full PANN linear op
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PannWeights:
    """Deployment artifact: quantized signed codes split into ± planes."""
    w_q: Array          # signed integer codes (float-typed)
    gamma: Array        # quantization step(s)
    r: float            # budget used


def pann_prepare(w: Array, r: float, axis=None) -> PannWeights:
    w_q, gamma = pann_quantize(w, r, axis)
    return PannWeights(w_q=w_q, gamma=gamma, r=r)


def pann_matmul_reference(x: Array, pw: PannWeights,
                          act_bits: int, act_signed: bool = False,
                          act_scale: Optional[Array] = None) -> Array:
    """Integer-exact PANN product: quantize activations, integer matmul with
    the quantized weights (the mathematical result of Eq. 11), rescale.
    """
    x_q, s_x = quant.ruq(x, act_bits, act_signed, scale=act_scale)
    y_int = x_q @ pw.w_q
    # gamma has keepdims shape (1, d_out) (per-channel) or (1, 1) (per-tensor);
    # flatten so it broadcasts against (..., d_out)
    return y_int * s_x * pw.gamma.reshape(-1)


def pann_qat_matmul(x: Array, w: Array, mq, act_range: Optional[Array] = None
                    ) -> Array:
    """The QAT (STE) branch of a PANN projection at one *module's* operating
    point — the per-module generalization the PolicyTree machinery feeds.

    ``mq`` is anything exposing ``.r`` / ``.act_bits_tilde`` (a per-module
    ``core.policy.ModuleQuant`` or the global ``QuantConfig``), so training
    runs the exact (b̃x, R) points the serving ladder deploys.  ``act_range``
    is an optional calibrated [lo, hi] pair (``core.calibrate``): when given
    (and seen), activations quantize against the frozen EMA range — the same
    numbers ``models.serving`` freezes into the export artifact; when absent
    or unseen the dynamic per-tensor range applies, bit-exact with the
    pre-calibration behavior.

    Cast discipline matches ``models.layers.qlinear``: fake-quant in fp32,
    matmul in the caller's compute dtype.
    """
    dtype = x.dtype
    wq = pann_fake_quant(w.astype(jnp.float32), mq.r, axis=0).astype(dtype)
    xf = x.astype(jnp.float32)
    n = float((1 << mq.act_bits_tilde) - 1)
    if act_range is None:
        q, s, z = quant.affine_quant_levels(xf, n)
    else:
        q, s, z = quant.affine_from_range(xf, n, act_range[0], act_range[1])
    xq_val = s * (q - z)
    xq = (xf + jax.lax.stop_gradient(xq_val - xf)).astype(dtype)
    return xq @ wq


def pann_linear(x: Array, w: Array, bias: Optional[Array], r: float,
                act_bits: int, *, axis=0, qat: bool = False) -> Array:
    """Model-level PANN linear layer.

    qat=True  -> differentiable fake-quant path (STE on weights + activations).
    qat=False -> same values, computed via explicit integer codes (PTQ eval).
    Both produce identical forward numerics up to float association.
    """
    if qat:
        wq = pann_fake_quant(w, r, axis=axis)
        xq = quant.fake_quant(x, act_bits, signed=False)
        y = xq @ wq
    else:
        w_q, gamma = pann_quantize(w, r, axis=axis)
        x_q, s_x = quant.ruq(x, act_bits, signed=False)
        y = (x_q @ (w_q * gamma)) * s_x
    if bias is not None:
        y = y + bias
    return y


def pann_bitplane_linear(x: Array, pw: PannWeights, act_bits: int,
                         bias: Optional[Array] = None) -> Array:
    """Deployment forward through bit-planes — numerically identical to
    ``pann_matmul_reference`` (integer-exact), multiplier-free dataflow."""
    x_q, s_x = quant.ruq(x, act_bits, signed=False)
    pos, neg = unsigned_split(pw.w_q)
    n_planes = weight_storage_bits(pw.w_q)
    planes_pos = bitplane_decompose(pos, n_planes)
    planes_neg = bitplane_decompose(neg, n_planes)
    y_int = bitplane_matmul(x_q, planes_pos, planes_neg)
    y = y_int * s_x * pw.gamma.reshape(-1)
    if bias is not None:
        y = y + bias
    return y
