"""Quantizers: RUQ (regular uniform quantizer), clip-calibrated quantization
(ACIQ-style), and LSQ (learned step size), all as pure-JAX functions.

Everything supports both "true integer" mode (returns integer codes + scale,
used for PTQ evaluation and the Pallas kernels) and "fake quant" mode (STE;
used inside differentiable forward passes for QAT).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QRange:
    """Integer code range [qmin, qmax]."""
    qmin: int
    qmax: int

    @property
    def n_levels(self) -> int:
        return self.qmax - self.qmin + 1


def qrange(bits: int, signed: bool, half_range: bool = False) -> QRange:
    """Code range of a ``bits``-wide quantizer.

    ``half_range=True`` follows the paper's App. A.4 convention for unsigned
    values on signed hardware: only [0, 2^(b-1)) is used.
    """
    if signed:
        return QRange(-(1 << (bits - 1)), (1 << (bits - 1)) - 1)
    if half_range:
        return QRange(0, (1 << (bits - 1)) - 1)
    return QRange(0, (1 << bits) - 1)


def _reduce_dims(x: Array, axis) -> tuple:
    if axis is None:
        return tuple(range(x.ndim))
    if isinstance(axis, int):
        axis = (axis,)
    return tuple(a % x.ndim for a in axis)


# ---------------------------------------------------------------------------
# RUQ — regular uniform quantizer (absmax / minmax scale)
# ---------------------------------------------------------------------------

def ruq_scale(x: Array, bits: int, signed: bool, axis=None,
              half_range: bool = False, eps: float = 1e-12) -> Array:
    """Per-tensor (axis=None) or per-axis absmax scale."""
    qr = qrange(bits, signed, half_range)
    dims = _reduce_dims(x, axis)
    if signed:
        # symmetric convention: +amax maps exactly to qmax, so the
        # quantization error is bounded by scale/2 everywhere
        amax = jnp.max(jnp.abs(x), axis=dims, keepdims=True)
        return jnp.maximum(amax, eps) / qr.qmax
    amax = jnp.max(jnp.maximum(x, 0.0), axis=dims, keepdims=True)
    return jnp.maximum(amax, eps) / qr.qmax


def quantize(x: Array, scale: Array, qr: QRange) -> Array:
    """Map reals to integer codes (round + clip). Returns float-typed codes."""
    q = jnp.round(x / scale)
    return jnp.clip(q, qr.qmin, qr.qmax)


def dequantize(q: Array, scale: Array) -> Array:
    return q * scale


def ruq(x: Array, bits: int, signed: bool, axis=None,
        scale: Optional[Array] = None, half_range: bool = False
        ) -> Tuple[Array, Array]:
    """Quantize to integer codes, returning (codes, scale)."""
    qr = qrange(bits, signed, half_range)
    if scale is None:
        scale = ruq_scale(x, bits, signed, axis, half_range)
    return quantize(x, scale, qr), scale


def fake_quant(x: Array, bits: int, signed: bool, axis=None,
               scale: Optional[Array] = None, half_range: bool = False
               ) -> Array:
    """Straight-through-estimator fake quantization: forward = dequant(quant),
    backward = identity (within the clip range, via the STE trick)."""
    q, s = ruq(x, bits, signed, axis, scale, half_range)
    xq = dequantize(q, s)
    return x + jax.lax.stop_gradient(xq - x)


def affine_quant_levels(x: Array, n, include_zero: bool = False
                        ) -> Tuple[Array, Array, Array]:
    """Asymmetric (zero-point) quantization: x ~ s * (q - z), q in [0, n].

    The ONE copy of the affine numerics, shared by the model-level fake-quant
    path (``models.layers``) and the integer serving backends
    (``kernels.dispatch``) — the zero point z absorbs signed activations so
    the integer codes q stay unsigned (DESIGN.md §4). ``n`` (the level count
    2^b - 1) may be a Python int or a traced array. Returns (q, s, z) with q
    float-typed exact integers.

    ``include_zero`` extends the calibration range to contain 0 (the
    TFLite/gemmlowp convention), which bounds the zero point to z in
    [0, n]. The integer backends REQUIRE this: an activation tensor that
    does not span zero (e.g. post-ReLU values near 100) otherwise yields
    |z| ~ |lo|/s far outside int32, and z-derived integer corrections wrap.
    The fp fake-quant paths keep the legacy unextended range.
    """
    lo, hi = act_range_bounds(x, include_zero=include_zero)
    return _affine_from_bounds(x, n, lo, hi)


def act_range_bounds(x: Array, lo=None, hi=None, include_zero: bool = True
                     ) -> Tuple[Array, Array]:
    """The calibration-range derivation of the affine quantizers, split out
    so consumers that only need the (s, z) scalars — the Pallas fused-
    prologue kernels quantize tile-locally in VMEM and must agree with the
    jnp oracle on the EXACT same bounds — share one copy of it.

    Without ``lo``/``hi``: the tensor's own extremes, optionally zero-
    extended (``affine_quant_levels`` semantics). With them: the frozen-
    range semantics of ``affine_from_range``, including the unseen sentinel
    (lo > hi falls back to dynamic extremes WITHOUT the zero extension).
    """
    if lo is None:
        lo_t = jnp.min(x)
        hi_t = jnp.max(x)
        if include_zero:
            lo_t = jnp.minimum(lo_t, 0.0)
            hi_t = jnp.maximum(hi_t, 0.0)
        return lo_t, hi_t
    lo = jnp.asarray(lo, x.dtype)
    hi = jnp.asarray(hi, x.dtype)
    use = lo <= hi
    if include_zero:
        lo = jnp.minimum(lo, 0.0)
        hi = jnp.maximum(hi, 0.0)
    lo = jnp.where(use, lo, jnp.min(x))
    hi = jnp.where(use, hi, jnp.max(x))
    return lo, hi


def affine_scale_zp(lo: Array, hi: Array, n) -> Tuple[Array, Array]:
    """(s, z) of the affine quantizer for calibration bounds [lo, hi] —
    the scalar half of ``_affine_from_bounds``, exposed so the serving
    artifact build (``models/serving``) can precompute frozen-range scales
    once instead of re-deriving them per decode step, with the SAME fp32
    op sequence the trace-time path uses (the hoist stays bit-exact)."""
    s = jnp.maximum((hi - lo) / n, 1e-12)
    z = jnp.round(-lo / s)
    return s, z


def cap_levels(bits: int, cap: int = 127) -> int:
    """Serving-side level count for a ``bits``-wide unsigned code: 2^b - 1
    capped so codes stay int8-safe. The ONE derivation shared by the
    serving quantizer, the KV cache, and the kernel dispatch — the number
    of live cache bit-planes is recovered from it as log2(n_lvl + 1)."""
    return min((1 << int(bits)) - 1, cap)


def affine_encode(x: Array, s: Array, z: Array, n) -> Array:
    """Map reals to affine codes ``clip(round(x/s) + z, 0, n)`` for a
    precomputed (s, z) — float-typed exact integers. This op sequence is
    replicated VERBATIM inside the fused-prologue Pallas kernels
    (``kernels/pann_matmul*``): change it there if you change it here, or
    the cross-backend bit-exactness contract breaks."""
    return jnp.clip(jnp.round(x / s) + z, 0, n)


def _affine_from_bounds(x: Array, n, lo: Array, hi: Array
                        ) -> Tuple[Array, Array, Array]:
    s, z = affine_scale_zp(lo, hi, n)
    return affine_encode(x, s, z, n), s, z


def affine_from_range(x: Array, n, lo, hi, include_zero: bool = True
                      ) -> Tuple[Array, Array, Array]:
    """``affine_quant_levels`` with an explicit calibration range [lo, hi]
    instead of the tensor's own extremes — the frozen-range path used by
    calibrated QAT (EMA activation observers, ``core.calibrate``), by
    exported serving artifacts (``act_lo``/``act_hi`` leaves), and by the
    integer kernel backends, so every consumer shares one copy of the math
    and quantizes a calibrated role against the SAME effective range.

    ``include_zero`` (default on — every frozen-range consumer must agree)
    extends a *seen* range to contain 0, the same TFLite convention as
    ``affine_quant_levels(include_zero=True)``: it bounds z to [0, n],
    which the integer backends require for int32 safety, so the fp paths
    adopt it too or the export round-trip would validate numerics the
    kernels don't serve.

    An *unseen* range (lo > hi — the calibration sentinel) falls back to
    the tensor's dynamic extremes WITHOUT the zero extension, bit-exact
    with ``affine_quant_levels(x, n)``: calibration warm-up is numerically
    the pre-calibration behavior.
    """
    lo, hi = act_range_bounds(x, lo, hi, include_zero=include_zero)
    return _affine_from_bounds(x, n, lo, hi)


# ---------------------------------------------------------------------------
# Clip-calibrated quantization (ACIQ-style)
# ---------------------------------------------------------------------------

def calibrate_clip(x: Array, bits: int, signed: bool,
                   n_grid: int = 64) -> Array:
    """Pick the clipping threshold minimizing quantization MSE on a
    calibration tensor — the data-driven analogue of ACIQ (Banner et al. 2019).

    Returns a scalar clip value c; the quantizer then uses scale = c / qmax.
    """
    qr = qrange(bits, signed)
    amax = jnp.max(jnp.abs(x)) if signed else jnp.max(jnp.maximum(x, 0.0))
    ratios = jnp.linspace(0.05, 1.0, n_grid)

    def mse_for(ratio):
        c = amax * ratio
        s = c / max(-qr.qmin, qr.qmax) if signed else c / qr.qmax
        s = jnp.maximum(s, 1e-12)
        xq = dequantize(quantize(x, s, qr), s)
        return jnp.mean((x - xq) ** 2)

    mses = jax.vmap(mse_for)(ratios)
    return amax * ratios[jnp.argmin(mses)]


def clip_quant(x: Array, bits: int, signed: bool, clip: Array
               ) -> Tuple[Array, Array]:
    """Quantize with a pre-calibrated clip value."""
    qr = qrange(bits, signed)
    s = jnp.maximum(clip / qr.qmax, 1e-12)
    return quantize(x, s, qr), s


# ---------------------------------------------------------------------------
# LSQ — learned step size quantization (Esser et al. 2019)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quant(x: Array, step: Array, qmin: int, qmax: int) -> Array:
    """LSQ fake-quant with the paper's gradient w.r.t. the step size."""
    q = jnp.clip(jnp.round(x / step), qmin, qmax)
    return q * step


def _lsq_fwd(x, step, qmin, qmax):
    v = x / step
    q = jnp.clip(jnp.round(v), qmin, qmax)
    return q * step, (v, q, step, x.size)


def _lsq_bwd(qmin, qmax, res, g):
    v, q, step, n = res
    in_range = (v >= qmin) & (v <= qmax)
    dx = jnp.where(in_range, g, 0.0)
    # d(out)/d(step) = q - v inside the range, qmin/qmax at the clip rails
    dstep_elem = jnp.where(in_range, q - v, jnp.clip(v, qmin, qmax))
    grad_scale = 1.0 / jnp.sqrt(n * float(qmax if qmax > 0 else 1))
    dstep = jnp.sum(g * dstep_elem) * grad_scale
    return dx, jnp.reshape(dstep, jnp.shape(step))


lsq_quant.defvjp(_lsq_fwd, _lsq_bwd)


def lsq_init_step(x: Array, bits: int, signed: bool) -> Array:
    """LSQ step initialization: 2<|x|>/sqrt(qmax)."""
    qr = qrange(bits, signed)
    qp = max(qr.qmax, 1)
    return 2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(float(qp))
