"""Analytic power models from the PANN paper, in units of bit flips.

All formulas are from "Energy awareness in low precision neural networks"
(Spingarn Eliezer et al., 2022):

  Eq. (1)  P_mult        = 0.5 b^2 + b                      (signed b x b)
  Eq. (2)  P_acc         = 0.5 B + 2 b                      (signed, B-bit accumulator)
  Eq. (3)  P_mult^u      = 0.5 b^2 + b                      (unsigned)
  Eq. (4)  P_acc^u       = 3 b                              (unsigned)
  Eq. (7)  P_mult_mixed  = 0.5 max(bw,bx)^2 + 0.5 (bw+bx)   (signed, mixed widths)
  Eq. (13) P_PANN        = (R + 0.5) b~x                    (per input element)
  Eq. (20) B_required    = bx + bw + 1 + log2(k^2 C_in)

Power is *per MAC* (or per input element for PANN); multiply by the MAC count of
the network to get total forward-pass power in bit flips (reported in Giga
bit-flips, as in the paper's tables).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

DEFAULT_ACC_BITS = 32  # the paper's default accumulator width


# ---------------------------------------------------------------------------
# Per-op models
# ---------------------------------------------------------------------------

def p_mult_signed(b: float) -> float:
    """Eq. (1): signed b x b multiplier power (bit flips per multiply)."""
    return 0.5 * b * b + b


def p_acc_signed(b: float, acc_bits: float = DEFAULT_ACC_BITS) -> float:
    """Eq. (2): accumulator power for signed products (B-bit accumulator)."""
    return 0.5 * acc_bits + 2.0 * b


def p_mult_unsigned(b: float) -> float:
    """Eq. (3): unsigned multiplier power (same model as signed; App. A.3)."""
    return 0.5 * b * b + b


def p_acc_unsigned(b: float) -> float:
    """Eq. (4): accumulator power for unsigned products."""
    return 3.0 * b


def p_mult_mixed(b_w: float, b_x: float) -> float:
    """Eq. (7): signed multiplier with different input widths.

    Observation 2: dominated by max(b_w, b_x)."""
    m = max(b_w, b_x)
    return 0.5 * m * m + 0.5 * (b_w + b_x)


def p_mac_signed(b: float, acc_bits: float = DEFAULT_ACC_BITS) -> float:
    """Signed MAC: Eq. (1) + Eq. (2)."""
    return p_mult_signed(b) + p_acc_signed(b, acc_bits)


def p_mac_unsigned(b: float) -> float:
    """Unsigned MAC: Eq. (3) + Eq. (4) = 0.5 b^2 + 4 b (Fig. 3 caption)."""
    return p_mult_unsigned(b) + p_acc_unsigned(b)


def p_mac_mixed_signed(b_w: float, b_x: float,
                       acc_bits: float = DEFAULT_ACC_BITS) -> float:
    """Mixed-width signed MAC: Eq. (7) + Eq. (2) at b = max(b_w, b_x)."""
    return p_mult_mixed(b_w, b_x) + p_acc_signed(max(b_w, b_x), acc_bits)


def p_pann(r: float, b_x_tilde: float) -> float:
    """Eq. (13): PANN power per input element, R additions of b~x-bit values."""
    return (r + 0.5) * b_x_tilde


def pann_r_for_budget(power: float, b_x_tilde: float) -> float:
    """Invert Eq. (13): the addition budget R matching a power budget."""
    return power / b_x_tilde - 0.5


def pann_bx_for_budget(power: float, r: float) -> float:
    """Invert Eq. (13) for the activation bit width."""
    return power / (r + 0.5)


def required_acc_bits(b_x: int, b_w: int, fan_in: int) -> int:
    """Eq. (20): accumulator width that avoids overflow.

    ``fan_in`` is k^2 * C_in for a conv layer, or d for a dense layer.
    The paper evaluates log2 with floor (Table 6 reproduces exactly).
    """
    return int(b_x + b_w + 1 + math.floor(math.log2(max(fan_in, 1))))


def unsigned_power_save(b: float, acc_bits: float = DEFAULT_ACC_BITS) -> float:
    """Fractional power saved by switching a signed MAC to unsigned (Fig. 12a)."""
    signed = p_mac_signed(b, acc_bits)
    return 1.0 - p_mac_unsigned(b) / signed


# ---------------------------------------------------------------------------
# Network-level accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MacBreakdown:
    """MAC counts of one forward pass, split by whether a static weight is
    involved (PANN applies) or both operands are activations (PANN does not)."""
    weight_macs: float = 0.0   # weight x activation products
    act_macs: float = 0.0      # activation x activation products (QK^T, AV, ...)

    @property
    def total(self) -> float:
        return self.weight_macs + self.act_macs

    def __add__(self, other: "MacBreakdown") -> "MacBreakdown":
        return MacBreakdown(self.weight_macs + other.weight_macs,
                            self.act_macs + other.act_macs)

    def scale(self, k: float) -> "MacBreakdown":
        return MacBreakdown(self.weight_macs * k, self.act_macs * k)


def network_power_bitflips(
    macs: MacBreakdown,
    *,
    scheme: str,
    bits: Optional[int] = None,
    b_w: Optional[int] = None,
    b_x: Optional[int] = None,
    r: Optional[float] = None,
    b_x_tilde: Optional[int] = None,
    acc_bits: float = DEFAULT_ACC_BITS,
) -> float:
    """Total forward-pass power (bit flips) of a network under a scheme.

    Schemes:
      'signed'    — regular signed quantized MACs at ``bits`` (or b_w/b_x mixed).
      'unsigned'  — after the Sec.-4 conversion, at ``bits``.
      'pann'      — PANN weights (R additions, b~x-bit activations); the
                    act x act MACs are charged as unsigned MACs at b~x.
    """
    if scheme == "signed":
        if b_w is not None and b_x is not None:
            per_mac = p_mac_mixed_signed(b_w, b_x, acc_bits)
        else:
            assert bits is not None
            per_mac = p_mac_signed(bits, acc_bits)
        return macs.total * per_mac
    if scheme == "unsigned":
        assert bits is not None
        return macs.total * p_mac_unsigned(bits)
    if scheme == "pann":
        assert r is not None and b_x_tilde is not None
        weight_part = macs.weight_macs * p_pann(r, b_x_tilde)
        act_part = macs.act_macs * p_mac_unsigned(b_x_tilde)
        return weight_part + act_part
    raise ValueError(f"unknown scheme {scheme!r}")


def giga(x: float) -> float:
    return x / 1e9


# ---------------------------------------------------------------------------
# Per-request energy accounting (serving)
# ---------------------------------------------------------------------------

def pann_token_bitflips(macs_per_token: MacBreakdown, r: float,
                        b_x_tilde: int) -> float:
    """Estimated bit flips of ONE generated token at a PANN operating point:
    Eq. (13) on the weight MACs plus unsigned-MAC accounting on the act x act
    MACs — the unit the serving engine reports per response."""
    return network_power_bitflips(macs_per_token, scheme="pann", r=r,
                                  b_x_tilde=b_x_tilde)


@dataclasses.dataclass
class EnergyLedger:
    """Running bit-flip account for one request at a fixed operating point.

    The serving engine charges one token per decode step and attaches
    ``report()`` to the response metadata, so every reply carries its own
    estimated energy price. ``breakdown_per_token`` (module path -> bit
    flips per token, e.g. from ``policy.tree_power_per_token``) additionally
    itemizes WHERE the budget went — the per-module view that makes a
    layerwise allocation auditable from the response alone.
    """
    bitflips_per_token: float
    tokens: int = 0
    breakdown_per_token: Optional[dict] = None

    def charge(self, n_tokens: int = 1) -> None:
        self.tokens += n_tokens

    @property
    def total(self) -> float:
        return self.bitflips_per_token * self.tokens

    def report(self) -> dict:
        out = {
            "tokens": self.tokens,
            "est_bitflips_per_token": self.bitflips_per_token,
            "est_gbitflips_per_token": giga(self.bitflips_per_token),
            "est_bitflips_total": self.total,
        }
        if self.breakdown_per_token:
            denom = sum(self.breakdown_per_token.values()) or 1.0
            out["per_module_gbitflips_per_token"] = {
                path: giga(v) for path, v in
                sorted(self.breakdown_per_token.items())}
            out["per_module_share"] = {
                path: round(v / denom, 4) for path, v in
                sorted(self.breakdown_per_token.items())}
        return out


def aggregate_ledgers(ledgers: "Iterable[EnergyLedger]") -> dict:
    """Fleet-level telemetry: fold per-stream ``EnergyLedger`` accounts from
    MANY hosts into one report — total tokens, total realized bit flips,
    and the merged per-module breakdown (module path -> total bit flips).

    The fold is order-deterministic for the caller's iteration order, so a
    fleet that sums hosts in id order and streams in uid order realizes the
    SAME float total on every run — the property the fleet-sim CI gate
    checks EXACTLY against its committed baseline
    (``repro.serve_engine.fleet``, benchmarks/fleet_sim.py).
    """
    tokens = 0
    total = 0.0
    by_module: dict = {}
    for led in ledgers:
        tokens += led.tokens
        total += led.total
        if led.breakdown_per_token:
            for path in sorted(led.breakdown_per_token):
                by_module[path] = by_module.get(path, 0.0) + \
                    led.breakdown_per_token[path] * led.tokens
    out = {
        "tokens": tokens,
        "bitflips_total": total,
        "gbitflips_total": giga(total),
    }
    if by_module:
        out["per_module_bitflips"] = dict(sorted(by_module.items()))
    return out
