"""Per-module quantization policies: the layer-wise generalization of the
single global ``QuantConfig``.

The paper's central criticism of prior art is that it ignores "the precise
power consumed by each module in the network" — a uniform operating point
spends the same bit-flip budget per MAC in a 4096-fan-in MLP down-projection
and a 64-fan-in decay head, even though their Eq.-19 MSE sensitivities and
Eq.-20 accumulator widths differ wildly. This module defines the vocabulary
for spending the budget *non*-uniformly:

  ``ModuleQuant``   one module's operating point (mode, b_w, b_x / b~x, R,
                    acc_bits) — the per-module analogue of ``QuantConfig``.
  ``PolicyTree``    a mapping from module *paths* ("attn.wq", "mlp.w_down",
                    "rwkv.tm.wo", "lm_head", ...) to ``ModuleQuant``, with
                    longest-dotted-prefix lookup and a default.
  ``uniform_policy``  lift a ``QuantConfig`` into a PolicyTree that assigns
                    every module the identical point — bit-exact with the
                    pre-policy behavior by construction.

Module paths are *roles*, not per-depth instances: every layer in the
scanned stack shares one policy per projection role, which is what keeps
``lax.scan`` bodies homogeneous and lets ONE jitted decode step serve every
policy tree (the serve_engine invariant; DESIGN.md §7).

Canonical path vocabulary (must match the names used by the model forwards
and ``models/serving.py``):

  attn.wq attn.wk attn.wv attn.wo            (self- and cross-attention)
  mlp.w_gate mlp.w_up mlp.w_down             (dense FFN)
  moe.router moe.w_gate moe.w_up moe.w_down  (MoE router + experts)
  ssm.in_proj ssm.out_proj ssm.conv          (Mamba2)
  rwkv.tm.wr rwkv.tm.wk rwkv.tm.wv rwkv.tm.wg rwkv.tm.decay_a
  rwkv.tm.decay_b rwkv.tm.wo rwkv.cm.wk rwkv.cm.wv
  lm_head
  conv.s0 conv.s1 ...                        (modality-frontend conv stem
                                              layers, one role per depth —
                                              stems are shallow and their
                                              fan-ins differ per layer, so
                                              unlike the scanned stack each
                                              depth IS a role)
  attn.k_cache attn.v_cache                  (decode-time KV cache codes;
                                              mode="ruq_unsigned", b_x = the
                                              cache bits — see CACHE_PATHS)

The power/score accounting at the bottom consumes the per-module MAC
profile from ``core/costs.py`` (duck-typed: anything with .path / .macs /
.fan_in) so the allocator, the serving ladder, and the per-response energy
breakdown all price a tree the same way.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Optional, Sequence, Tuple

from repro.core import mse as mse_theory
from repro.core import power as pw


@dataclasses.dataclass(frozen=True)
class ModuleQuant:
    """One module's operating point.

    Field names follow the paper (b_w, b_x, r, b_x_tilde); the properties
    below mirror ``QuantConfig``'s names so ``models/layers.qlinear`` works
    identically with either object.
    """
    mode: str = "none"            # none | ruq | ruq_unsigned | pann
    b_w: int = 8                  # RUQ weight bits
    b_x: int = 8                  # RUQ activation bits
    r: float = 2.0                # PANN addition budget per input element
    b_x_tilde: int = 8            # PANN activation bits (b~x)
    acc_bits: int = pw.DEFAULT_ACC_BITS   # accumulator width (Eq. 20-capped)

    # --- QuantConfig-compatible aliases ---
    @property
    def weight_bits(self) -> int:
        return self.b_w

    @property
    def act_bits(self) -> int:
        return self.b_x

    @property
    def act_bits_tilde(self) -> int:
        return self.b_x_tilde

    def power_per_mac(self) -> float:
        """Bit flips one weight-MAC of this module costs (Eq. 13 / 7 / 3-4)."""
        if self.mode == "pann":
            return pw.p_pann(self.r, self.b_x_tilde)
        if self.mode == "ruq_unsigned":
            return pw.p_mac_unsigned(max(self.b_w, self.b_x))
        if self.mode == "ruq":
            return pw.p_mac_mixed_signed(self.b_w, self.b_x, self.acc_bits)
        return 0.0                 # fp module: outside the quantized account

    def theory_mse(self, d: float = 1.0) -> float:
        """Eq. 18/16 output MSE of one fan-in-``d`` neuron at this point.

        ``d=1`` gives the *relative* (signal-normalized) MSE: under the
        §5.3 model both the Eq.-14 error and the output signal variance
        scale linearly with the fan-in, so their ratio is the d=1 value.
        """
        if self.mode == "pann":
            return mse_theory.mse_pann(d, self.b_x_tilde, self.r)
        if self.mode in ("ruq", "ruq_unsigned"):
            return mse_theory.mse_ruq(d, self.b_x, self.b_w)
        return 0.0


def as_module_quant(qc) -> ModuleQuant:
    """Normalize a ``QuantConfig`` (or ModuleQuant) into a ModuleQuant."""
    if isinstance(qc, ModuleQuant):
        return qc
    return ModuleQuant(mode=qc.mode, b_w=qc.weight_bits, b_x=qc.act_bits,
                       r=qc.r, b_x_tilde=qc.act_bits_tilde,
                       acc_bits=qc.acc_bits)


@dataclasses.dataclass(frozen=True)
class PolicyTree:
    """Module-path -> ModuleQuant, with longest-dotted-prefix fallback.

    ``overrides`` is a sorted tuple of (path, ModuleQuant) pairs so the tree
    is hashable (it rides on the frozen ``ModelConfig``); build trees with
    ``policy_tree`` to pass a plain dict.
    """
    default: ModuleQuant
    overrides: Tuple[Tuple[str, ModuleQuant], ...] = ()

    def lookup(self, path: str) -> ModuleQuant:
        """Exact match, else longest dotted prefix, else the default."""
        # lookup runs per projection at trace time and per module per
        # response in the serving engine's energy accounting — build the
        # dict once per tree (lazily; eq/hash only see dataclass fields)
        table = self.__dict__.get("_table")
        if table is None:
            table = dict(self.overrides)
            object.__setattr__(self, "_table", table)
        probe = path
        while probe:
            if probe in table:
                return table[probe]
            cut = probe.rfind(".")
            probe = probe[:cut] if cut > 0 else ""
        return self.default

    def items(self) -> Tuple[Tuple[str, ModuleQuant], ...]:
        return self.overrides

    def describe(self) -> str:
        rows = [f"  {p}: {m.mode} b~x={m.b_x_tilde} R={m.r:.2f} "
                f"acc={m.acc_bits}" for p, m in self.overrides]
        head = (f"PolicyTree(default {self.default.mode}, "
                f"{len(self.overrides)} overrides)")
        return "\n".join([head] + rows)


def policy_tree(default, overrides: Optional[Mapping[str, ModuleQuant]] = None
                ) -> PolicyTree:
    """Build a PolicyTree from a QuantConfig/ModuleQuant default + dict."""
    ov = tuple(sorted((overrides or {}).items()))
    return PolicyTree(default=as_module_quant(default), overrides=ov)


def uniform_policy(qc) -> PolicyTree:
    """The backward-compatibility lift: every module gets the global point.

    ``lookup`` returns a ModuleQuant with field-for-field the same values as
    ``qc``, and ``layers.qlinear`` reads the same attributes, so a forward
    under ``uniform_policy(qc)`` is bit-exact with one under ``qc`` (asserted
    in tests/test_policy_allocator.py).
    """
    return PolicyTree(default=as_module_quant(qc))


# ---------------------------------------------------------------------------
# Serving-artifact path resolution
# ---------------------------------------------------------------------------

# structural parents that anchor a module path in the param pytree
_STRUCTURAL = {"attn", "xattn", "shared_attn", "mlp", "moe", "ssm",
               "tm", "cm"}
_RWKV_SUBBLOCKS = {"tm", "cm"}


def serving_path(trail: Sequence[str]) -> str:
    """Map a param-pytree key trail to the canonical policy path.

    e.g. ("decoder", "groups", "layers", "attn", "wq") -> "attn.wq";
    ("tm", "wr") -> "rwkv.tm.wr"; ("lm_head",) -> "lm_head";
    ("conv_stem", "s0") -> "conv.s0".
    ``xattn`` and the zamba2 ``shared_attn`` block map onto ``attn`` so one
    policy entry covers every attention instance.
    """
    leaf = trail[-1]
    if "conv_stem" in trail:
        # stem layers are per-depth roles: shallow, heterogeneous fan-ins
        return f"conv.{leaf}"
    parent = next((t for t in reversed(trail[:-1]) if t in _STRUCTURAL),
                  None)
    if parent in _RWKV_SUBBLOCKS:
        return f"rwkv.{parent}.{leaf}"
    if parent in ("xattn", "shared_attn"):
        return f"attn.{leaf}"
    if parent is not None:
        return f"{parent}.{leaf}"
    return leaf


# ---------------------------------------------------------------------------
# Pricing and scoring a tree against a module cost profile
# ---------------------------------------------------------------------------

ACT_PATH = "attn.act"   # breakdown key for act x act MACs (QK^T, PV)

# Cache roles: the two act x act operand streams of decode attention. An
# EXPLICIT override on either role (not a prefix fallback from "attn") means
# the tree prices the cache at its own width; otherwise the legacy ACT_PATH
# lump applies. Cache points are unsigned codes, so mode="ruq_unsigned" with
# b_w = b_x = the cache bits is the canonical ModuleQuant.
CACHE_PATHS = ("attn.k_cache", "attn.v_cache")


def cache_module_quant(bits: int) -> ModuleQuant:
    """The canonical operating point of a ``bits``-bit quantized KV cache."""
    b = int(bits)
    return ModuleQuant(mode="ruq_unsigned", b_w=b, b_x=b, b_x_tilde=b)


def tree_cache_bits(tree: PolicyTree) -> dict:
    """{cache role: bits} for the roles the tree EXPLICITLY overrides.

    Prefix fallback is deliberately not consulted: an "attn" override is a
    weight-projection point, not an opt-in to cache quantization.
    """
    table = dict(tree.overrides)
    out = {}
    for role in CACHE_PATHS:
        mq = table.get(role)
        if mq is not None and mq.mode != "none":
            out[role] = max(mq.b_w, mq.b_x)
    return out


def tree_power_per_token(profile: Iterable, tree: PolicyTree,
                         act_macs: float = 0.0) -> Tuple[float, dict]:
    """(total bit flips per token, {path: bit flips}) of one forward token.

    Weight modules are priced at their own operating point; act x act MACs
    (outside PANN's scope, DESIGN.md §4) are charged as unsigned MACs at the
    default policy's activation width, mirroring
    ``power.network_power_bitflips(scheme="pann")``. When the tree carries
    explicit cache-role overrides (CACHE_PATHS), the act x act MACs split in
    half per role — QK^T reads the K cache, PV reads the V cache — and each
    half is priced at its role's own width instead of the default lump.
    """
    breakdown: dict[str, float] = {}
    for m in profile:
        if m.path in CACHE_PATHS:
            continue               # cache roles are priced off act_macs below
        mq = tree.lookup(m.path)
        breakdown[m.path] = m.macs * mq.power_per_mac()
    if act_macs:
        cache = tree_cache_bits(tree)
        if cache:
            d = tree.default
            b_act = d.b_x_tilde if d.mode == "pann" else d.b_x
            for role in CACHE_PATHS:
                b = cache.get(role, b_act)
                breakdown[role] = 0.5 * act_macs * pw.p_mac_unsigned(b)
        else:
            d = tree.default
            b_act = d.b_x_tilde if d.mode == "pann" else d.b_x
            breakdown[ACT_PATH] = act_macs * pw.p_mac_unsigned(b_act)
    return sum(breakdown.values()), breakdown


def tree_theory_score(profile: Iterable, tree: PolicyTree) -> float:
    """-(output-weighted relative Eq. 18/19 MSE) of a tree — higher is
    better.

    Each module contributes (its output count per token, ``macs / fan_in``)
    x (the per-output *relative* MSE at its operating point). Relative —
    not absolute — because under the §5.3 uniform model both the Eq.-14
    error and the output signal variance grow linearly with fan-in, so the
    per-output SNR is the fan-in-free ``theory_mse(1)``. This is what makes
    layer-wise allocation non-degenerate: a wide reduction (mlp.w_down's
    14336-fan-in) yields fewer outputs per MAC than a narrow one, so a bit
    flip spent there buys less output fidelity, and the allocator shifts
    budget toward the narrow modules. (With the absolute metric the fan-in
    cancels against the output count and uniform is provably optimal.)

    Uniform and layerwise trees are scored with the SAME metric so the
    allocator's "never worse than uniform" guarantee is well defined.
    """
    total = 0.0
    for m in profile:
        mq = tree.lookup(m.path)
        weight = m.macs / max(float(m.fan_in), 1.0)
        total += weight * mq.theory_mse(1.0)
    return -total


def pann_storage_bits(r: float) -> int:
    """Estimated b_R: bits storing a PANN weight code at addition budget R.

    Codes concentrate within a few multiples of R (Table 14 measures
    b_R <= 5 in practice); 2R+1 levels is the working envelope we size the
    Eq.-20 accumulator with.
    """
    return max(1, int(math.ceil(math.log2(2.0 * max(r, 0.5) + 1.0))))


def pann_module_quant(r: float, b_x_tilde: int, fan_in: int) -> ModuleQuant:
    """A PANN ModuleQuant with the Eq.-20 accumulator width for its fan-in
    (capped at the paper's 32-bit default — never wider than the hardware)."""
    b_w = pann_storage_bits(r)
    acc = min(pw.DEFAULT_ACC_BITS,
              pw.required_acc_bits(b_x_tilde, b_w, fan_in))
    return ModuleQuant(mode="pann", b_w=b_w, r=r, b_x_tilde=b_x_tilde,
                       acc_bits=acc)
