"""Config registry: ``get_config(arch_id)`` for every assigned architecture,
plus reduced smoke-test variants and the shape cells."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs import (dbrx_132b, gemma2_9b, llama3_8b,
                           llama_3_2_vision_90b, mixtral_8x7b, qwen1_5_4b,
                           rwkv6_1_6b, seamless_m4t_medium, stablelm_12b,
                           zamba2_1_2b)
from repro.configs.base import (SHAPES, SHAPES_BY_NAME, ConvSpec, ModelConfig,
                                MoEConfig, ParallelConfig, QuantConfig,
                                ShapeConfig, TrainConfig)

_REGISTRY = {
    "qwen1.5-4b": qwen1_5_4b.config,
    "stablelm-12b": stablelm_12b.config,
    "gemma2-9b": gemma2_9b.config,
    "llama3-8b": llama3_8b.config,
    "dbrx-132b": dbrx_132b.config,
    "mixtral-8x7b": mixtral_8x7b.config,
    "seamless-m4t-medium": seamless_m4t_medium.config,
    "zamba2-1.2b": zamba2_1_2b.config,
    "rwkv6-1.6b": rwkv6_1_6b.config,
    "llama-3.2-vision-90b": llama_3_2_vision_90b.config,
}

ARCH_NAMES = tuple(_REGISTRY)


def get_config(name: str, *, quant: Optional[QuantConfig] = None,
               dtype: Optional[str] = None) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if quant is not None:
        cfg = dataclasses.replace(cfg, quant=quant)
    if dtype is not None:
        cfg = dataclasses.replace(cfg, dtype=dtype)
    return cfg


def reduced(cfg: ModelConfig, *, layers: Optional[int] = None) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers, small width,
    tiny vocab — exercises every structural feature (pattern, tail, caches).
    """
    from repro.models.transformer import group_pattern  # lazy: avoid cycle

    pattern_len = len(group_pattern(cfg))
    if layers is None:
        layers = pattern_len * 2 + (2 if cfg.family == "hybrid" else 0)
    kv = max(1, (4 * cfg.num_kv_heads) // cfg.num_heads)
    # same-shape-family conv stems at smoke scale (stem_tokens matches the
    # reduced token counts below: vlm 4x4=16, encdec 96 -> 48 -> 24)
    stem: tuple = ()
    hw: tuple = ()
    if cfg.conv_stem and cfg.family == "vlm":
        stem = (ConvSpec(kh=4, kw=4, sh=4, sw=4, c_in=3, c_out=64),)
        hw = (16, 16)
    elif cfg.conv_stem:
        stem = (ConvSpec(kh=3, kw=1, sh=2, sw=1, c_in=80, c_out=64, ph=1),
                ConvSpec(kh=3, kw=1, sh=2, sw=1, c_in=64, c_out=64, ph=1))
        hw = (96, 1)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=32 if cfg.head_dim else None,
        d_ff=128,
        vocab_size=512,
        local_window=16,
        sliding_window=16 if cfg.sliding_window else None,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq_len=24 if cfg.family == "encdec" else cfg.encoder_seq_len,
        num_image_tokens=16 if cfg.family == "vlm" else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2) if cfg.moe else None,
        conv_stem=stem,
        frontend_hw=hw,
    )


__all__ = [
    "ARCH_NAMES", "SHAPES", "SHAPES_BY_NAME", "ConvSpec", "ModelConfig",
    "MoEConfig",
    "ParallelConfig", "QuantConfig", "ShapeConfig", "TrainConfig",
    "get_config", "reduced",
]
