"""zamba2-1.2b [hybrid]: 38L d_model=2048 (Mamba2, ssm_state=64) with a
SHARED attention(32H, kv=32)+MLP block every 6 layers, d_ff=8192
vocab=32000. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        norm="rmsnorm", activation="gelu",
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_period=6)
