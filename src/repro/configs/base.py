"""Config dataclasses: model architecture, quantization, shapes, parallelism."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.policy import PolicyTree


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How PANN / baseline quantization is applied to every linear layer."""
    mode: str = "none"            # none | ruq | ruq_unsigned | pann
    weight_bits: int = 8          # RUQ weight bits
    act_bits: int = 8             # RUQ activation bits
    r: float = 2.0                # PANN addition budget per input element
    act_bits_tilde: int = 8       # PANN activation bits (b~x)
    qat: bool = False             # STE fake-quant inside the train step
    acc_bits: int = 32            # accumulator width for power accounting


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One conv-stem layer's static geometry (NHWC, zero 'same-ish' pad).

    Geometry is CONFIG, never artifact data: the serving artifact stores a
    conv kernel flat as a (kh*kw*c_in, c_out) matrix (kernels/pann_conv
    layout contract), so the one weight store / mmap schema is untouched
    and rung views, plane packing, and the allocator all see a linear with
    fan-in kh*kw*c_in.
    """
    kh: int                       # kernel height
    kw: int                       # kernel width
    sh: int                       # stride height
    sw: int                       # stride width
    c_in: int
    c_out: int
    ph: int = 0                   # zero padding (each side), height
    pw: int = 0                   # zero padding (each side), width

    @property
    def fan_in(self) -> int:
        return self.kh * self.kw * self.c_in

    def out_hw(self, h: int, w: int) -> Tuple[int, int]:
        return ((h + 2 * self.ph - self.kh) // self.sh + 1,
                (w + 2 * self.pw - self.kw) // self.sw + 1)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | encdec | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    activation: str = "swiglu"    # swiglu | geglu | gelu | relu
    dtype: str = "float32"        # compute dtype ("bfloat16" on TPU)
    # --- attention variants ---
    sliding_window: Optional[int] = None   # mixtral-style SWA (all layers)
    local_global_period: int = 0  # gemma2: every Nth layer is global, rest local
    local_window: int = 4096
    attn_softcap: float = 0.0     # gemma2 attention-logit softcap
    logit_softcap: float = 0.0    # gemma2 final-logit softcap
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    moe_impl: str = "scan"        # scan (dense, baseline) | capacity (§Perf)
    # --- SSM / hybrid ---
    ssm_state: int = 0            # mamba2 state size (N)
    ssm_head_dim: int = 64        # mamba2 head dim (P)
    ssm_expand: int = 2           # d_inner = expand * d_model
    ssm_conv_width: int = 4
    attn_period: int = 0          # zamba2: shared attn block every N layers
    # --- enc-dec ---
    encoder_layers: int = 0
    encoder_seq_len: int = 1024   # stubbed modality frontend output length
    # --- VLM ---
    cross_attn_period: int = 0    # llama-3.2-vision: cross-attn every Nth layer
    num_image_tokens: int = 0
    # --- modality frontend (conv stem) ---
    # When non-empty, the encoder path owns a REAL conv stem: raw (B, H, W,
    # C) pixels / (B, frames, 1, mels) features run through these layers
    # (models.layers.apply_conv -> kernels.dispatch.serving_conv) and the
    # result is flattened to the encoder/image token sequence. Empty = the
    # pre-conv behavior (data.pipeline.frontend_stub embeddings).
    conv_stem: Tuple[ConvSpec, ...] = ()
    frontend_hw: Tuple[int, int] = ()   # raw input spatial dims (H, W)
    # --- serving ---
    kv_cache_dtype: str = ""      # "" = compute dtype; "float8_e4m3fn" halves
    #                               KV-cache bytes for decode (§Perf iter. 7)
    # Serving-matmul backend for quantized (w_q) projections — None (legacy
    # float dequant) | "ref" | "fused" | "packed" (repro.kernels.dispatch).
    # Trace-time static: one jitted decode step per backend.
    kernel_backend: Optional[str] = None
    # Quantized KV cache: when set, decode stores K/V as packed bit-plane
    # codes at this many unsigned bits (<= 7) and attention runs through the
    # bit-plane decode kernel (kernels/pann_attention via dispatch). The
    # *structure* knob only — per-rung cache bits ride as data leaves
    # (k_nlvl/v_nlvl) so one jitted step serves mixed cache-rung ladders.
    cache_bits: Optional[int] = None
    # --- misc ---
    tie_embeddings: bool = False
    scale_embed: bool = False     # gemma2: multiply embeddings by sqrt(d)
    post_norm: bool = False       # gemma2: extra norm on sublayer outputs
    # Cost-probe mode: unroll scans (layer groups, attention chunks, MoE
    # experts) so compiled.cost_analysis() counts every iteration — XLA
    # counts while-loop bodies once. Used by the dry-run's FLOPs probes on
    # shallow variants; never for real execution.
    unroll_loops: bool = False
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    # Per-module policy tree (repro.core.policy). None = the global ``quant``
    # applies uniformly (the pre-policy behavior, bit-exact). When set, every
    # projection looks up its own ModuleQuant by module path ("attn.wq",
    # "mlp.w_down", ...) via ``layers.module_quant``.
    policy: Optional[PolicyTree] = None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP shards evenly."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def stem_out_hw(self) -> Tuple[int, int]:
        """Spatial dims after the conv stem (requires conv_stem set)."""
        h, w = self.frontend_hw
        for spec in self.conv_stem:
            h, w = spec.out_hw(h, w)
        return h, w

    @property
    def stem_tokens(self) -> int:
        """Token-sequence length the conv stem feeds the encoder."""
        h, w = self.stem_out_hw
        return h * w

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic attention -> the long_500k cell runs (DESIGN.md §5)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.local_global_period > 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


# The four LM shape cells assigned to every architecture.
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = False            # ZeRO-3-style param sharding over "data"
    remat: str = "block"          # none | block  (activation checkpointing)
    pipeline_stages: int = 1      # GPipe over the "pod" axis when > 1
    compress_grads: bool = False  # int8 + error-feedback gradient all-reduce
    microbatches: int = 1         # gradient-accumulation factor


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    # --- power-aware QAT (DESIGN.md §9) ---
    # Budget-annealing curriculum: "step:bits" knots, e.g. "0:fp,200:8,600:4"
    # (core/anneal.py). None = a fixed operating point for the whole run.
    budget_schedule: Optional[str] = None
    # how each annealed budget is spent across modules: uniform | layerwise
    budget_allocation: str = "layerwise"
    # EMA decay of the activation-range calibration collection
    calib_decay: float = 0.99
    # LR re-warmup after each budget-tightening knot: ramp length in steps
    # (0 = off) and the knot steps it applies at (set by the trainer from
    # the parsed schedule; consumed by optim.cosine_warmup_schedule)
    anneal_warmup_steps: int = 0
    lr_rewarmup_knots: Tuple[int, ...] = ()
