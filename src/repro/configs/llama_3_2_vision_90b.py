"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attention image layers every 5th layer. The vision
tower's transformer is still stubbed, but the patchify conv stem is REAL:
a 14x14/s14 conv over 560x560 RGB produces the 40x40 = 1600 image tokens
(models.model.encode), served through the quantized conv projection.
[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]"""
from repro.configs.base import ConvSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        norm="rmsnorm", activation="swiglu", rope_theta=500000.0,
        cross_attn_period=5, num_image_tokens=1600,
        conv_stem=(ConvSpec(kh=14, kw=14, sh=14, sw=14, c_in=3, c_out=8192),),
        frontend_hw=(560, 560))
