"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attention image layers every 5th layer. The vision
tower is a STUB: input_specs() provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        norm="rmsnorm", activation="swiglu", rope_theta=500000.0,
        cross_attn_period=5, num_image_tokens=1600)
