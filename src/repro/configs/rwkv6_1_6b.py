"""rwkv6-1.6b "Finch" [ssm]: 24L d_model=2048 (attention-free, data-dependent
decay) d_ff=7168 vocab=65536. [arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536,
        norm="layernorm", activation="relu")
