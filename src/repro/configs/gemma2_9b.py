"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating attention, logit softcaps,
head_dim=256, tied embeddings, post-norms. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
        d_ff=14336, vocab_size=256000, head_dim=256,
        norm="rmsnorm", activation="geglu",
        local_global_period=2, local_window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        post_norm=True, scale_embed=True, tie_embeddings=True)
