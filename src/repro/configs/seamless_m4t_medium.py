"""seamless-m4t-medium [audio]: enc-dec transformer BACKBONE, 12+12L
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206. The speech frontend's
feature-extractor conv stem is REAL: two stride-2 temporal convs over
(frames, 1, 80) fbank features — 4096 frames -> the 1024 encoder positions
(models.model.encode) — served through the quantized conv projection.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ConvSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=256206,
        norm="layernorm", activation="relu",
        encoder_layers=12, encoder_seq_len=1024,
        conv_stem=(
            ConvSpec(kh=3, kw=1, sh=2, sw=1, c_in=80, c_out=1024, ph=1),
            ConvSpec(kh=3, kw=1, sh=2, sw=1, c_in=1024, c_out=1024, ph=1),
        ),
        frontend_hw=(4096, 1))
