"""seamless-m4t-medium [audio]: enc-dec transformer BACKBONE, 12+12L
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206. The speech/text modality
frontend is a STUB: input_specs() provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=256206,
        norm="layernorm", activation="relu",
        encoder_layers=12, encoder_seq_len=1024)
