"""RWKV-6 "Finch" block: attention-free time mixing with data-dependent decay
(arXiv:2404.05892), plus the squared-ReLU channel mix.

Time mixing keeps a per-head (hd x hd) wkv state — O(1) memory per token —
so long_500k decode is natural. Training runs the recurrence with a chunked
lax.scan (one scan step per CHUNK of tokens, recurrence vectorized inside the
chunk), which keeps compile size flat and exposes parallelism to XLA.

PANN applies to all the static mixing matrices (r/k/v/g/o projections and the
channel-mix matrices); the decay path is elementwise (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain as C
from repro.models import layers as L

Array = jax.Array

HEAD_DIM = 64


class RWKVState(NamedTuple):
    wkv: Array      # (B, H, hd, hd)
    shift_tm: Array  # (B, d) previous token (time mix)
    shift_cm: Array  # (B, d) previous token (channel mix)
    length: Array


def _heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % HEAD_DIM == 0
    return cfg.d_model // HEAD_DIM


def init_rwkv_time_mix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = _heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,g,w token-shift mix
        "wr": L.init_linear(ks[0], d, d),
        "wk": L.init_linear(ks[1], d, d),
        "wv": L.init_linear(ks[2], d, d),
        "wg": L.init_linear(ks[3], d, d),
        # data-dependent decay: low-rank w = exp(-exp(base + tanh(x A) B))
        "decay_a": L.init_linear(ks[4], d, 64),
        "decay_b": L.init_linear(ks[5], 64, d),
        "decay_base": jnp.full((d,), -4.0, jnp.float32),
        "bonus": jnp.zeros((h, HEAD_DIM), jnp.float32),  # per-head "u" term
        "ln_x": L.init_norm(d, "layernorm"),
        "wo": L.init_linear(ks[7], d, d),
    }


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "mu": jnp.full((2, d), 0.5, jnp.float32),
        "wk": L.init_linear(ks[0], d, cfg.d_ff),
        "wv": L.init_linear(ks[1], cfg.d_ff, d),
    }


def _token_shift(x: Array, prev: Array) -> Array:
    """Shifted sequence: [prev, x_0, ..., x_{T-2}]. x: (B, T, d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_inner(r, k, v, w, u, state):
    """Sequential wkv recurrence over one chunk (vectorized over B, H).

    r,k,v: (B, T, H, hd); w: (B, T, H, hd) decays in (0,1); u: (H, hd).
    state: (B, H, hd, hd). Returns (out (B,T,H,hd), new state).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                      # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, out

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(outs, 0, 1), state


def apply_time_mix(x: Array, p: dict, cfg: ModelConfig,
                   state: RWKVState | None = None
                   ) -> tuple[Array, Array, Array]:
    """x: (B, T, d) -> (y, final wkv state, last token). Prefill/training."""
    b, t, d = x.shape
    h = _heads(cfg)

    def qc(name):
        return L.module_quant(cfg, f"rwkv.tm.{name}")

    def lin(xv, w, name):
        return L.apply_linear(xv, w, qc(name), backend=cfg.kernel_backend,
                              path=f"rwkv.tm.{name}")

    prev = jnp.zeros((b, d), x.dtype) if state is None else \
        state.shift_tm.astype(x.dtype)
    xs = _token_shift(x, prev)
    mu = p["mu"].astype(x.dtype)
    mix = [x * mu[i] + xs * (1 - mu[i]) for i in range(5)]
    r = C.constrain_axis(
        lin(mix[0], p["wr"], "wr").reshape(b, t, h, HEAD_DIM), 2)
    k = C.constrain_axis(
        lin(mix[1], p["wk"], "wk").reshape(b, t, h, HEAD_DIM), 2)
    v = C.constrain_axis(
        lin(mix[2], p["wv"], "wv").reshape(b, t, h, HEAD_DIM), 2)
    g = jax.nn.silu(lin(mix[3], p["wg"], "wg"))
    dlow = jnp.tanh(lin(mix[4], p["decay_a"], "decay_a"))
    dd = lin(dlow, p["decay_b"], "decay_b") + p["decay_base"]
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32))).reshape(b, t, h, HEAD_DIM)

    s0 = jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32) if state is None \
        else state.wkv
    out, s_fin = _time_mix_inner(r.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), w,
                                 p["bonus"], s0)
    out = out.reshape(b, t, d).astype(x.dtype)
    out = L.apply_norm(out, p["ln_x"], "layernorm") * g
    return lin(out, p["wo"], "wo"), s_fin, x[:, -1, :]


def apply_channel_mix(x: Array, p: dict, cfg: ModelConfig,
                      prev: Array | None = None) -> tuple[Array, Array]:
    b, t, d = x.shape
    pv = jnp.zeros((b, d), x.dtype) if prev is None else prev.astype(x.dtype)
    xs = _token_shift(x, pv)
    mu = p["mu"].astype(x.dtype)
    xk = x * mu[0] + xs * (1 - mu[0])
    k = jnp.square(jax.nn.relu(
        L.project(xk, p["wk"], cfg, "rwkv.cm.wk")))
    return L.project(k, p["wv"], cfg, "rwkv.cm.wv"), x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    h = _heads(cfg)
    return RWKVState(
        wkv=jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
        length=jnp.zeros((), jnp.int32))
