"""Mamba2 (SSD) block — chunked state-space duality formulation in pure JAX.

Follows the minimal SSD reference (Dao & Gu 2024): within-chunk quadratic
attention-like term + cross-chunk recurrent state passing, O(T) overall.
Decode keeps an explicit (H, P, N) state per sequence — O(1) per token, which
is what makes the long_500k cell runnable for hybrid/SSM architectures.

PANN applies to the in/out projections (weight x activation matmuls); the
selective-scan itself is state x input arithmetic with no static weight and
is left in floating point (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain as C
from repro.models import layers as L

Array = jax.Array


class SSMState(NamedTuple):
    state: Array       # (B, H, P, N) recurrent state
    conv: Array        # (B, W-1, conv_dim) causal-conv tail
    length: Array      # () int32


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg: ModelConfig) -> dict:
    d_inner, h, p_dim, n = _dims(cfg)
    d = cfg.d_model
    conv_dim = d_inner + 2 * n * 1  # x + B + C streams (single group)
    ks = jax.random.split(key, 5)
    return {
        # in_proj emits [z (gate), x, B, C, dt]
        "in_proj": L.init_linear(ks[0], d, 2 * d_inner + 2 * n + h),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": L.init_norm(d_inner, "rmsnorm"),
        "out_proj": L.init_linear(ks[4], d_inner, d),
    }


def _split_proj(zxbcdt: Array, cfg: ModelConfig):
    d_inner, h, p_dim, n = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    x, b_ssm, c_ssm = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    return z, x, b_ssm, c_ssm, dt


def _causal_conv(x: Array, w: Array, b: Array, tail: Array | None = None):
    """Depthwise causal conv along time. x: (B, T, C); w: (W, C)."""
    width = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
              for i in range(width))
    new_tail = xp[:, -(width - 1):, :] if width > 1 else None
    return jax.nn.silu(out + b.astype(x.dtype)), new_tail


def _ssd_chunked(x: Array, dt: Array, a_log: Array, b_ssm: Array,
                 c_ssm: Array, chunk: int = 64):
    """SSD scan. x: (B, T, H, P); dt: (B, T, H); b,c: (B, T, N).

    Returns y (B, T, H, P) and the final state (B, H, P, N).

    chunk=64: the within-chunk decay tensor is (B, C, L, L, H) — at chunk
    256 that is tokens x 256 x H elements (~68 TB at the train_4k cell,
    §Perf iteration 6); at 64 it fits comfortably under remat. A Pallas SSD
    kernel holding the block in VMEM would allow larger chunks on TPU.
    """
    bsz, t, h, p_dim = x.shape
    n = b_ssm.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    n_chunks = t // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32))            # (B, T, H)
    da = dt * a[None, None, :]                              # (B, T, H) log-decay

    xr = x.reshape(bsz, n_chunks, chunk, h, p_dim)
    dtr = dt.reshape(bsz, n_chunks, chunk, h)
    dar = da.reshape(bsz, n_chunks, chunk, h)
    br = b_ssm.reshape(bsz, n_chunks, chunk, n)
    cr = c_ssm.reshape(bsz, n_chunks, chunk, n)

    cum = jnp.cumsum(dar, axis=2)                           # (B, C, L, H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,C,Lq,Lk,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: masked entries have seg > 0 and would overflow,
    # poisoning gradients through the where (0 * inf = NaN)
    seg = jnp.where(causal[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)

    # within-chunk (quadratic in chunk length only)
    scores = jnp.einsum("bcln,bcmn->bclm", cr, br) \
        [..., None] * decay                                  # (B,C,L,M,H)
    y_diag = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", scores, dtr, xr)

    # per-chunk input -> state contribution
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,C,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        br, dtr * decay_to_end, xr)

    # cross-chunk recurrence over chunk index
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B, C, H)

    def scan_fn(carry, inp):
        s_new, dec = inp                                     # (B,H,P,N),(B,H)
        carry_out = carry * dec[:, :, None, None] + s_new
        return carry_out, carry                              # emit state *before* chunk

    init = jnp.zeros((bsz, h, p_dim, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,C,H,P,N)

    # contribution of carried-in state to each position
    decay_from_start = jnp.exp(cum)                          # (B,C,L,H)
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp",
                       cr, decay_from_start, prev_states)

    y = (y_diag + y_off).reshape(bsz, t, h, p_dim)
    return y, final_state


def apply_ssm(x: Array, p: dict, cfg: ModelConfig) -> Array:
    """Training/prefill forward. x: (B, T, d) -> (B, T, d)."""
    d_inner, h, p_dim, n = _dims(cfg)
    zxbcdt = L.project(x, p["in_proj"], cfg, "ssm.in_proj")
    z, xs, b_ssm, c_ssm, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xs, b_ssm, c_ssm], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xs, b_ssm, c_ssm = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = C.constrain_axis(xs.reshape(*xs.shape[:-1], h, p_dim), 2)
    y, _ = _ssd_chunked(xh, dt, p["a_log"], b_ssm, c_ssm)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.apply_norm(y, p["norm"], "rmsnorm")
    return L.project(y, p["out_proj"], cfg, "ssm.out_proj")


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    d_inner, h, p_dim, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return SSMState(
        state=jnp.zeros((batch, h, p_dim, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def decode_ssm(x: Array, st: SSMState, p: dict, cfg: ModelConfig
               ) -> tuple[Array, SSMState]:
    """Single-token recurrent step. x: (B, 1, d)."""
    d_inner, h, p_dim, n = _dims(cfg)
    zxbcdt = L.project(x, p["in_proj"], cfg, "ssm.in_proj")
    z, xs, b_ssm, c_ssm, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xs, b_ssm, c_ssm], axis=-1)   # (B, 1, C)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                               tail=st.conv)
    new_tail = jnp.concatenate([st.conv, conv_in.astype(st.conv.dtype)],
                               axis=1)[:, 1:, :]
    xs, b_ssm, c_ssm = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(xs.shape[0], h, p_dim).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32))      # (B, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dtv * a[None, :])                          # (B, H)
    bv = b_ssm[:, 0].astype(jnp.float32)                     # (B, N)
    cv = c_ssm[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtv, xh, bv)
    state = st.state * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cv)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(x.shape[0], 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.apply_norm(y, p["norm"], "rmsnorm")
    out = L.project(y, p["out_proj"], cfg, "ssm.out_proj")
    return out, SSMState(state=state, conv=new_tail, length=st.length + 1)
