"""Model assembly: every assigned architecture is a repeating *group pattern*
of layer kinds, stacked and scanned with lax.scan (fast compiles at 100
layers) with optional per-group activation rematerialization.

Layer kinds:
  attn        — self-attention (+MLP) block; window=None means global
  attn_moe    — self-attention + MoE block
  mamba       — Mamba2 (SSD) block
  mamba_attn  — Mamba2 block followed by the zamba2 *shared* attn+MLP block
  rwkv        — RWKV6 time-mix + channel-mix block
  cross_attn  — VLM / enc-dec cross-attention (+MLP) block
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mlp as M
from repro.models import rwkv as R
from repro.models import ssm as S

Array = jax.Array


# ---------------------------------------------------------------------------
# Group patterns
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str
    window: Optional[int] = None


def group_pattern(cfg: ModelConfig, role: str = "decoder") -> list[LayerSpec]:
    """Smallest repeating pattern of layers for this architecture."""
    if role == "encoder":  # encdec encoder: bidirectional self-attn blocks
        return [LayerSpec("attn")]
    if cfg.family == "encdec":  # decoder: self-attn + cross-attn every layer
        return [LayerSpec("cross_attn")]
    if cfg.family == "moe":
        return [LayerSpec("attn_moe", cfg.sliding_window)]
    if cfg.family == "ssm":
        return [LayerSpec("rwkv")]
    if cfg.family == "hybrid":
        period = cfg.attn_period or 6
        return [LayerSpec("mamba")] * (period - 1) + [LayerSpec("mamba_attn")]
    if cfg.family == "vlm":
        period = cfg.cross_attn_period or 5
        return [LayerSpec("cross_attn")] + [LayerSpec("attn")] * (period - 1)
    if cfg.local_global_period:  # gemma2: alternate local / global
        return [LayerSpec("attn", cfg.local_window), LayerSpec("attn", None)]
    return [LayerSpec("attn", cfg.sliding_window)]


def group_layout(cfg: ModelConfig, num_layers: Optional[int] = None,
                 role: str = "decoder") -> tuple[list[LayerSpec], int, int]:
    """(pattern, n_groups, n_tail): n_tail layers don't fill a full group and
    run outside the scan (e.g. zamba2's 38 = 6*6 + 2)."""
    pattern = group_pattern(cfg, role)
    n_layers = num_layers if num_layers is not None else cfg.num_layers
    n_groups = n_layers // len(pattern)
    n_tail = n_layers - n_groups * len(pattern)
    return pattern, n_groups, n_tail


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": L.init_norm(d, cfg.norm)}
    if spec.kind in ("attn", "attn_moe", "cross_attn"):
        p["attn"] = A.init_attention(ks[0], cfg)
        p["norm2"] = L.init_norm(d, cfg.norm)
        if spec.kind == "attn_moe":
            p["moe"] = M.init_moe(ks[1], cfg)
        else:
            p["mlp"] = M.init_mlp(ks[1], cfg)
        if spec.kind == "cross_attn":
            p["xattn"] = A.init_attention(ks[2], cfg, cross=True)
            p["norm_x"] = L.init_norm(d, cfg.norm)
            p["xgate"] = jnp.zeros((), jnp.float32)
        if cfg.post_norm:
            p["post1"] = L.init_norm(d, cfg.norm)
            p["post2"] = L.init_norm(d, cfg.norm)
    elif spec.kind in ("mamba", "mamba_attn"):
        p["ssm"] = S.init_ssm(ks[0], cfg)
    elif spec.kind == "rwkv":
        p["tm"] = R.init_rwkv_time_mix(ks[0], cfg)
        p["norm2"] = L.init_norm(d, cfg.norm)
        p["cm"] = R.init_rwkv_channel_mix(ks[1], cfg)
    else:
        raise ValueError(spec.kind)
    return p


def init_shared_attn(key, cfg: ModelConfig) -> dict:
    """zamba2: one attention+MLP block shared by all mamba_attn positions."""
    ks = jax.random.split(key, 2)
    return {"norm1": L.init_norm(cfg.d_model, cfg.norm),
            "attn": A.init_attention(ks[0], cfg),
            "norm2": L.init_norm(cfg.d_model, cfg.norm),
            "mlp": M.init_mlp(ks[1], cfg)}


def _residual(x, delta, p, cfg, post_key):
    if cfg.post_norm and post_key in p:
        delta = L.apply_norm(delta, p[post_key], cfg.norm)
    return x + delta


def _apply_moe_dispatch(h, p, cfg: ModelConfig):
    """Baseline dense scan, or the capacity-dispatch path (§Perf) when a
    mesh is active and the config opts in."""
    if cfg.moe_impl == "capacity":
        from repro.dist.constrain import _context_mesh
        from repro.dist.moe_ep import apply_moe_capacity
        mesh = _context_mesh()
        if mesh is not None and hasattr(mesh, "devices"):
            return apply_moe_capacity(h, p, cfg, mesh)
    return M.apply_moe(h, p, cfg)


def apply_layer(x: Array, p: dict, cfg: ModelConfig, spec: LayerSpec, *,
                shared: Optional[dict] = None,
                cross_src: Optional[Array] = None,
                causal: bool = True) -> tuple[Array, Array]:
    """Training/prefill forward of one layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.kind in ("attn", "attn_moe", "cross_attn"):
        h = L.apply_norm(x, p["norm1"], cfg.norm)
        h = A.attend(h, p["attn"], cfg, window=spec.window, causal=causal)
        x = _residual(x, h, p, cfg, "post1")
        if spec.kind == "cross_attn" and cross_src is not None:
            h = L.apply_norm(x, p["norm_x"], cfg.norm)
            h = A.attend(h, p["xattn"], cfg, kv_src=cross_src, causal=False)
            x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * h
        h = L.apply_norm(x, p["norm2"], cfg.norm)
        if spec.kind == "attn_moe":
            h, aux = _apply_moe_dispatch(h, p["moe"], cfg)
        else:
            h = M.apply_mlp(h, p["mlp"], cfg)
        x = _residual(x, h, p, cfg, "post2")
    elif spec.kind in ("mamba", "mamba_attn"):
        h = L.apply_norm(x, p["norm1"], cfg.norm)
        x = x + S.apply_ssm(h, p["ssm"], cfg)
        if spec.kind == "mamba_attn":
            assert shared is not None
            h = L.apply_norm(x, shared["norm1"], cfg.norm)
            x = x + A.attend(h, shared["attn"], cfg, causal=causal)
            h = L.apply_norm(x, shared["norm2"], cfg.norm)
            x = x + M.apply_mlp(h, shared["mlp"], cfg)
    elif spec.kind == "rwkv":
        h = L.apply_norm(x, p["norm1"], cfg.norm)
        y, _, _ = R.apply_time_mix(h, p["tm"], cfg)
        x = x + y
        h = L.apply_norm(x, p["norm2"], cfg.norm)
        y, _ = R.apply_channel_mix(h, p["cm"], cfg)
        x = x + y
    else:
        raise ValueError(spec.kind)
    return x, aux


# ---------------------------------------------------------------------------
# Decode-path per-layer state
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype) -> Any:
    if spec.kind in ("attn", "attn_moe", "cross_attn"):
        win = spec.window
        cache_len = min(max_len, win) if win else max_len
        return A.init_cache(cfg, batch, cache_len, dtype)
    if spec.kind in ("mamba", "mamba_attn"):
        ssm = S.init_ssm_state(cfg, batch, dtype)
        if spec.kind == "mamba_attn":
            return (ssm, A.init_cache(cfg, batch, max_len, dtype))
        return ssm
    if spec.kind == "rwkv":
        return R.init_rwkv_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


def decode_layer(x: Array, cache: Any, p: dict, cfg: ModelConfig,
                 spec: LayerSpec, *, shared: Optional[dict] = None,
                 cross_kv: Optional[tuple] = None) -> tuple[Array, Any]:
    """Single-token decode step of one layer."""
    if spec.kind in ("attn", "attn_moe", "cross_attn"):
        h = L.apply_norm(x, p["norm1"], cfg.norm)
        h, cache = A.decode_attend(h, cache, p["attn"], cfg,
                                   window=spec.window)
        x = _residual(x, h, p, cfg, "post1")
        if spec.kind == "cross_attn" and cross_kv is not None:
            h = L.apply_norm(x, p["norm_x"], cfg.norm)
            h = A.cross_attend_cached(h, cross_kv, p["xattn"], cfg)
            x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * h
        h = L.apply_norm(x, p["norm2"], cfg.norm)
        if spec.kind == "attn_moe":
            h, _ = M.apply_moe(h, p["moe"], cfg)
        else:
            h = M.apply_mlp(h, p["mlp"], cfg)
        x = _residual(x, h, p, cfg, "post2")
        return x, cache
    if spec.kind in ("mamba", "mamba_attn"):
        if spec.kind == "mamba_attn":
            ssm_state, kv = cache
        else:
            ssm_state, kv = cache, None
        h = L.apply_norm(x, p["norm1"], cfg.norm)
        y, ssm_state = S.decode_ssm(h, ssm_state, p["ssm"], cfg)
        x = x + y
        if spec.kind == "mamba_attn":
            assert shared is not None
            h = L.apply_norm(x, shared["norm1"], cfg.norm)
            y, kv = A.decode_attend(h, kv, shared["attn"], cfg)
            x = x + y
            h = L.apply_norm(x, shared["norm2"], cfg.norm)
            x = x + M.apply_mlp(h, shared["mlp"], cfg)
            return x, (ssm_state, kv)
        return x, ssm_state
    if spec.kind == "rwkv":
        h = L.apply_norm(x, p["norm1"], cfg.norm)
        y, wkv, last_tm = R.apply_time_mix(h, p["tm"], cfg, state=cache)
        x = x + y
        h = L.apply_norm(x, p["norm2"], cfg.norm)
        y, last_cm = R.apply_channel_mix(h, p["cm"], cfg, prev=cache.shift_cm)
        x = x + y
        new = R.RWKVState(wkv=wkv, shift_tm=last_tm.astype(cache.shift_tm.dtype),
                          shift_cm=last_cm.astype(cache.shift_cm.dtype),
                          length=cache.length + 1)
        return x, new
    raise ValueError(spec.kind)
