"""Attention: GQA/MHA with RoPE, sliding-window and local/global variants,
logit soft-capping, cross-attention, KV caches, and a flash-style chunked
implementation (online softmax over KV blocks) so 32k-token prefill never
materializes an (S, S) score matrix.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.dist import constrain as C
from repro.kernels import dispatch as KD
from repro.kernels import ref as KREF
from repro.models import layers as L

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, T, H, hd); positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    if angles.ndim == 2:                                # (T, hd/2) -> batch dim
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameter init / projections
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(ks[0], d, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "wk": L.init_linear(ks[1], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": L.init_linear(ks[2], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": L.init_linear(ks[3], cfg.num_heads * hd, d),
    }


class KVCache(NamedTuple):
    k: Array          # (B, S_max, K, hd)
    v: Array          # (B, S_max, K, hd)
    length: Array     # () int32 — tokens currently cached


class QuantKVCache(NamedTuple):
    """Power-aware KV cache: K/V as packed bit-plane affine codes.

    Codes are unsigned affine (``core.quant.affine_encode``), bit-plane
    decomposed and packed 8/byte along head_dim (``kernels.ref.
    pack_cache_codes``); the plane axis sits behind the batch/scan dims and
    is pinned at ``kernels.ref.CACHE_PLANES`` whatever the rung's cache
    bits, so every cache rung shares ONE pytree structure and one jitted
    decode step (the ladder invariant). Quantizer (s, z) are per position —
    dynamic ranges vary per token; frozen (calibrated) ranges broadcast one
    scalar — with z integer-valued f32 (docs/kv_cache.md).
    """
    k_planes: Array   # (B, P, S_max, K, hd//8) uint8
    v_planes: Array   # (B, P, S_max, K, hd//8) uint8
    k_s: Array        # (B, S_max) f32 per-position K scales
    k_z: Array        # (B, S_max) f32 per-position K zero points (integer)
    v_s: Array        # (B, S_max) f32
    v_z: Array        # (B, S_max) f32
    length: Array     # () int32 — tokens currently cached


def _project_qkv(x: Array, kv_src: Array, p: dict, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    b, t, _ = x.shape
    s = kv_src.shape[1]
    q = L.project(x, p["wq"], cfg, "attn.wq") \
        .reshape(b, t, cfg.num_heads, hd)
    k = L.project(kv_src, p["wk"], cfg, "attn.wk") \
        .reshape(b, s, cfg.num_kv_heads, hd)
    v = L.project(kv_src, p["wv"], cfg, "attn.wv") \
        .reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# Flash-style chunked attention (training / prefill)
# ---------------------------------------------------------------------------

def _chunked_attention(q: Array, k: Array, v: Array, *,
                       causal: bool, window: Optional[int],
                       softcap_val: float, q_offset: int = 0,
                       q_chunk: int = 512, kv_chunk: int = 1024,
                       unroll: bool = False) -> Array:
    """Online-softmax attention over KV chunks.

    q: (B, T, K, G, hd) — queries grouped per KV head.
    k, v: (B, S, K, hd).
    Never materializes more than (T, kv_chunk) scores per pass.
    ``unroll`` (cost-probe mode): straight-line code so cost_analysis counts
    every chunk; block sizes grow so probe HLO stays small (FLOPs are
    identical — masking doesn't change block compute).
    """
    b, t, kh, g, hd = q.shape
    s = k.shape[1]
    scale = hd ** -0.5
    q = q * scale
    if unroll:
        q_chunk, kv_chunk = 4096, 8192
    kv_chunk = min(kv_chunk, s)
    q_chunk = min(q_chunk, t)
    if s % kv_chunk:    # short cross-attn sources (e.g. 1600 image tokens)
        kv_chunk = s
    if t % q_chunk:
        q_chunk = t
    n_kv = s // kv_chunk

    q_pos_base = jnp.arange(t) + q_offset

    def one_q_chunk(qc_idx):
        qi = q_chunk * qc_idx
        qch = jax.lax.dynamic_slice_in_dim(q, qi, q_chunk, axis=1)
        q_pos = jax.lax.dynamic_slice_in_dim(q_pos_base, qi, q_chunk)

        def body(carry, kv_idx):
            m_prev, l_prev, acc = carry
            ki = kv_chunk * kv_idx
            kch = jax.lax.dynamic_slice_in_dim(k, ki, kv_chunk, axis=1)
            vch = jax.lax.dynamic_slice_in_dim(v, ki, kv_chunk, axis=1)
            k_pos = jnp.arange(kv_chunk) + ki
            # scores: (B, Tq, K, G, Skv)
            scores = jnp.einsum("btkgh,bskh->btkgs", qch, kch,
                                preferred_element_type=jnp.float32)
            if softcap_val > 0:
                scores = L.softcap(scores, softcap_val)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
            m_cur = jnp.max(scores, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("btkgs,bskh->btkgh", p.astype(v.dtype), vch,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, q_chunk, kh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kh, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kh, g, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_kv),
                                      unroll=n_kv if unroll else 1)
        return acc / jnp.maximum(l[..., None], 1e-30)

    if t == q_chunk:
        out = one_q_chunk(0)
    elif unroll:
        outs = jnp.stack([one_q_chunk(i) for i in range(t // q_chunk)])
        out = jnp.moveaxis(outs, 0, 1).reshape(b, t, kh, g, hd)
    else:
        outs = jax.lax.map(one_q_chunk, jnp.arange(t // q_chunk))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, t, kh, g, hd)
    return out


def attend(x: Array, p: dict, cfg: ModelConfig, *,
           kv_src: Optional[Array] = None,
           positions: Optional[Array] = None,
           causal: bool = True,
           window: Optional[int] = None,
           use_rope: bool = True) -> Array:
    """Full (training / prefill) attention. x: (B, T, d).

    GQA is realized by repeating K/V to the full head count and constraining
    the head dim to the TP ("model") axis — sharding propagation does NOT
    survive the grouped 5D einsum (GSPMD replicates the score computation
    across TP, a measured 16x flop bloat; see EXPERIMENTS.md §Perf).
    """
    b, t, _ = x.shape
    kv_in = x if kv_src is None else kv_src
    q, k, v = _project_qkv(x, kv_in, p, cfg)
    if positions is None:
        positions = jnp.arange(t)
    if use_rope and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if kv_src is None:
        # observe the CACHE roles (post-RoPE K, V — exactly what decode
        # writes) so serving can freeze the cache quantizer ranges from
        # the same EMA calibration as the projection inputs
        tap = L._active_tap()
        if tap is not None:
            tap.observe("attn.k_cache", k)
            tap.observe("attn.v_cache", v)
    g = cfg.num_heads // cfg.num_kv_heads
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = C.constrain_axis(q, 2)
    k = C.constrain_axis(k, 2)
    v = C.constrain_axis(v, 2)
    qg = q.reshape(b, t, cfg.num_heads, 1, cfg.resolved_head_dim)
    out = _chunked_attention(qg, k, v, causal=causal and kv_src is None,
                             window=window, softcap_val=cfg.attn_softcap,
                             unroll=cfg.unroll_loops)
    out = out.astype(x.dtype).reshape(b, t, -1)
    return L.project(out, p["wo"], cfg, "attn.wo")


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    if cfg.cache_bits:
        # packed bit-plane cache (cfg-only knob, so params-free decode
        # state init keeps working); all-zero planes/scales are inert —
        # unwritten positions are masked off by ``length`` anyway
        assert hd % 8 == 0, (
            f"quantized KV cache packs 8 codes/byte along head_dim; "
            f"head_dim={hd} is not a multiple of 8")
        shape = (batch, KREF.CACHE_PLANES, max_len, cfg.num_kv_heads,
                 hd // 8)
        row = jnp.zeros((batch, max_len), jnp.float32)
        return QuantKVCache(k_planes=jnp.zeros(shape, jnp.uint8),
                            v_planes=jnp.zeros(shape, jnp.uint8),
                            k_s=row, k_z=row, v_s=row, v_z=row,
                            length=jnp.zeros((), jnp.int32))
    if cfg.kv_cache_dtype:
        dtype = jnp.dtype(cfg.kv_cache_dtype)
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def decode_attend(x: Array, cache: KVCache, p: dict, cfg: ModelConfig, *,
                  window: Optional[int] = None,
                  use_rope: bool = True) -> tuple[Array, KVCache]:
    """One-token decode step. x: (B, 1, d). Returns (out, updated cache).

    Sequence-parallel decode (§Perf iteration 4): the cache layout is
    (batch -> dp, seq -> model | dp) and every intermediate is constrained
    to it, so attention over the cached keys is a LOCAL partial softmax per
    shard plus a tiny reduction — instead of the all-gather of the whole
    cache that GSPMD otherwise inserts (measured 6.9e10 B/device/step).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = cache.length
    s_max = (cache.k_s if isinstance(cache, QuantKVCache)
             else cache.k).shape[1]
    batch_ax, seq_ax = C.dp_model_plan(b, s_max)
    q, k_new, v_new = _project_qkv(x, x, p, cfg)
    if use_rope:
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)
    if isinstance(cache, QuantKVCache):
        return _decode_attend_quant(x, cache, p, cfg, q, k_new, v_new,
                                    window=window, batch_ax=batch_ax)
    # masked (select) cache update: a dynamic_update_slice at a traced
    # position on the sharded seq dim triggers GSPMD's "involuntary full
    # rematerialization" — an all-gather of the WHOLE cache every step
    # (measured 7.1e10 B/device; §Perf iteration 4). The elementwise select
    # is shard-local and fuses into an in-place update on donated buffers.
    cache_plan = {0: batch_ax, 1: seq_ax}
    write = (jnp.arange(s_max) == pos)[None, :, None, None]
    k = C.constrain_spec(
        jnp.where(write, k_new.astype(cache.k.dtype), cache.k), cache_plan)
    v = C.constrain_spec(
        jnp.where(write, v_new.astype(cache.v.dtype), cache.v), cache_plan)
    g = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, 1, cfg.num_kv_heads, g, hd) * hd ** -0.5
    qg = C.constrain_spec(qg, {0: batch_ax})
    scores = jnp.einsum("btkgh,bskh->btkgs", qg, k.astype(qg.dtype),
                        preferred_element_type=jnp.float32)
    scores = C.constrain_spec(scores, {0: batch_ax, 4: seq_ax})
    if cfg.attn_softcap > 0:
        scores = L.softcap(scores, cfg.attn_softcap)
    k_pos = jnp.arange(s_max)
    valid = k_pos <= pos
    if window is not None:
        valid &= (pos - k_pos) < window
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)  # sharded-S softmax: GSPMD
    probs = C.constrain_spec(probs, {0: batch_ax, 4: seq_ax})
    out = jnp.einsum("btkgs,bskh->btkgh", probs.astype(x.dtype),
                     v.astype(x.dtype), preferred_element_type=jnp.float32)
    out = C.constrain_spec(out.astype(x.dtype).reshape(b, 1, -1),
                           {0: batch_ax})
    y = L.project(out, p["wo"], cfg, "attn.wo")
    return y, KVCache(k=k, v=v, length=pos + 1)


def _cache_rows(new: Array, s_leaf, z_leaf, n_lvl) -> tuple[Array, Array]:
    """The per-batch cache quantizer (s, z) of one new K or V token
    (B, 1, K, hd). Frozen calibration (artifact leaves hoisted by
    ``models/serving`` with the IDENTICAL ``affine_scale_zp`` op sequence)
    broadcasts one scalar; otherwise the dynamic per-batch extremes,
    zero-extended — the ``act_range_bounds(include_zero=True)`` convention
    that bounds z to [0, n] (the kernels' int32-safety requirement)."""
    b = new.shape[0]
    if s_leaf is not None:
        s = jnp.broadcast_to(jnp.asarray(s_leaf, jnp.float32).reshape(()),
                             (b,))
        z = jnp.broadcast_to(jnp.asarray(z_leaf, jnp.float32).reshape(()),
                             (b,))
        return s, z
    xf = new.astype(jnp.float32)
    lo = jnp.minimum(jnp.min(xf, axis=(1, 2, 3)), 0.0)
    hi = jnp.maximum(jnp.max(xf, axis=(1, 2, 3)), 0.0)
    return quant.affine_scale_zp(lo, hi, n_lvl)


def _cache_write(planes: Array, s_row: Array, z_row: Array, new: Array,
                 s: Array, z: Array, n_lvl, pos: Array):
    """Encode one token and select-write its packed planes + quantizer row
    at ``pos`` (masked select, not dynamic_update_slice — same GSPMD
    rationale as the fp cache write above)."""
    s_max = s_row.shape[1]
    codes = quant.affine_encode(new.astype(jnp.float32),
                                s[:, None, None, None],
                                z[:, None, None, None], n_lvl)
    codes = codes[:, 0].astype(jnp.int32)                  # (B, K, hd)
    tok = jnp.moveaxis(KREF.pack_cache_codes(codes), 0, 1)  # (B, P, K, d8)
    sel = jnp.arange(s_max) == pos
    planes = jnp.where(sel[None, None, :, None, None],
                       tok[:, :, None, :, :], planes)
    s_row = jnp.where(sel[None, :], s[:, None], s_row)
    z_row = jnp.where(sel[None, :], z[:, None], z_row)
    return planes, s_row, z_row


def _decode_attend_quant(x: Array, cache: QuantKVCache, p: dict,
                         cfg: ModelConfig, q: Array, k_new: Array,
                         v_new: Array, *, window: Optional[int],
                         batch_ax) -> tuple[Array, QuantKVCache]:
    """The quantized-cache decode step: encode-write this token's K/V at
    the rung's cache bits, then attend THROUGH the packed planes via
    ``kernels.dispatch.decode_attention`` (Pallas bit-plane kernel on TPU,
    its bit-identical jnp oracle elsewhere).

    Cache bits arrive as DATA leaves (``p["kv_cache"]["k_nlvl"]``/
    ``v_nlvl``, built per rung by ``models/serving``) so mixed cache-rung
    ladders share one compilation; raw params fall back to the static
    ``cfg.cache_bits``. Frozen ranges ride as hoisted ``k_s``/``k_z``/
    ``v_s``/``v_z`` scalar leaves next to them.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = cache.length
    kc = p.get("kv_cache", {}) if hasattr(p, "get") else {}

    def nlvl(leaf):
        if leaf is not None:
            return jnp.asarray(leaf, jnp.float32).reshape(())
        bits = int(cfg.cache_bits or 8)
        return jnp.float32(quant.cap_levels(bits))

    k_nlvl = nlvl(kc.get("k_nlvl"))
    v_nlvl = nlvl(kc.get("v_nlvl"))
    ks, kz = _cache_rows(k_new, kc.get("k_s"), kc.get("k_z"), k_nlvl)
    vs, vz = _cache_rows(v_new, kc.get("v_s"), kc.get("v_z"), v_nlvl)
    kp, ks_row, kz_row = _cache_write(cache.k_planes, cache.k_s, cache.k_z,
                                      k_new, ks, kz, k_nlvl, pos)
    vp, vs_row, vz_row = _cache_write(cache.v_planes, cache.v_s, cache.v_z,
                                      v_new, vs, vz, v_nlvl, pos)
    kp = C.constrain_spec(kp, {0: batch_ax})
    vp = C.constrain_spec(vp, {0: batch_ax})
    view = QuantKVCache(k_planes=kp, v_planes=vp, k_s=ks_row, k_z=kz_row,
                        v_s=vs_row, v_z=vz_row, length=pos)
    out = KD.decode_attention(q.reshape(b, cfg.num_heads, hd), view,
                              cfg.kernel_backend or "ref",
                              num_kv_heads=cfg.num_kv_heads, window=window,
                              softcap=cfg.attn_softcap,
                              k_nlvl=k_nlvl, v_nlvl=v_nlvl)
    out = C.constrain_spec(out.astype(x.dtype).reshape(b, 1, -1),
                           {0: batch_ax})
    y = L.project(out, p["wo"], cfg, "attn.wo")
    return y, view._replace(length=pos + 1)


def cross_attend_cached(x: Array, enc_kv: tuple[Array, Array], p: dict,
                        cfg: ModelConfig) -> Array:
    """Cross-attention against precomputed encoder/image K,V (decode path)."""
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.project(x, p["wq"], cfg, "attn.wq").reshape(
        b, t, cfg.num_heads, hd)
    k, v = enc_kv
    g = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, t, cfg.num_kv_heads, g, hd) * hd ** -0.5
    scores = jnp.einsum("btkgh,bskh->btkgs", qg, k,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("btkgs,bskh->btkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(b, t, -1)
    return L.project(out, p["wo"], cfg, "attn.wo")


def project_cross_kv(enc: Array, p: dict, cfg: ModelConfig
                     ) -> tuple[Array, Array]:
    """Project encoder outputs to (K, V) once; reused every decode step."""
    b, s, _ = enc.shape
    hd = cfg.resolved_head_dim
    k = L.project(enc, p["wk"], cfg, "attn.wk").reshape(
        b, s, cfg.num_kv_heads, hd)
    v = L.project(enc, p["wv"], cfg, "attn.wv").reshape(
        b, s, cfg.num_kv_heads, hd)
    return k, v
