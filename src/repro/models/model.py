"""Top-level model: init / forward / loss / decode for every architecture.

Layers are stacked per repeating group and scanned (lax.scan) with optional
per-group rematerialization; heterogeneous tails (e.g. zamba2's 38 = 6x6+2)
run unrolled after the scan. Decode threads stacked per-group caches through
the same scan.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import calibrate as CAL
from repro.dist import constrain as C
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T

Array = jax.Array


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_group(key, cfg: ModelConfig, pattern) -> dict:
    ks = jax.random.split(key, len(pattern))
    return {"layers": [T.init_layer(k, cfg, s) for k, s in zip(ks, pattern)]}


def _init_stack(key, cfg: ModelConfig, num_layers: Optional[int] = None,
                role: str = "decoder") -> dict:
    pattern, n_groups, n_tail = T.group_layout(cfg, num_layers, role)
    kg, kt = jax.random.split(key)
    groups = jax.vmap(lambda k: _init_group(k, cfg, pattern))(
        jax.random.split(kg, n_groups))
    out = {"groups": groups}
    if n_tail:
        tks = jax.random.split(kt, n_tail)
        out["tail"] = [T.init_layer(tks[i], cfg, pattern[i])
                       for i in range(n_tail)]
    return out


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "decoder": _init_stack(ks[1], cfg),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(ks[2], cfg.d_model,
                                          cfg.padded_vocab, scale=0.02)
    if cfg.family == "hybrid":
        params["shared_attn"] = T.init_shared_attn(ks[3], cfg)
    if cfg.family == "encdec":
        params["encoder"] = _init_stack(ks[4], cfg, cfg.encoder_layers,
                                        role="encoder")
        params["enc_norm"] = L.init_norm(cfg.d_model, cfg.norm)
    if cfg.conv_stem:
        cks = jax.random.split(ks[5], len(cfg.conv_stem))
        # dict keys (not a list) so the params walk extends the trail with
        # "s{i}" and the serving path resolves to the per-depth "conv.s{i}"
        # policy role (core/policy.serving_path)
        params["conv_stem"] = {f"s{i}": L.init_conv(cks[i], spec)
                               for i, spec in enumerate(cfg.conv_stem)}
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _run_stack(x: Array, stack: dict, cfg: ModelConfig, *, causal: bool,
               shared: Optional[dict] = None,
               cross_src: Optional[Array] = None,
               remat: bool = True, role: str = "decoder",
               calib: Optional[dict] = None
               ) -> tuple[Array, Array, Optional[dict]]:
    """Run one scanned layer stack. ``calib`` (a ``core.calibrate``
    collection) turns on activation-range observation: a tap is installed
    *inside* each scan body so observed statistics ride the scan carry, and
    the EMA ranges feed the quantizers. Returns (x, aux_loss, observed) —
    observed is None when calibration is off (the bit-exact legacy path).
    """
    pattern = T.group_pattern(cfg, role)
    collect = bool(calib)

    def run_layers(h, aux, gp):
        for i, spec in enumerate(pattern):
            h, a = T.apply_layer(h, gp["layers"][i], cfg, spec,
                                 shared=shared, cross_src=cross_src,
                                 causal=causal)
            aux = aux + a
        return h, aux

    def group_body(carry, gp):
        if not collect:
            h, aux = carry
            h, aux = run_layers(h, aux, gp)
            return (h, aux), None
        h, aux, obs = carry
        with L.calib_tap(calib) as tap:
            h, aux = run_layers(h, aux, gp)
        return (h, aux, CAL.merge(obs, tap.observed)), None

    body = jax.checkpoint(group_body) if remat else group_body
    n_groups = jax.tree_util.tree_leaves(stack["groups"])[0].shape[0]
    aux0 = jnp.zeros((), jnp.float32)
    init = (x, aux0, CAL.unseen_like(calib)) if collect else (x, aux0)
    carry, _ = jax.lax.scan(body, init, stack["groups"],
                            unroll=n_groups if cfg.unroll_loops else 1)
    if collect:
        x, aux, obs = carry
    else:
        (x, aux), obs = carry, None

    def run_tail(h, aux):
        for i, lp in enumerate(stack.get("tail", [])):
            h, a = T.apply_layer(h, lp, cfg, pattern[i % len(pattern)],
                                 shared=shared, cross_src=cross_src,
                                 causal=causal)
            aux = aux + a
        return h, aux

    if collect and stack.get("tail"):
        with L.calib_tap(calib) as tap:
            x, aux = run_tail(x, aux)
        obs = CAL.merge(obs, tap.observed)
    else:
        x, aux = run_tail(x, aux)
    return x, aux, obs


def apply_conv_stem(params: dict, cfg: ModelConfig, raw: Array) -> Array:
    """Run raw frontend input through the conv stem -> token sequence.

    raw: (B, H, W, C) pixels (vision) or (B, frames, 1, mels) features
    (speech), with (H, W) == cfg.frontend_hw. Each layer is a quantized
    conv projection (layers.apply_conv); ReLU between layers (the paper's
    CNN activation — its nonnegative range is exactly the include_zero
    affine encoding the serving kernels assume), none after the last.
    Returns (B, stem_tokens, c_out_last) flattened row-major over (H, W).
    """
    x = raw
    last = len(cfg.conv_stem) - 1
    for i, spec in enumerate(cfg.conv_stem):
        x = L.apply_conv(x, params["conv_stem"][f"s{i}"], cfg, spec,
                         f"conv.s{i}")
        if i < last:
            x = jax.nn.relu(x)
    b, h, w, c = x.shape
    return x.reshape(b, h * w, c)


def encode(params: dict, cfg: ModelConfig, inputs: Array) -> Array:
    """The batch-oriented encode path (no KV cache, whole-sequence waves).

    inputs: raw 4-D (B, H, W, C) frontend input when ``cfg.conv_stem`` is
    set, else pre-embedded (B, T, d_model) stub embeddings (the pre-conv
    behavior). Returns (B, T, d_model) encoder states:

    - encdec: conv stem -> bidirectional encoder stack -> enc_norm (the
      cross-attention source ``forward``/``init_decode_state`` consume);
    - vlm: the conv stem alone — its transformer *is* the cross-attending
      decoder, so the stem output is the image-token sequence.
    """
    dtype = _dtype(cfg)
    if cfg.conv_stem:
        assert inputs.ndim == 4, (
            f"conv_stem set: encode() wants raw (B, H, W, C), got "
            f"{inputs.shape}")
        x = apply_conv_stem(params, cfg, inputs)
    else:
        x = inputs
    x = x.astype(dtype)
    if cfg.family == "encdec":
        enc, _, _ = _run_stack(x, params["encoder"], cfg, causal=False,
                               remat=False, role="encoder")
        return L.apply_norm(enc, params["enc_norm"], cfg.norm)
    return x


class ForwardOut(NamedTuple):
    logits: Array
    aux_loss: Array
    # observed activation ranges ({path: [lo, hi]}, core/calibrate.py) when
    # the caller passed a calibration collection; None otherwise
    calib: Optional[dict] = None


def forward(params: dict, cfg: ModelConfig, tokens: Array, *,
            enc_inputs: Optional[Array] = None,
            image_embeds: Optional[Array] = None,
            remat: bool = True, calib: Optional[dict] = None) -> ForwardOut:
    """tokens: (B, T) int32. enc_inputs: (B, S_enc, d) stubbed frontend
    embeddings (encdec). image_embeds: (B, n_img, d) stubbed patch embeddings
    (vlm). ``calib``: EMA activation-range collection (``core.calibrate``) —
    quantizers use its frozen ranges and the ForwardOut reports this pass's
    observed ranges for the EMA update (power-aware QAT, DESIGN.md §9)."""
    dtype = _dtype(cfg)
    collect = bool(calib)
    x = C.constrain_batch(L.embed(tokens, params["embed"], dtype))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)

    obs = CAL.unseen_like(calib) if collect else None
    cross_src = None
    # raw 4-D frontend input runs through the conv stem first (when the
    # config owns one); 3-D input is pre-embedded (the stub path), unchanged
    if cfg.conv_stem and enc_inputs is not None and enc_inputs.ndim == 4:
        enc_inputs = apply_conv_stem(params, cfg, enc_inputs)
    if cfg.conv_stem and image_embeds is not None and image_embeds.ndim == 4:
        image_embeds = apply_conv_stem(params, cfg, image_embeds)
    if cfg.family == "encdec":
        assert enc_inputs is not None
        enc, _, enc_obs = _run_stack(enc_inputs.astype(dtype),
                                     params["encoder"], cfg, causal=False,
                                     remat=remat, role="encoder", calib=calib)
        cross_src = L.apply_norm(enc, params["enc_norm"], cfg.norm)
        if collect:
            obs = CAL.merge(obs, enc_obs)
    elif cfg.family == "vlm":
        assert image_embeds is not None
        cross_src = image_embeds.astype(dtype)

    x, aux, dec_obs = _run_stack(x, params["decoder"], cfg, causal=True,
                                 shared=params.get("shared_attn"),
                                 cross_src=cross_src, remat=remat,
                                 calib=calib)
    if collect:
        obs = CAL.merge(obs, dec_obs)
    x = L.apply_norm(x, params["final_norm"], cfg.norm)

    def head(h):
        if cfg.tie_embeddings:
            return L.unembed(h, params["embed"],
                             L.module_quant(cfg, "lm_head"))
        return L.project(h, params["lm_head"], cfg, "lm_head")

    if collect:
        with L.calib_tap(calib) as tap:
            logits = head(x)
        obs = CAL.merge(obs, tap.observed)
    else:
        logits = head(x)
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return ForwardOut(logits=logits, aux_loss=aux, calib=obs)


def lm_loss(params: dict, cfg: ModelConfig, tokens: Array, labels: Array,
            *, enc_inputs=None, image_embeds=None, remat: bool = True,
            aux_weight: float = 0.01, calib: Optional[dict] = None,
            return_calib: bool = False):
    out = forward(params, cfg, tokens, enc_inputs=enc_inputs,
                  image_embeds=image_embeds, remat=remat, calib=calib)
    logits = out.logits
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = loss + aux_weight * out.aux_loss
    if return_calib:
        return loss, out.calib
    return loss


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any            # stacked per-group caches (+ "tail" list)
    cross_kv: Any          # stacked per-group cross (K, V) or None
    position: Array


def init_decode_state(params: dict, cfg: ModelConfig, batch: int,
                      max_len: int, *, enc_inputs=None, image_embeds=None
                      ) -> DecodeState:
    dtype = _dtype(cfg)
    pattern, n_groups, n_tail = T.group_layout(cfg)

    def one_group_cache(_):
        return tuple(T.init_layer_cache(cfg, s, batch, max_len, dtype)
                     for s in pattern)

    caches = {"groups": jax.vmap(one_group_cache)(jnp.arange(n_groups))}
    if n_tail:
        caches["tail"] = [T.init_layer_cache(cfg, pattern[i], batch, max_len,
                                             dtype) for i in range(n_tail)]

    cross_kv = None
    if cfg.family in ("encdec", "vlm"):
        if cfg.conv_stem and enc_inputs is not None and enc_inputs.ndim == 4:
            enc_inputs = apply_conv_stem(params, cfg, enc_inputs)
        if cfg.conv_stem and image_embeds is not None \
                and image_embeds.ndim == 4:
            image_embeds = apply_conv_stem(params, cfg, image_embeds)
        if cfg.family == "encdec":
            assert enc_inputs is not None
            enc, _, _ = _run_stack(enc_inputs.astype(dtype),
                                   params["encoder"], cfg, causal=False,
                                   remat=False, role="encoder")
            src = L.apply_norm(enc, params["enc_norm"], cfg.norm)
        else:
            assert image_embeds is not None
            src = image_embeds.astype(dtype)

        # project cross K/V once per cross-attn layer (stacked over groups)
        def project_group(gp):
            outs = []
            for i, spec in enumerate(pattern):
                if spec.kind == "cross_attn":
                    outs.append(A.project_cross_kv(src,
                                                   gp["layers"][i]["xattn"],
                                                   cfg))
            return tuple(outs)

        cross_kv = jax.vmap(project_group)(params["decoder"]["groups"])
    return DecodeState(caches=caches, cross_kv=cross_kv,
                       position=jnp.zeros((), jnp.int32))


def decode_step(params: dict, cfg: ModelConfig, state: DecodeState,
                tokens: Array) -> tuple[Array, DecodeState]:
    """tokens: (B, 1) -> (logits (B, 1, V), new state)."""
    dtype = _dtype(cfg)
    pattern, _, _ = T.group_layout(cfg)
    shared = params.get("shared_attn")
    x = C.constrain_batch(L.embed(tokens, params["embed"], dtype))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)

    def group_body(h, xs):
        gp, gcache, gcross = xs
        new_caches = []
        xi = 0
        for i, spec in enumerate(pattern):
            ckv = None
            if spec.kind == "cross_attn" and gcross is not None:
                ckv = gcross[xi]
                xi += 1
            h, c = T.decode_layer(h, gcache[i], gp["layers"][i], cfg, spec,
                                  shared=shared, cross_kv=ckv)
            new_caches.append(c)
        return h, tuple(new_caches)

    xs = (params["decoder"]["groups"], state.caches["groups"], state.cross_kv)
    n_groups = jax.tree_util.tree_leaves(xs[0])[0].shape[0]
    # ALWAYS unrolled for decode: a while-loop over groups makes GSPMD
    # all-gather the stacked KV caches as loop xs (measured 7.1e10 B/device
    # per step — the entire global cache; §Perf iteration 4b). Decode bodies
    # are single-token, so straight-line code is cheap to compile and keeps
    # every layer's cache shard-local.
    x, new_group_caches = jax.lax.scan(group_body, x, xs, unroll=n_groups)

    new_caches = {"groups": new_group_caches}
    if "tail" in state.caches:
        tail_caches = []
        for i, lp in enumerate(params["decoder"].get("tail", [])):
            x, c = T.decode_layer(x, state.caches["tail"][i], lp, cfg,
                                  pattern[i % len(pattern)], shared=shared)
            tail_caches.append(c)
        new_caches["tail"] = tail_caches

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = L.unembed(x, params["embed"],
                           L.module_quant(cfg, "lm_head"))
    else:
        logits = L.project(x, params["lm_head"], cfg, "lm_head")
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits, DecodeState(caches=new_caches, cross_kv=state.cross_kv,
                               position=state.position + 1)
