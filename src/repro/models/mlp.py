"""Feed-forward blocks: dense (SwiGLU / GELU / ReLU) and Mixture-of-Experts.

MoE baseline: top-k softmax router + a scan over experts, each expert a
TP-sharded FFN, with per-token gates zeroed for non-selected experts. This is
GSPMD-friendly and memory-bounded (one expert's activations at a time), at
the cost of E/k redundant FLOPs — the expert-parallel dispatch path in
``repro.dist.moe_ep`` removes that overhead (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain as C
from repro.models import layers as L

Array = jax.Array


def _act(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "silu": jax.nn.silu}.get(name, jax.nn.silu)


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"w_gate": L.init_linear(ks[0], d, ff),
                "w_up": L.init_linear(ks[1], d, ff),
                "w_down": L.init_linear(ks[2], ff, d)}
    return {"w_up": L.init_linear(ks[1], d, ff),
            "w_down": L.init_linear(ks[2], ff, d)}


def apply_mlp(x: Array, p: dict, cfg: ModelConfig) -> Array:
    if cfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(L.project(x, p["w_gate"], cfg, "mlp.w_gate")) \
            * L.project(x, p["w_up"], cfg, "mlp.w_up")
    else:
        h = _act(cfg.activation)(
            L.project(x, p["w_up"], cfg, "mlp.w_up"))
    return L.project(h, p["w_down"], cfg, "mlp.w_down")


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    e = cfg.moe.num_experts
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale = d ** -0.5

    def stack(k, shape_in, shape_out):
        return jax.random.normal(k, (e, shape_in, shape_out),
                                 jnp.float32) * scale

    return {
        "router": L.init_linear(ks[0], d, e, scale=0.02),
        "w_gate": stack(ks[1], d, ff),
        "w_up": stack(ks[2], d, ff),
        "w_down": jax.random.normal(ks[3], (e, ff, d), jnp.float32) * ff ** -0.5,
    }


def router_topk(logits: Array, top_k: int) -> tuple[Array, Array]:
    """Softmax-after-topk gates (Mixtral convention). Returns (gates, mask).

    gates: (..., E) with zeros outside the top-k; mask: bool (..., E).
    """
    e = logits.shape[-1]
    vals, idx = jax.lax.top_k(logits, top_k)
    probs = jax.nn.softmax(vals, axis=-1)
    one_hot = jax.nn.one_hot(idx, e, dtype=logits.dtype)  # (..., k, E)
    gates = jnp.einsum("...ke,...k->...e", one_hot, probs)
    mask = gates > 0
    return gates, mask


def route(x: Array, p: dict, cfg: ModelConfig
          ) -> tuple[Array, Array, Array]:
    """Shared router: (gates, mask, aux_loss). Both the dense scan and the
    expert-parallel capacity path (repro.dist.moe_ep) call this, so the
    Switch-style load-balance loss E * sum_e f_e * p_e is one definition.
    """
    assert cfg.moe is not None
    e = cfg.moe.num_experts
    logits = L.apply_linear(x, p["router"],
                            L.module_quant(cfg, "moe.router"),
                            path="moe.router").astype(jnp.float32)
    gates, mask = router_topk(logits, cfg.moe.top_k)
    probs_full = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(mask.astype(jnp.float32), axis=(0, 1))        # fraction routed
    pbar = jnp.mean(probs_full, axis=(0, 1))
    aux = e * jnp.sum(f * pbar)
    return gates, mask, aux


def expert_ffn(x: Array, w_gate: Array, w_up: Array, w_down: Array,
               cfg: ModelConfig) -> Array:
    """One expert's gated FFN. The single definition shared by the dense
    scan below and the capacity-dispatch path (repro.dist.moe_ep), which
    must stay numerically identical to it.

    Runs under ``calib_suspend``: the expert body executes inside an inner
    ``lax.scan`` (or shard_map), so observing into the layer-stack tap
    would leak inner-trace values; expert projections keep dynamic
    activation ranges (the roles stay unseen → export leaves them dynamic
    too). The router, which runs in the outer scope, is calibrated."""
    act = jax.nn.silu if cfg.activation in ("swiglu", "geglu") else \
        _act(cfg.activation)
    with L.calib_suspend():
        h = act(L.qlinear(x, w_gate.astype(x.dtype), None,
                          L.module_quant(cfg, "moe.w_gate"),
                          path="moe.w_gate")) \
            * L.qlinear(x, w_up.astype(x.dtype), None,
                        L.module_quant(cfg, "moe.w_up"), path="moe.w_up")
        # pin TP sharding: propagation dies through the scan-sliced /
        # vmapped expert weights and GSPMD otherwise computes the FULL d_ff
        # per device (measured 16x FLOP bloat; EXPERIMENTS.md §Perf 3a)
        h = C.constrain_axis(h, -1, "model")
        return L.qlinear(h, w_down.astype(x.dtype), None,
                         L.module_quant(cfg, "moe.w_down"),
                         path="moe.w_down")


def apply_moe(x: Array, p: dict, cfg: ModelConfig) -> tuple[Array, Array]:
    """x: (B, T, d) -> (y, aux_loss). Scan over experts (see module doc)."""
    assert cfg.moe is not None
    e = cfg.moe.num_experts
    gates, _, aux = route(x, p, cfg)

    def expert_step(carry, ew):
        w_gate, w_up, w_down, gate_e = ew
        y_e = expert_ffn(x, w_gate, w_up, w_down, cfg)
        return carry + gate_e[..., None].astype(x.dtype) * y_e, None

    gates_t = jnp.moveaxis(gates, -1, 0)                        # (E, B, T)
    y0 = jnp.zeros_like(x)
    y, _ = jax.lax.scan(expert_step, y0,
                        (p["w_gate"], p["w_up"], p["w_down"], gates_t),
                        unroll=e if cfg.unroll_loops else 1)
    return y, aux
