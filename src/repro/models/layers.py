"""Shared layers: norms, embeddings, and QuantLinear — the single choke point
through which every projection in every architecture runs, realizing the
paper's quantization modes (none / RUQ / RUQ-unsigned / PANN).

Activation handling for transformers (a generalization the paper doesn't
need for its ReLU CNNs): activations into projections are signed, so we use
*asymmetric* (zero-point) quantization: x ~ s (x_q - z) with unsigned codes
x_q. Then W x = s (W x_q) - s z (sum_k W[k, :]) — the correction term is a
per-output constant folded into the bias, so the MACs stay unsigned and the
Sec.-4 accumulator saving is preserved. See DESIGN.md §4.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import pann as pann_core
from repro.core import quant
from repro.kernels import dispatch
from repro.kernels import pann_conv as _pc

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale)).astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(x: Array, params: dict, kind: str) -> Array:
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def init_norm(d: int, kind: str) -> dict:
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


def softcap(x: Array, cap: float) -> Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# Asymmetric (zero-point) activation quantization
# ---------------------------------------------------------------------------

def affine_act_quant(x: Array, bits: int):
    """x ~= s * (q - z), q unsigned in [0, 2^b - 1]. Returns (q, s, z).

    The numerics live in ``core.quant.affine_quant_levels`` — one copy
    shared with the integer serving backends (``kernels.dispatch``)."""
    return quant.affine_quant_levels(x, (1 << bits) - 1)


def affine_fake_quant(x: Array, bits: int) -> Array:
    q, s, z = affine_act_quant(x, bits)
    xq = s * (q - z)
    return x + jax.lax.stop_gradient(xq - x)


def affine_fake_quant_n(x: Array, n: Array) -> Array:
    """``affine_fake_quant`` with a *traced* level count n = 2^b - 1.

    Serving variants carry n as a data leaf (models/serving.py), so ladder
    rungs with different b~x share one jit compilation — the whole point of
    the serve_engine's recompilation-free traversal."""
    xf = x.astype(jnp.float32)
    q, s, z = quant.affine_quant_levels(xf, n)
    return (s * (q - z)).astype(x.dtype)


def affine_fake_quant_ranged(x: Array, bits: int, rng: Array) -> Array:
    """``affine_fake_quant`` against a calibrated [lo, hi] range (STE).

    ``rng`` is a (2,)-shaped [lo, hi] entry from the EMA calibration
    collection (``core.calibrate``); the unseen sentinel (lo > hi) falls
    back to the dynamic per-tensor range, bit-exact with
    ``affine_fake_quant``."""
    xf = x.astype(jnp.float32)
    q, s, z = quant.affine_from_range(xf, float((1 << bits) - 1),
                                      rng[0], rng[1])
    xq = s * (q - z)
    return xf + jax.lax.stop_gradient(xq - xf)


# ---------------------------------------------------------------------------
# Activation-range calibration tap (QAT observers; core/calibrate.py)
# ---------------------------------------------------------------------------

class CalibTap:
    """Trace-scoped activation observer.

    While installed (``calib_tap``), every ``qlinear`` call that knows its
    module path (a) records the per-tensor min/max of its input under that
    path into ``observed``, and (b) quantizes activations against the
    calibrated EMA range in ``ranges`` (falling back to the dynamic range
    while a role is unseen).  The tap is installed *inside* each scan body
    (``models.model``) so the observed tracers stay inside their scan scope
    and are merged out through the carry — never leaked across traces.
    """

    __slots__ = ("ranges", "observed")

    def __init__(self, ranges):
        self.ranges = ranges or {}
        self.observed: dict[str, Array] = {}

    def observe(self, path: str, x: Array) -> None:
        xf = x.astype(jnp.float32)
        rec = jnp.stack([jnp.min(xf), jnp.max(xf)])
        prev = self.observed.get(path)
        if prev is not None:
            rec = jnp.stack([jnp.minimum(prev[0], rec[0]),
                             jnp.maximum(prev[1], rec[1])])
        self.observed[path] = rec

    def range_for(self, path: str) -> Optional[Array]:
        rng = self.ranges.get(path)
        if rng is None:
            return None
        return jax.lax.stop_gradient(rng)


_TAPS: list[CalibTap] = []


@contextlib.contextmanager
def calib_tap(ranges):
    """Install an activation observer for the enclosed trace scope."""
    tap = CalibTap(ranges)
    _TAPS.append(tap)
    try:
        yield tap
    finally:
        _TAPS.pop()


@contextlib.contextmanager
def calib_suspend():
    """Mask the active tap for a nested trace scope.

    Projections that run inside an INNER ``lax.scan`` (the MoE expert loop)
    must not observe into the outer tap: their min/max tracers belong to
    the inner trace and leak (UnexpectedTracerError) when the outer scope
    merges them. Suspended projections keep dynamic per-tensor ranges; the
    roles stay *unseen* in the collection, so export leaves them dynamic
    too — the train→serve agreement is preserved, just without frozen
    ranges for those roles."""
    _TAPS.append(None)
    try:
        yield
    finally:
        _TAPS.pop()


def _active_tap() -> Optional[CalibTap]:
    return _TAPS[-1] if _TAPS else None


def _act_fake_quant(x: Array, bits: int, path: Optional[str]) -> Array:
    """The activation side of ``qlinear``: dynamic per-tensor fake-quant,
    upgraded to observed + EMA-calibrated quantization when a tap is
    installed and the call site identified itself with a module path."""
    xf = x.astype(jnp.float32)
    tap = _active_tap()
    if tap is not None and path is not None:
        tap.observe(path, xf)
        rng = tap.range_for(path)
        if rng is not None:
            return affine_fake_quant_ranged(xf, bits, rng)
    return affine_fake_quant(xf, bits)


# ---------------------------------------------------------------------------
# QuantLinear
# ---------------------------------------------------------------------------

def module_quant(cfg, path: str):
    """Resolve the quant spec of the module at ``path`` ("attn.wq",
    "mlp.w_down", ...; vocabulary in core/policy.py).

    Without a policy tree this returns the global ``cfg.quant`` — the exact
    pre-policy object down the exact pre-policy code path, so uniform
    configs are bit-identical to the pre-refactor behavior. With one, each
    projection gets its own ``ModuleQuant`` (whose QuantConfig-compatible
    aliases feed the same ``qlinear`` branches).
    """
    if cfg.policy is None:
        return cfg.quant
    return cfg.policy.lookup(path)


def qlinear(x: Array, w: Array, b: Optional[Array], qc,
            path: Optional[str] = None) -> Array:
    """y = quant(x) @ quant(w) + b under the configured scheme.
    ``qc`` is a ``QuantConfig`` or a per-module ``core.policy.ModuleQuant``
    (attribute-compatible).  ``path`` is the module's canonical policy path
    ("attn.wq", ...): when given and a calibration tap is installed
    (``calib_tap``), the activation range is observed and the EMA-calibrated
    range drives the quantizer — without a tap the path is inert and the
    numerics are bit-exact with the pre-calibration behavior.

    Shapes: x (..., d_in), w (d_in, d_out). All schemes are implemented as
    (differentiable) fake-quant so the same code path serves PTQ evaluation
    and QAT (STE); the integer-exact deployment path lives in repro.kernels.

    'ruq_unsigned' is numerically identical to 'ruq' (Eq. 5-6 is exact) — the
    difference is pure power accounting — so it shares the ruq compute path;
    the split itself is exercised by repro.core.unsigned and the kernels.
    """
    mode = qc.mode
    dtype = x.dtype
    if mode == "none":
        y = x @ w
    elif mode in ("ruq", "ruq_unsigned"):
        wq = quant.fake_quant(w.astype(jnp.float32), qc.weight_bits,
                              signed=True, axis=0).astype(dtype)
        xq = _act_fake_quant(x, qc.act_bits, path).astype(dtype)
        y = xq @ wq
    elif mode == "pann":
        # the STE branch lives in core.pann so PANN's training semantics sit
        # beside its deployment semantics; per-module (b~x, R) comes from qc
        tap = _active_tap()
        rng = None
        if tap is not None and path is not None:
            tap.observe(path, x)
            rng = tap.range_for(path)
        y = pann_core.pann_qat_matmul(x, w, qc, act_range=rng)
    else:
        raise ValueError(f"unknown quant mode {mode!r}")
    if b is not None:
        y = y + b
    return y


def project(x: Array, p: dict, cfg, path: str) -> Array:
    """The one-call projection idiom: resolve the module's policy
    (``module_quant``), route through ``apply_linear`` with the configured
    kernel backend, and identify the module to the calibration tap. Every
    model projection goes through here so the per-module (b̃x, R) operating
    point and its observed activation range always travel together."""
    return apply_linear(x, p, module_quant(cfg, path),
                        backend=cfg.kernel_backend, path=path)


def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                scale: float | None = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_linear(x: Array, p: dict, qc, backend: Optional[str] = None,
                 path: Optional[str] = None) -> Array:
    """The projection entry point. Training params route through ``qlinear``;
    a serving artifact ("w_q" present) routes through the selected kernel
    backend (``kernels.dispatch``: 'ref' | 'fused' | 'packed' — call sites
    thread ``cfg.kernel_backend``), or through the legacy float dequant
    below when ``backend`` is None (the pre-dispatch behavior, bit-exact).
    ``path`` identifies the module for activation-range calibration
    (``calib_tap``); inert unless a tap is installed.
    """
    b = p.get("b")
    b = None if b is None else b.astype(x.dtype)
    if "w_q" in p:
        if backend is not None:
            return dispatch.serving_linear(x, p, backend)
        # legacy serving path (models/serving.py): PANN int codes +
        # per-channel gamma, dequantized on load — weight-read bytes are the
        # int8 codes. "act_n" (= 2^b~x - 1, a data leaf so rungs share one
        # compilation) quantizes activations at the operating point's b~x;
        # "act_lo"/"act_hi" (export-frozen EMA calibration, launch/export.py)
        # pin the range statically so serving reproduces calibrated QAT.
        w = (p["w_q"].astype(jnp.float32)
             * p["w_scale"]).astype(x.dtype)
        if "act_lo" in p:
            xf = x.astype(jnp.float32)
            q, s, z = quant.affine_from_range(xf, p["act_n"],
                                              p["act_lo"], p["act_hi"])
            x = (s * (q - z)).astype(x.dtype)
        elif "act_n" in p:
            x = affine_fake_quant_n(x, p["act_n"])
        y = x @ w
        return y if b is None else y + b
    return qlinear(x, p["w"].astype(x.dtype), b, qc, path=path)


# ---------------------------------------------------------------------------
# Conv stem (modality frontend)
# ---------------------------------------------------------------------------

def init_conv(key, spec) -> dict:
    """One conv-stem layer. The kernel is stored FLAT as a
    (kh*kw*c_in, c_out) matrix (kernels/pann_conv layout contract: feature
    order (di, dj, c) ⇔ HWIO reshape), so the quantizers, the serving
    artifact, and the rung-view machinery all see an ordinary linear with
    fan-in kh*kw*c_in. Conv stems conventionally carry a bias; the serving
    path folds it into the exact int32 zcol correction (kernels.dispatch)."""
    scale = spec.fan_in ** -0.5
    return {"w": jax.random.normal(key, (spec.fan_in, spec.c_out),
                                   jnp.float32) * scale,
            "b": jnp.zeros((spec.c_out,), jnp.float32)}


def apply_conv(x: Array, p: dict, cfg, spec, path: str) -> Array:
    """Conv projection through the same choke point as every linear.

    x: (B, H, W, C) raw frontend input. Serving artifacts ("w_q" + a kernel
    backend) route through ``dispatch.serving_conv`` — im2col over the fused
    /packed integer matmuls, bit-identical to the int32 conv oracle. The
    training / float path lowers to the *same* im2col (pad -> patches ->
    matmul) and reuses ``apply_linear``: QAT fake-quant, calibration taps,
    and the legacy dequant path all apply to conv exactly as to linears.
    """
    if "w_q" in p and cfg.kernel_backend is not None:
        return dispatch.serving_conv(x, p, spec, cfg.kernel_backend)
    xpad = _pc.pad_nhwc(x.astype(jnp.float32), spec.ph, spec.pw)
    patches = _pc.extract_patches(xpad, spec.kh, spec.kw, spec.sh, spec.sw)
    b, ho, wo, _ = patches.shape
    flat = patches.reshape(b * ho * wo, -1).astype(x.dtype)
    y = apply_linear(flat, p, module_quant(cfg, path),
                     backend=None, path=path)
    return y.reshape(b, ho, wo, spec.c_out).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(tokens: Array, p: dict, dtype) -> Array:
    return p["table"].astype(dtype)[tokens]


def unembed(x: Array, p: dict, qc) -> Array:
    """LM head (weight-activation matmul -> quantized like any projection)."""
    return qlinear(x, jnp.transpose(p["table"]).astype(x.dtype), None, qc,
                   path="lm_head")
