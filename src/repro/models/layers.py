"""Shared layers: norms, embeddings, and QuantLinear — the single choke point
through which every projection in every architecture runs, realizing the
paper's quantization modes (none / RUQ / RUQ-unsigned / PANN).

Activation handling for transformers (a generalization the paper doesn't
need for its ReLU CNNs): activations into projections are signed, so we use
*asymmetric* (zero-point) quantization: x ~ s (x_q - z) with unsigned codes
x_q. Then W x = s (W x_q) - s z (sum_k W[k, :]) — the correction term is a
per-output constant folded into the bias, so the MACs stay unsigned and the
Sec.-4 accumulator saving is preserved. See DESIGN.md §4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import pann as pann_core
from repro.core import quant
from repro.kernels import dispatch

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale)).astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(x: Array, params: dict, kind: str) -> Array:
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def init_norm(d: int, kind: str) -> dict:
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


def softcap(x: Array, cap: float) -> Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# Asymmetric (zero-point) activation quantization
# ---------------------------------------------------------------------------

def affine_act_quant(x: Array, bits: int):
    """x ~= s * (q - z), q unsigned in [0, 2^b - 1]. Returns (q, s, z).

    The numerics live in ``core.quant.affine_quant_levels`` — one copy
    shared with the integer serving backends (``kernels.dispatch``)."""
    return quant.affine_quant_levels(x, (1 << bits) - 1)


def affine_fake_quant(x: Array, bits: int) -> Array:
    q, s, z = affine_act_quant(x, bits)
    xq = s * (q - z)
    return x + jax.lax.stop_gradient(xq - x)


def affine_fake_quant_n(x: Array, n: Array) -> Array:
    """``affine_fake_quant`` with a *traced* level count n = 2^b - 1.

    Serving variants carry n as a data leaf (models/serving.py), so ladder
    rungs with different b~x share one jit compilation — the whole point of
    the serve_engine's recompilation-free traversal."""
    xf = x.astype(jnp.float32)
    q, s, z = quant.affine_quant_levels(xf, n)
    return (s * (q - z)).astype(x.dtype)


# ---------------------------------------------------------------------------
# QuantLinear
# ---------------------------------------------------------------------------

def module_quant(cfg, path: str):
    """Resolve the quant spec of the module at ``path`` ("attn.wq",
    "mlp.w_down", ...; vocabulary in core/policy.py).

    Without a policy tree this returns the global ``cfg.quant`` — the exact
    pre-policy object down the exact pre-policy code path, so uniform
    configs are bit-identical to the pre-refactor behavior. With one, each
    projection gets its own ``ModuleQuant`` (whose QuantConfig-compatible
    aliases feed the same ``qlinear`` branches).
    """
    if cfg.policy is None:
        return cfg.quant
    return cfg.policy.lookup(path)


def qlinear(x: Array, w: Array, b: Optional[Array], qc) -> Array:
    """y = quant(x) @ quant(w) + b under the configured scheme.
    ``qc`` is a ``QuantConfig`` or a per-module ``core.policy.ModuleQuant``
    (attribute-compatible).

    Shapes: x (..., d_in), w (d_in, d_out). All schemes are implemented as
    (differentiable) fake-quant so the same code path serves PTQ evaluation
    and QAT (STE); the integer-exact deployment path lives in repro.kernels.

    'ruq_unsigned' is numerically identical to 'ruq' (Eq. 5-6 is exact) — the
    difference is pure power accounting — so it shares the ruq compute path;
    the split itself is exercised by repro.core.unsigned and the kernels.
    """
    mode = qc.mode
    dtype = x.dtype
    if mode == "none":
        y = x @ w
    elif mode in ("ruq", "ruq_unsigned"):
        wq = quant.fake_quant(w.astype(jnp.float32), qc.weight_bits,
                              signed=True, axis=0).astype(dtype)
        xq = affine_fake_quant(x.astype(jnp.float32),
                               qc.act_bits).astype(dtype)
        y = xq @ wq
    elif mode == "pann":
        wq = pann_core.pann_fake_quant(w.astype(jnp.float32), qc.r,
                                       axis=0).astype(dtype)
        xq = affine_fake_quant(x.astype(jnp.float32),
                               qc.act_bits_tilde).astype(dtype)
        y = xq @ wq
    else:
        raise ValueError(f"unknown quant mode {mode!r}")
    if b is not None:
        y = y + b
    return y


def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                scale: float | None = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_linear(x: Array, p: dict, qc, backend: Optional[str] = None
                 ) -> Array:
    """The projection entry point. Training params route through ``qlinear``;
    a serving artifact ("w_q" present) routes through the selected kernel
    backend (``kernels.dispatch``: 'ref' | 'fused' | 'packed' — call sites
    thread ``cfg.kernel_backend``), or through the legacy float dequant
    below when ``backend`` is None (the pre-dispatch behavior, bit-exact).
    """
    b = p.get("b")
    b = None if b is None else b.astype(x.dtype)
    if "w_q" in p:
        if backend is not None:
            return dispatch.serving_linear(x, p, backend)
        # legacy serving path (models/serving.py): PANN int codes +
        # per-channel gamma, dequantized on load — weight-read bytes are the
        # int8 codes. "act_n" (= 2^b~x - 1, a data leaf so rungs share one
        # compilation) quantizes activations at the operating point's b~x.
        w = (p["w_q"].astype(jnp.float32)
             * p["w_scale"]).astype(x.dtype)
        if "act_n" in p:
            x = affine_fake_quant_n(x, p["act_n"])
        y = x @ w
        return y if b is None else y + b
    return qlinear(x, p["w"].astype(x.dtype), b, qc)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(tokens: Array, p: dict, dtype) -> Array:
    return p["table"].astype(dtype)[tokens]


def unembed(x: Array, p: dict, qc) -> Array:
    """LM head (weight-activation matmul -> quantized like any projection)."""
    return qlinear(x, jnp.transpose(p["table"]).astype(x.dtype), None, qc)
