"""Serving-time weight quantization: materialize PANN's deployment artifact.

Every projection weight is replaced by its PANN integer codes (Eq. 12,
per-output-channel gamma) stored in int8 — b_R <= 5 bits in practice
(Table 14), so int8 holds them losslessly — with dequant-on-load in the
forward. This is the §Perf iteration-5 change: decode is memory-bound and
weight-read bytes drop 2x vs bf16 (4x vs f32); the Pallas bit-plane kernel
(repro.kernels.pann_matmul) realizes the full b_R-bit layout on TPU.

Activations stay in the compute dtype (W-PANN/A16); the PTQ accuracy story
at matched power is measured separately in benchmarks/table2_ptq.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import pann as pann_core

# projection parents whose "w" is PANN-quantized for serving
_QUANT_PARENTS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj",
    "out_proj", "wr", "wg", "decay_a", "decay_b", "lm_head",
}


def quantize_params_for_serving(params: Any, cfg: ModelConfig,
                                r: float | None = None,
                                store_dtype=jnp.int8) -> Any:
    """Walk the param tree; replace {"w": W} under known projections with
    {"w_q": int codes, "w_scale": gamma}. MoE stacked experts and the
    embedding gather table stay in floating point (documented)."""
    r = r if r is not None else cfg.quant.r

    def walk(node, name=""):
        if isinstance(node, dict):
            if "w" in node and name in _QUANT_PARENTS \
                    and getattr(node["w"], "ndim", 0) >= 2:
                w = node["w"]
                w_q, gamma = pann_core.pann_quantize(
                    w.astype(jnp.float32), r, axis=w.ndim - 2)
                out = {
                    "w_q": jnp.clip(w_q, -127, 127).astype(store_dtype),
                    "w_scale": gamma.astype(jnp.float32),
                }
                if "b" in node:
                    out["b"] = node["b"]
                return out
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, name) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v, name) for v in node)
        return node

    return walk(params)
