"""Serving-time weight quantization: materialize PANN's deployment artifact.

Every projection weight is replaced by its PANN integer codes (Eq. 12,
per-output-channel gamma) stored in int8 — b_R <= 5 bits in practice
(Table 14), so int8 holds them losslessly — with dequant-on-load in the
forward. This is the §Perf iteration-5 change: decode is memory-bound and
weight-read bytes drop 2x vs bf16 (4x vs f32); the Pallas bit-plane kernel
(repro.kernels.pann_matmul) realizes the full b_R-bit layout on TPU.

By default activations stay in the compute dtype (W-PANN/A16); the PTQ
accuracy story at matched power is measured separately in
benchmarks/table2_ptq.py. Passing ``act_bits`` additionally quantizes
activations at b~x in the forward (stored as a data leaf so serve-engine
rungs share one compilation) — the full (b~x, R) operating point.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import pann as pann_core
from repro.core import policy as pol
from repro.core import quant as quant_core
from repro.core.unsigned import unsigned_split
from repro.dist import sharding as shardlib
from repro.kernels.pann_matmul_packed import pack_planes

# projection parents whose "w" is PANN-quantized for serving
_QUANT_PARENTS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj",
    "out_proj", "wr", "wg", "decay_a", "decay_b", "lm_head",
}


def _is_quant_parent(node: dict, trail: tuple) -> bool:
    """Does this pytree node hold a projection weight to quantize?

    Conv-stem layers (``params["conv_stem"]["s0"]`` etc.) qualify by trail,
    not by leaf name: their ``w`` is the FLAT (kh·kw·Cin, Cout) matrix of
    kernels/pann_conv's layout contract, so everything below — per-Cout
    gamma, plane packing, colsum, rung views — treats it as a linear.
    """
    if "w" not in node or getattr(node["w"], "ndim", 0) < 2:
        return False
    name = trail[-1] if trail else ""
    return name in _QUANT_PARENTS or "conv_stem" in trail

# Plane count used for ladder variant caches: int8 codes are clipped to
# +-127 = 2^7 - 1, so 7 planes reconstruct EVERY rung's codes exactly AND
# give every rung identical plane-leaf avals — the one-compiled-decode-step
# invariant extends to the packed backend for free (values-only variants).
LADDER_PLANE_COUNT = 7


@dataclasses.dataclass(frozen=True)
class ServingQuantSpec:
    """Every serving-quantizer knob in ONE object — the single place new
    knobs land, threaded through the engine, export, and fleet instead of
    the historical kwarg sprawl on ``quantize_params_for_serving`` /
    ``build_variant_cache`` / ``build_weight_store``.

    ``policy`` / ``r`` + ``act_bits`` pick the operating point (a tree, or
    one global (R, b~x)); the remaining fields mean exactly what the
    same-named kwargs of ``quantize_params_for_serving`` document.
    ``cache_bits`` additionally accepts a {rung key: bits} mapping when the
    spec parameterizes a whole-ladder build. Pass as ``spec=`` to any of the
    three builders; an explicit spec supersedes the individual kwargs.
    """
    policy: Optional[pol.PolicyTree] = None
    r: Optional[float] = None
    act_bits: Optional[int] = None
    store_dtype: Any = jnp.int8
    pack_planes: bool = False
    plane_count: Optional[int] = None
    calib: Optional[Mapping[str, Any]] = None
    cache_bits: Any = None

    def for_rung(self, cache_bits: Optional[int]) -> "ServingQuantSpec":
        """The per-rung restriction a ladder builder hands the per-variant
        quantizer: same knobs, this rung's resolved cache width."""
        return dataclasses.replace(self, cache_bits=cache_bits)


def _planes_artifact(codes, plane_count: int) -> dict:
    """Bit-pack the unsigned split of int codes into the deployment layout
    consumed by the 'packed' kernel backend (kernels/pann_matmul_packed).

    codes: (..., K, N) ints. Returns uint8 leaves of shape
    (..., P, ceil(K/8), N): the plane axis sits BEHIND any scan-stacked
    layer/group dims so ``lax.scan`` still slices per-layer artifacts, and
    K is the packed axis (8 codes/byte — 2*P/8 bytes per weight for both
    signs).
    """
    pos, neg = unsigned_split(codes.astype(jnp.int32))
    out = {}
    for key, half in (("w_planes_pos", pos), ("w_planes_neg", neg)):
        planes = pann_core.bitplane_decompose(half, plane_count)
        out[key] = pack_planes(jnp.moveaxis(planes, 0, -3))
    return out


def _cache_artifact(stack, cache_role_bits, calib) -> dict:
    """Per-rung KV-cache leaves: level counts + (when the role was
    calibrated) hoisted quantizer scalars, stack-shaped so scan bodies can
    slice them. One copy shared by the legacy per-rung quantizer and the
    weight-store view builder."""
    out = {}
    for role, prefix in zip(pol.CACHE_PATHS, ("k", "v")):
        n_lvl = float(quant_core.cap_levels(cache_role_bits[role]))
        out[f"{prefix}_nlvl"] = jnp.full(stack, n_lvl, jnp.float32)
        rng = calib.get(role) if calib else None
        if rng is not None and float(rng[0]) <= float(rng[1]):
            lo = jnp.minimum(jnp.float32(rng[0]), 0.0)
            hi = jnp.maximum(jnp.float32(rng[1]), 0.0)
            s, z = quant_core.affine_scale_zp(lo, hi, jnp.float32(n_lvl))
            out[f"{prefix}_s"] = jnp.full(stack, s, jnp.float32)
            out[f"{prefix}_z"] = jnp.full(stack, z, jnp.float32)
    return out


def _act_leaves(stack, ab, trail, calib) -> dict:
    """Per-rung activation-quantizer leaves for one projection at b~x=ab:
    level counts always, frozen range + hoisted (s, z) when calibrated.
    Shared by the legacy quantizer and the view builder (identical op
    sequences keep hoisted and derived scalars bit-exact)."""
    out = {
        # match the weight's stack dims (e.g. the vmapped group axis) so
        # scanned decode bodies can slice per group
        "act_n": jnp.full(stack, float((1 << int(ab)) - 1), jnp.float32),
        # hoisted kernel-facing level count min(act_n, 127): the decode
        # step reads the leaf instead of re-deriving the half-range cap
        # per projection per token (dispatch._act_scalars)
        "act_nlvl": jnp.full(stack, float(quant_core.cap_levels(int(ab))),
                             jnp.float32),
    }
    if calib:
        rng = calib.get(pol.serving_path(trail))
        if rng is not None and float(rng[0]) <= float(rng[1]):
            out["act_lo"] = jnp.full(stack, float(rng[0]), jnp.float32)
            out["act_hi"] = jnp.full(stack, float(rng[1]), jnp.float32)
            # frozen ranges admit build-time (s, z): the SAME f32 op
            # sequence as the serve-time derivation (quant.act_range_bounds
            # with a seen range + affine_scale_zp), so hoisted and derived
            # artifacts stay bit-exact
            lo = jnp.minimum(jnp.float32(rng[0]), 0.0)
            hi = jnp.maximum(jnp.float32(rng[1]), 0.0)
            s, z = quant_core.affine_scale_zp(
                lo, hi, jnp.float32(quant_core.cap_levels(int(ab))))
            out["act_s"] = jnp.full(stack, s, jnp.float32)
            out["act_z"] = jnp.full(stack, z, jnp.float32)
    return out


def quantize_params_for_serving(params: Any, cfg: ModelConfig,
                                r: float | None = None,
                                act_bits: int | None = None,
                                policy: Optional[pol.PolicyTree] = None,
                                store_dtype=jnp.int8,
                                pack_planes: bool = False,
                                plane_count: Optional[int] = None,
                                calib: Optional[Mapping[str, Any]] = None,
                                cache_bits: Optional[int] = None,
                                spec: Optional[ServingQuantSpec] = None
                                ) -> Any:
    """Walk the param tree; replace {"w": W} under known projections with
    {"w_q": int codes, "w_scale": gamma}. MoE stacked experts and the
    embedding gather table stay in floating point (documented).

    ``act_bits`` (b~x) additionally stores ``act_n = 2^b~x - 1`` per
    projection so the forward quantizes activations at the operating point's
    bit width; it is a data leaf, not a shape/dtype change, so serve-engine
    rungs with different b~x still share one compiled decode step. Without
    ``act_bits`` the artifact is W-PANN-only (activations in compute dtype),
    the legacy single-point behavior.

    ``policy`` (a ``core.policy.PolicyTree``) quantizes each projection at
    ITS OWN (R, b~x): the key trail through the pytree is mapped to the
    canonical module path (``policy.serving_path``) and the looked-up
    ``ModuleQuant`` supplies that projection's point. Since only leaf
    VALUES change — never shapes, dtypes, or the tree structure — a
    layerwise variant shares the decode-step compilation with every uniform
    variant (the serve_engine invariant).

    ``pack_planes`` additionally materializes the bit-packed plane artifact
    (``w_planes_pos``/``w_planes_neg`` uint8 leaves) the 'packed' kernel
    backend reads — 2 * P / 8 bytes per weight for plane count P.
    ``plane_count`` pins P; None derives each module's value-exact b_R
    (minimal HBM, single-point artifacts), while ladder caches pass
    ``LADDER_PLANE_COUNT`` so every rung shares plane-leaf avals. Codes are
    clipped to the planes' +-(2^P - 1) envelope (a no-op at P = 7, the int8
    range) so ``w_q`` and the planes always describe the SAME weights —
    the backends' bit-exactness contract.

    ``calib`` (an EMA activation-range collection from power-aware QAT,
    ``core.calibrate`` / ``launch/export.py``) freezes each projection's
    activation range into ``act_lo``/``act_hi`` leaves: the forward then
    quantizes against the SAME static ranges training converged on instead
    of the per-batch dynamic range — the train→serve closing move. Roles
    the training run never observed (lo > hi) stay dynamic. Requires an
    activation bit width (``act_bits`` or a ``policy``) so ``act_n`` is
    materialized alongside.

    ``cache_bits`` — or a ``policy`` with EXPLICIT cache-role overrides
    (``policy.CACHE_PATHS``; prefix fallback from "attn" is deliberately
    NOT an opt-in) — attaches a ``kv_cache`` artifact dict under every
    self-attention parent: per-role ``k_nlvl``/``v_nlvl`` DATA leaves (the
    rung's cache level counts, stack-shaped like ``act_n`` so scan bodies
    slice them) plus, when ``calib`` saw the cache roles, frozen quantizer
    scalars ``k_s``/``k_z``/``v_s``/``v_z`` hoisted with the identical
    ``affine_scale_zp`` op sequence the decode step would run. ``xattn``
    parents are skipped: cross-attention K/V are precomputed fp encoder
    projections, not a decode-time cache.

    ``spec`` (a ``ServingQuantSpec``) names the same knobs as one object
    and supersedes the individual kwargs."""
    if spec is not None:
        policy, r, act_bits = spec.policy, spec.r, spec.act_bits
        store_dtype, pack_planes = spec.store_dtype, spec.pack_planes
        plane_count, calib = spec.plane_count, spec.calib
        cache_bits = spec.cache_bits
    if policy is None:
        r = r if r is not None else cfg.quant.r
    if calib:
        if act_bits is None and policy is None:
            raise ValueError(
                "freezing calibrated ranges needs an activation bit width: "
                "pass act_bits= or a policy= tree")
        calib = {k: np.asarray(v, np.float32) for k, v in calib.items()}

    cache_role_bits = None
    policy_cache = pol.tree_cache_bits(policy) if policy is not None else {}
    if policy_cache or cache_bits is not None:
        default_b = cache_bits if cache_bits is not None else max(
            policy_cache.values())
        cache_role_bits = {
            role: int(policy_cache.get(role, default_b))
            for role in pol.CACHE_PATHS}

    def cache_artifact(stack) -> dict:
        return _cache_artifact(stack, cache_role_bits, calib)

    def walk(node, trail=()):
        if isinstance(node, dict):
            name = trail[-1] if trail else ""
            if _is_quant_parent(node, trail):
                w = node["w"]
                if policy is not None:
                    mq = policy.lookup(pol.serving_path(trail))
                    r_mod, ab = mq.r, mq.b_x_tilde
                else:
                    r_mod, ab = r, act_bits
                w_q, gamma = pann_core.pann_quantize(
                    w.astype(jnp.float32), float(r_mod), axis=w.ndim - 2)
                codes = jnp.clip(w_q, -127, 127)
                if pack_planes:
                    p_cnt = plane_count if plane_count is not None else \
                        pann_core.weight_storage_bits(codes)
                    cap = (1 << min(int(p_cnt), 7)) - 1
                    codes = jnp.clip(codes, -cap, cap)
                out = {
                    "w_q": codes.astype(store_dtype),
                    "w_scale": gamma.astype(jnp.float32),
                    # per-output-channel code sum, precomputed so the kernel
                    # backends' zero-point row (dispatch: zcol = z * colsum)
                    # never re-reads the code tensor at decode time — for
                    # 'packed' that read would dwarf the plane bytes
                    "w_colsum": jnp.sum(codes.astype(jnp.int32), axis=-2),
                }
                if pack_planes:
                    out.update(_planes_artifact(codes, int(p_cnt)))
                if ab is not None:
                    out.update(_act_leaves(w.shape[:-2], ab, trail, calib))
                if "b" in node:
                    out["b"] = node["b"]
                return out
            out = {k: walk(v, trail + (k,)) for k, v in node.items()}
            if (cache_role_bits is not None
                    and name in ("attn", "shared_attn") and "wk" in node
                    and isinstance(node["wk"], dict) and "w" in node["wk"]):
                out["kv_cache"] = cache_artifact(node["wk"]["w"].shape[:-2])
            return out
        if isinstance(node, list):
            return [walk(v, trail) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v, trail) for v in node)
        return node

    return walk(params)


# ---------------------------------------------------------------------------
# Operating-point variant cache (serve_engine)
# ---------------------------------------------------------------------------

def variant_shardings(variant: Any, mesh, par: Optional[ParallelConfig] = None
                      ) -> Any:
    """NamedShardings for one quantized variant on ``mesh`` — the same
    Megatron column/row rules as training params (``w_q`` follows ``w``,
    ``w_scale`` is replicated; see repro.dist.sharding)."""
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), variant)
    specs = shardlib.param_specs(shapes, mesh, par or ParallelConfig())
    return shardlib.to_named(specs, mesh)


def build_variant_cache(params: Any, cfg: ModelConfig,
                        r_by_rung: Mapping[Any, Any],
                        mesh=None, par: Optional[ParallelConfig] = None,
                        store_dtype=jnp.int8,
                        pack_planes: bool = False,
                        plane_count: Optional[int] = None,
                        calib: Optional[Mapping[str, Any]] = None,
                        cache_bits: Any = None,
                        spec: Optional[ServingQuantSpec] = None) -> dict:
    """Materialize one int8 weight-code variant per operating point.

    ``r_by_rung`` maps a rung key (e.g. the unsigned-MAC bit budget) to the
    rung's PANN addition budget R, to ``(R, b~x)`` to also quantize
    activations at the rung's bit width, or to a ``core.policy.PolicyTree``
    for a layerwise rung (each projection at its own per-module (R, b~x)).
    All variants share one pytree structure and one set of avals (b~x is
    stored as data, not shape), so a single jitted decode step serves every
    rung — switching rungs is a pointer swap, never a retrace. With a
    ``mesh``, each variant is device_put with the training-param layout so
    the cache scales past one device instead of replicating N ladders.

    ``pack_planes`` adds the uint8 plane leaves for the 'packed' kernel
    backend; callers must pin ``plane_count`` (e.g. ``LADDER_PLANE_COUNT``)
    so every rung's plane leaves share avals — a value-exact per-rung count
    would retrace the decode step at every rung switch.

    ``calib`` freezes EMA-calibrated activation ranges into every rung (see
    ``quantize_params_for_serving``); since the range leaves are values,
    not avals, calibrated and uncalibrated rungs still share one compiled
    decode step — but every rung in ONE cache must agree on which roles are
    calibrated (same leaf set), which passing one collection guarantees.

    ``cache_bits`` quantizes the decode-time KV cache per rung: an int
    applies to every rung, a mapping (rung key -> bits) gives each rung its
    own cache width — still one compiled step, because the width rides in
    the ``k_nlvl``/``v_nlvl`` DATA leaves. All-or-none across rungs (a rung
    without cache leaves would change the pytree structure); PolicyTree
    rungs may instead carry explicit cache-role overrides.

    ``spec`` (a ``ServingQuantSpec``) supersedes the per-knob kwargs.
    """
    if spec is not None:
        store_dtype, pack_planes = spec.store_dtype, spec.pack_planes
        plane_count, calib = spec.plane_count, spec.calib
        cache_bits = spec.cache_bits
    if isinstance(cache_bits, Mapping):
        missing = set(r_by_rung) - set(cache_bits)
        if missing:
            raise ValueError(
                f"cache_bits mapping must cover every rung (missing "
                f"{sorted(missing)}): rungs with and without kv_cache "
                "leaves cannot share one pytree structure")
    if pack_planes and plane_count is None and len(r_by_rung) > 1:
        raise ValueError(
            "pack_planes over multiple rungs needs a pinned plane_count "
            "(e.g. serving.LADDER_PLANE_COUNT); per-rung value-exact plane "
            "counts give rungs different avals and break the one-compiled-"
            "decode-step invariant")
    base = ServingQuantSpec(store_dtype=store_dtype,
                            pack_planes=pack_planes,
                            plane_count=plane_count, calib=calib)
    cache = {}
    shardings = None
    for key, rung_spec in r_by_rung.items():
        cb = (cache_bits.get(key) if isinstance(cache_bits, Mapping)
              else cache_bits)
        rq = base.for_rung(None if cb is None else int(cb))
        if isinstance(rung_spec, pol.PolicyTree):
            rq = dataclasses.replace(rq, policy=rung_spec)
        else:
            r, act_bits = rung_spec if isinstance(rung_spec, tuple) \
                else (rung_spec, None)
            rq = dataclasses.replace(rq, r=float(r), act_bits=act_bits)
        v = quantize_params_for_serving(params, cfg, spec=rq)
        if mesh is not None:
            if shardings is None:     # variants share avals: compute once
                shardings = variant_shardings(v, mesh, par)
            v = jax.device_put(v, shardings)
        cache[key] = v
    return cache


# ---------------------------------------------------------------------------
# Max-R weight store + zero-copy rung views (DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeightStore:
    """One quantized artifact serving a whole ladder.

    ``store`` holds the big leaves quantized ONCE at each module's maximal
    budget (w_q codes, packed plane stacks, w_scale = gamma_R, biases,
    frozen calibration ranges, plus every fp passthrough leaf). ``views``
    maps each rung key to a decode-ready variant that REFERENCES the store's
    big leaves (same arrays, same device buffers) and adds only per-rung
    small leaves: ``plane_shift`` (the dropped-low-plane count the kernels
    predicate on), the view's ``w_colsum``, the rung's activation-quantizer
    scalars, and its ``kv_cache`` level counts. Weight HBM is therefore
    INDEPENDENT of ladder depth — a 5-rung ladder holds one code tensor per
    module, not five (benchmarks/table14_footprint.py gates this).

    Rung numerics under views are the truncation-consistent scheme: rung
    codes are the top planes of the max-R codes, so a rung realizes the
    SNAPPED budget r_max / 2^shift rather than its exactly-planned R
    (``core.pann.view_shift``; accuracy delta measured at equal power by
    benchmarks/artifact_parity.py)."""
    store: Any
    views: dict


def _resolve_point(spec, trail) -> tuple[float, Optional[int]]:
    """One rung spec -> (R, b~x) for the module at ``trail`` — the same
    three spellings ``build_variant_cache`` accepts (PolicyTree / (R, b~x) /
    bare R)."""
    if isinstance(spec, pol.PolicyTree):
        mq = spec.lookup(pol.serving_path(trail))
        return float(mq.r), int(mq.b_x_tilde)
    if isinstance(spec, tuple):
        r, ab = spec
        return float(r), (None if ab is None else int(ab))
    return float(spec), None


def _rung_cache_role_bits(spec, cb: Optional[int]) -> Optional[dict]:
    """Per-role cache bits of one rung: explicit PolicyTree overrides win,
    ``cb`` fills the rest; None when the rung keeps the fp cache."""
    policy_cache = pol.tree_cache_bits(spec) \
        if isinstance(spec, pol.PolicyTree) else {}
    if not policy_cache and cb is None:
        return None
    default_b = cb if cb is not None else max(policy_cache.values())
    return {role: int(policy_cache.get(role, default_b))
            for role in pol.CACHE_PATHS}


def build_weight_store(params: Any, cfg: ModelConfig,
                       r_by_rung: Mapping[Any, Any],
                       mesh=None, par: Optional[ParallelConfig] = None,
                       store_dtype=jnp.int8,
                       pack_planes: bool = False,
                       calib: Optional[Mapping[str, Any]] = None,
                       cache_bits: Any = None,
                       spec: Optional[ServingQuantSpec] = None
                       ) -> WeightStore:
    """Quantize once at the per-module max budget; realize every rung of
    ``r_by_rung`` as a view over that single store (see ``WeightStore``).

    Accepts the same rung-spec / ``calib`` / ``cache_bits`` spellings as
    ``build_variant_cache`` and produces views with the legacy variants'
    pytree structure plus one extra data leaf per projection
    (``plane_shift``) — all views share avals, so the one-compiled-decode-
    step invariant holds across mixed weight-rung x cache-rung ladders.
    Plane leaves (``pack_planes``) are always built at ``LADDER_PLANE_COUNT``
    so the full truncation envelope is stored.

    With a ``mesh`` the store is device_put ONCE under the training param
    rules; views then alias the store's device buffers and only their small
    per-rung leaves are placed separately — the flat-HBM property survives
    sharding.

    ``spec`` (a ``ServingQuantSpec``) supersedes the per-knob kwargs.
    """
    if spec is not None:
        store_dtype, pack_planes = spec.store_dtype, spec.pack_planes
        calib, cache_bits = spec.calib, spec.cache_bits
    if isinstance(cache_bits, Mapping):
        missing = set(r_by_rung) - set(cache_bits)
        if missing:
            raise ValueError(
                f"cache_bits mapping must cover every rung (missing "
                f"{sorted(missing)}): rungs with and without kv_cache "
                "leaves cannot share one pytree structure")
    if calib:
        calib = {k: np.asarray(v, np.float32) for k, v in calib.items()}
    keys = list(r_by_rung)
    if not keys:
        raise ValueError("r_by_rung must name at least one rung")
    rung_cache: dict = {}
    for key in keys:
        cb = (cache_bits.get(key) if isinstance(cache_bits, Mapping)
              else cache_bits)
        rung_cache[key] = _rung_cache_role_bits(
            r_by_rung[key], None if cb is None else int(cb))
    cached = [k for k in keys if rung_cache[k] is not None]
    if cached and len(cached) != len(keys):
        raise ValueError(
            "kv_cache leaves must be all-or-none across rungs: rungs "
            f"{sorted(set(keys) - set(cached))!r} have no cache bits while "
            f"{sorted(cached)!r} do")

    def walk(node, trail=()):
        """Returns (store_node, {rung key: view_node}); passthrough leaves
        are the SAME object in the store and every view."""
        if isinstance(node, dict):
            name = trail[-1] if trail else ""
            if _is_quant_parent(node, trail):
                w = node["w"]
                points = {k: _resolve_point(r_by_rung[k], trail)
                          for k in keys}
                r_max = max(r for r, _ in points.values())
                w_q, gamma = pann_core.pann_quantize(
                    w.astype(jnp.float32), r_max, axis=w.ndim - 2)
                codes = jnp.clip(w_q, -127, 127)
                shared = {
                    "w_q": codes.astype(store_dtype),
                    "w_scale": gamma.astype(jnp.float32),
                }
                if pack_planes:
                    shared.update(
                        _planes_artifact(codes, LADDER_PLANE_COUNT))
                if "b" in node:
                    shared["b"] = node["b"]
                stack = w.shape[:-2]
                views = {}
                for k in keys:
                    r_mod, ab = points[k]
                    sh = pann_core.view_shift(r_max, r_mod,
                                              LADDER_PLANE_COUNT - 1)
                    masked = pann_core.masked_codes(codes, sh)
                    v = dict(shared)
                    v["plane_shift"] = jnp.full(stack, float(sh),
                                                jnp.float32)
                    # the view's zero-point row: colsum of the codes the
                    # plane-skipping kernels REALIZE, not the stored ones
                    v["w_colsum"] = jnp.sum(masked, axis=-2)
                    if ab is not None:
                        v.update(_act_leaves(stack, ab, trail, calib))
                    views[k] = v
                return shared, views
            pairs = {k2: walk(v, trail + (k2,)) for k2, v in node.items()}
            store_n = {k2: p[0] for k2, p in pairs.items()}
            view_n = {k: {k2: p[1][k] for k2, p in pairs.items()}
                      for k in keys}
            if (cached and name in ("attn", "shared_attn") and "wk" in node
                    and isinstance(node["wk"], dict) and "w" in node["wk"]):
                stack = node["wk"]["w"].shape[:-2]
                for k in keys:
                    view_n[k]["kv_cache"] = _cache_artifact(
                        stack, rung_cache[k], calib)
            return store_n, view_n
        if isinstance(node, list):
            pairs = [walk(v, trail) for v in node]
            return ([p[0] for p in pairs],
                    {k: [p[1][k] for p in pairs] for k in keys})
        if isinstance(node, tuple):
            pairs = [walk(v, trail) for v in node]
            return (tuple(p[0] for p in pairs),
                    {k: tuple(p[1][k] for p in pairs) for k in keys})
        return node, {k: node for k in keys}

    store, view_trees = walk(params)
    if mesh is not None:
        store_dev = jax.device_put(store,
                                   variant_shardings(store, mesh, par))
        relink = {id(h): d for h, d in
                  zip(jax.tree_util.tree_leaves(store),
                      jax.tree_util.tree_leaves(store_dev))}

        def put(x, s):
            hit = relink.get(id(x))
            return hit if hit is not None else jax.device_put(x, s)

        shardings = None
        out_views = {}
        for k, vt in view_trees.items():
            if shardings is None:     # views share avals: compute once
                shardings = variant_shardings(vt, mesh, par)
            out_views[k] = jax.tree_util.tree_map(put, vt, shardings)
        return WeightStore(store=store_dev, views=out_views)
    return WeightStore(store=store, views=view_trees)


def device_put_weight_store(ws: WeightStore, mesh=None,
                            par: Optional[ParallelConfig] = None
                            ) -> WeightStore:
    """Place a host-memory weight store (e.g. ``serve_engine.artifact.
    load_artifact``'s mmap-backed numpy views) on device, PRESERVING the
    store/view aliasing: every store leaf is uploaded exactly once, view
    leaves that alias the store resolve to the SAME device buffer, and only
    the small per-rung leaves are placed separately — so serving straight
    from an artifact keeps weight HBM flat in ladder depth, exactly like a
    store built in-process (``build_weight_store``). With a ``mesh`` the
    training-param sharding rules apply, as there."""
    if mesh is not None:
        store_dev = jax.device_put(ws.store,
                                   variant_shardings(ws.store, mesh, par))
    else:
        store_dev = jax.device_put(ws.store)
    relink = {id(h): d for h, d in
              zip(jax.tree_util.tree_leaves(ws.store),
                  jax.tree_util.tree_leaves(store_dev))}

    shardings = None
    out_views = {}
    for k, vt in ws.views.items():
        if mesh is not None and shardings is None:  # views share avals
            shardings = variant_shardings(vt, mesh, par)

        def put(x, s=None):
            hit = relink.get(id(x))
            if hit is not None:
                return hit
            return jax.device_put(x) if s is None else jax.device_put(x, s)

        if mesh is not None:
            out_views[k] = jax.tree_util.tree_map(put, vt, shardings)
        else:
            out_views[k] = jax.tree_util.tree_map(put, vt)
    return WeightStore(store=store_dev, views=out_views)


def materialize_view(view: Any) -> Any:
    """Copy one rung view out into a standalone legacy-format variant:
    ``w_q`` becomes the masked codes the plane-skipping kernels realize
    (``core.pann.masked_codes``), plane leaves are re-packed from them, and
    the ``plane_shift`` leaf is dropped. Same gamma_R scale, same bias grid,
    same integer dataflow — the decode outputs are bit-identical to running
    the view itself, which tests/test_artifact.py asserts per module and
    per backend."""
    def walk(node):
        if isinstance(node, dict):
            if "w_q" in node and "plane_shift" in node:
                sh = jnp.asarray(node["plane_shift"],
                                 jnp.int32).reshape(-1)[0]
                masked = pann_core.masked_codes(node["w_q"], sh)
                out = {k: v for k, v in node.items() if k != "plane_shift"}
                out["w_q"] = masked.astype(node["w_q"].dtype)
                out["w_colsum"] = jnp.sum(masked, axis=-2)
                if "w_planes_pos" in node:
                    out.update(
                        _planes_artifact(masked, LADDER_PLANE_COUNT))
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(view)
