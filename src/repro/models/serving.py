"""Serving-time weight quantization: materialize PANN's deployment artifact.

Every projection weight is replaced by its PANN integer codes (Eq. 12,
per-output-channel gamma) stored in int8 — b_R <= 5 bits in practice
(Table 14), so int8 holds them losslessly — with dequant-on-load in the
forward. This is the §Perf iteration-5 change: decode is memory-bound and
weight-read bytes drop 2x vs bf16 (4x vs f32); the Pallas bit-plane kernel
(repro.kernels.pann_matmul) realizes the full b_R-bit layout on TPU.

By default activations stay in the compute dtype (W-PANN/A16); the PTQ
accuracy story at matched power is measured separately in
benchmarks/table2_ptq.py. Passing ``act_bits`` additionally quantizes
activations at b~x in the forward (stored as a data leaf so serve-engine
rungs share one compilation) — the full (b~x, R) operating point.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import pann as pann_core
from repro.core import policy as pol
from repro.core import quant as quant_core
from repro.core.unsigned import unsigned_split
from repro.dist import sharding as shardlib
from repro.kernels.pann_matmul_packed import pack_planes

# projection parents whose "w" is PANN-quantized for serving
_QUANT_PARENTS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj",
    "out_proj", "wr", "wg", "decay_a", "decay_b", "lm_head",
}

# Plane count used for ladder variant caches: int8 codes are clipped to
# +-127 = 2^7 - 1, so 7 planes reconstruct EVERY rung's codes exactly AND
# give every rung identical plane-leaf avals — the one-compiled-decode-step
# invariant extends to the packed backend for free (values-only variants).
LADDER_PLANE_COUNT = 7


def _planes_artifact(codes, plane_count: int) -> dict:
    """Bit-pack the unsigned split of int codes into the deployment layout
    consumed by the 'packed' kernel backend (kernels/pann_matmul_packed).

    codes: (..., K, N) ints. Returns uint8 leaves of shape
    (..., P, ceil(K/8), N): the plane axis sits BEHIND any scan-stacked
    layer/group dims so ``lax.scan`` still slices per-layer artifacts, and
    K is the packed axis (8 codes/byte — 2*P/8 bytes per weight for both
    signs).
    """
    pos, neg = unsigned_split(codes.astype(jnp.int32))
    out = {}
    for key, half in (("w_planes_pos", pos), ("w_planes_neg", neg)):
        planes = pann_core.bitplane_decompose(half, plane_count)
        out[key] = pack_planes(jnp.moveaxis(planes, 0, -3))
    return out


def quantize_params_for_serving(params: Any, cfg: ModelConfig,
                                r: float | None = None,
                                act_bits: int | None = None,
                                policy: Optional[pol.PolicyTree] = None,
                                store_dtype=jnp.int8,
                                pack_planes: bool = False,
                                plane_count: Optional[int] = None,
                                calib: Optional[Mapping[str, Any]] = None,
                                cache_bits: Optional[int] = None) -> Any:
    """Walk the param tree; replace {"w": W} under known projections with
    {"w_q": int codes, "w_scale": gamma}. MoE stacked experts and the
    embedding gather table stay in floating point (documented).

    ``act_bits`` (b~x) additionally stores ``act_n = 2^b~x - 1`` per
    projection so the forward quantizes activations at the operating point's
    bit width; it is a data leaf, not a shape/dtype change, so serve-engine
    rungs with different b~x still share one compiled decode step. Without
    ``act_bits`` the artifact is W-PANN-only (activations in compute dtype),
    the legacy single-point behavior.

    ``policy`` (a ``core.policy.PolicyTree``) quantizes each projection at
    ITS OWN (R, b~x): the key trail through the pytree is mapped to the
    canonical module path (``policy.serving_path``) and the looked-up
    ``ModuleQuant`` supplies that projection's point. Since only leaf
    VALUES change — never shapes, dtypes, or the tree structure — a
    layerwise variant shares the decode-step compilation with every uniform
    variant (the serve_engine invariant).

    ``pack_planes`` additionally materializes the bit-packed plane artifact
    (``w_planes_pos``/``w_planes_neg`` uint8 leaves) the 'packed' kernel
    backend reads — 2 * P / 8 bytes per weight for plane count P.
    ``plane_count`` pins P; None derives each module's value-exact b_R
    (minimal HBM, single-point artifacts), while ladder caches pass
    ``LADDER_PLANE_COUNT`` so every rung shares plane-leaf avals. Codes are
    clipped to the planes' +-(2^P - 1) envelope (a no-op at P = 7, the int8
    range) so ``w_q`` and the planes always describe the SAME weights —
    the backends' bit-exactness contract.

    ``calib`` (an EMA activation-range collection from power-aware QAT,
    ``core.calibrate`` / ``launch/export.py``) freezes each projection's
    activation range into ``act_lo``/``act_hi`` leaves: the forward then
    quantizes against the SAME static ranges training converged on instead
    of the per-batch dynamic range — the train→serve closing move. Roles
    the training run never observed (lo > hi) stay dynamic. Requires an
    activation bit width (``act_bits`` or a ``policy``) so ``act_n`` is
    materialized alongside.

    ``cache_bits`` — or a ``policy`` with EXPLICIT cache-role overrides
    (``policy.CACHE_PATHS``; prefix fallback from "attn" is deliberately
    NOT an opt-in) — attaches a ``kv_cache`` artifact dict under every
    self-attention parent: per-role ``k_nlvl``/``v_nlvl`` DATA leaves (the
    rung's cache level counts, stack-shaped like ``act_n`` so scan bodies
    slice them) plus, when ``calib`` saw the cache roles, frozen quantizer
    scalars ``k_s``/``k_z``/``v_s``/``v_z`` hoisted with the identical
    ``affine_scale_zp`` op sequence the decode step would run. ``xattn``
    parents are skipped: cross-attention K/V are precomputed fp encoder
    projections, not a decode-time cache."""
    if policy is None:
        r = r if r is not None else cfg.quant.r
    if calib:
        if act_bits is None and policy is None:
            raise ValueError(
                "freezing calibrated ranges needs an activation bit width: "
                "pass act_bits= or a policy= tree")
        calib = {k: np.asarray(v, np.float32) for k, v in calib.items()}

    cache_role_bits = None
    policy_cache = pol.tree_cache_bits(policy) if policy is not None else {}
    if policy_cache or cache_bits is not None:
        default_b = cache_bits if cache_bits is not None else max(
            policy_cache.values())
        cache_role_bits = {
            role: int(policy_cache.get(role, default_b))
            for role in pol.CACHE_PATHS}

    def cache_artifact(stack) -> dict:
        out = {}
        for role, prefix in zip(pol.CACHE_PATHS, ("k", "v")):
            n_lvl = float(min((1 << cache_role_bits[role]) - 1, 127))
            out[f"{prefix}_nlvl"] = jnp.full(stack, n_lvl, jnp.float32)
            rng = calib.get(role) if calib else None
            if rng is not None and float(rng[0]) <= float(rng[1]):
                lo = jnp.minimum(jnp.float32(rng[0]), 0.0)
                hi = jnp.maximum(jnp.float32(rng[1]), 0.0)
                s, z = quant_core.affine_scale_zp(lo, hi,
                                                  jnp.float32(n_lvl))
                out[f"{prefix}_s"] = jnp.full(stack, s, jnp.float32)
                out[f"{prefix}_z"] = jnp.full(stack, z, jnp.float32)
        return out

    def walk(node, trail=()):
        if isinstance(node, dict):
            name = trail[-1] if trail else ""
            if "w" in node and name in _QUANT_PARENTS \
                    and getattr(node["w"], "ndim", 0) >= 2:
                w = node["w"]
                if policy is not None:
                    mq = policy.lookup(pol.serving_path(trail))
                    r_mod, ab = mq.r, mq.b_x_tilde
                else:
                    r_mod, ab = r, act_bits
                w_q, gamma = pann_core.pann_quantize(
                    w.astype(jnp.float32), float(r_mod), axis=w.ndim - 2)
                codes = jnp.clip(w_q, -127, 127)
                if pack_planes:
                    p_cnt = plane_count if plane_count is not None else \
                        pann_core.weight_storage_bits(codes)
                    cap = (1 << min(int(p_cnt), 7)) - 1
                    codes = jnp.clip(codes, -cap, cap)
                out = {
                    "w_q": codes.astype(store_dtype),
                    "w_scale": gamma.astype(jnp.float32),
                    # per-output-channel code sum, precomputed so the kernel
                    # backends' zero-point row (dispatch: zcol = z * colsum)
                    # never re-reads the code tensor at decode time — for
                    # 'packed' that read would dwarf the plane bytes
                    "w_colsum": jnp.sum(codes.astype(jnp.int32), axis=-2),
                }
                if pack_planes:
                    out.update(_planes_artifact(codes, int(p_cnt)))
                if ab is not None:
                    # match the weight's stack dims (e.g. the vmapped group
                    # axis) so scanned decode bodies can slice it per group
                    stack = w.shape[:-2]
                    out["act_n"] = jnp.full(stack,
                                            float((1 << int(ab)) - 1),
                                            jnp.float32)
                    # hoisted kernel-facing level count min(act_n, 127):
                    # the decode step reads the leaf instead of re-deriving
                    # the half-range cap per projection per token
                    # (dispatch._act_scalars; 127 = 2^7 - 1 half-range)
                    n_lvl = float(min((1 << int(ab)) - 1, 127))
                    out["act_nlvl"] = jnp.full(stack, n_lvl, jnp.float32)
                    if calib:
                        rng = calib.get(pol.serving_path(trail))
                        if rng is not None and float(rng[0]) <= float(rng[1]):
                            out["act_lo"] = jnp.full(stack, float(rng[0]),
                                                     jnp.float32)
                            out["act_hi"] = jnp.full(stack, float(rng[1]),
                                                     jnp.float32)
                            # frozen ranges admit build-time (s, z): the
                            # SAME f32 op sequence as the serve-time
                            # derivation (quant.act_range_bounds with a
                            # seen range + affine_scale_zp), so hoisted
                            # and derived artifacts stay bit-exact
                            lo = jnp.minimum(jnp.float32(rng[0]), 0.0)
                            hi = jnp.maximum(jnp.float32(rng[1]), 0.0)
                            s, z = quant_core.affine_scale_zp(
                                lo, hi, jnp.float32(n_lvl))
                            out["act_s"] = jnp.full(stack, s, jnp.float32)
                            out["act_z"] = jnp.full(stack, z, jnp.float32)
                if "b" in node:
                    out["b"] = node["b"]
                return out
            out = {k: walk(v, trail + (k,)) for k, v in node.items()}
            if (cache_role_bits is not None
                    and name in ("attn", "shared_attn") and "wk" in node
                    and isinstance(node["wk"], dict) and "w" in node["wk"]):
                out["kv_cache"] = cache_artifact(node["wk"]["w"].shape[:-2])
            return out
        if isinstance(node, list):
            return [walk(v, trail) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v, trail) for v in node)
        return node

    return walk(params)


# ---------------------------------------------------------------------------
# Operating-point variant cache (serve_engine)
# ---------------------------------------------------------------------------

def variant_shardings(variant: Any, mesh, par: Optional[ParallelConfig] = None
                      ) -> Any:
    """NamedShardings for one quantized variant on ``mesh`` — the same
    Megatron column/row rules as training params (``w_q`` follows ``w``,
    ``w_scale`` is replicated; see repro.dist.sharding)."""
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), variant)
    specs = shardlib.param_specs(shapes, mesh, par or ParallelConfig())
    return shardlib.to_named(specs, mesh)


def build_variant_cache(params: Any, cfg: ModelConfig,
                        r_by_rung: Mapping[Any, Any],
                        mesh=None, par: Optional[ParallelConfig] = None,
                        store_dtype=jnp.int8,
                        pack_planes: bool = False,
                        plane_count: Optional[int] = None,
                        calib: Optional[Mapping[str, Any]] = None,
                        cache_bits: Any = None) -> dict:
    """Materialize one int8 weight-code variant per operating point.

    ``r_by_rung`` maps a rung key (e.g. the unsigned-MAC bit budget) to the
    rung's PANN addition budget R, to ``(R, b~x)`` to also quantize
    activations at the rung's bit width, or to a ``core.policy.PolicyTree``
    for a layerwise rung (each projection at its own per-module (R, b~x)).
    All variants share one pytree structure and one set of avals (b~x is
    stored as data, not shape), so a single jitted decode step serves every
    rung — switching rungs is a pointer swap, never a retrace. With a
    ``mesh``, each variant is device_put with the training-param layout so
    the cache scales past one device instead of replicating N ladders.

    ``pack_planes`` adds the uint8 plane leaves for the 'packed' kernel
    backend; callers must pin ``plane_count`` (e.g. ``LADDER_PLANE_COUNT``)
    so every rung's plane leaves share avals — a value-exact per-rung count
    would retrace the decode step at every rung switch.

    ``calib`` freezes EMA-calibrated activation ranges into every rung (see
    ``quantize_params_for_serving``); since the range leaves are values,
    not avals, calibrated and uncalibrated rungs still share one compiled
    decode step — but every rung in ONE cache must agree on which roles are
    calibrated (same leaf set), which passing one collection guarantees.

    ``cache_bits`` quantizes the decode-time KV cache per rung: an int
    applies to every rung, a mapping (rung key -> bits) gives each rung its
    own cache width — still one compiled step, because the width rides in
    the ``k_nlvl``/``v_nlvl`` DATA leaves. All-or-none across rungs (a rung
    without cache leaves would change the pytree structure); PolicyTree
    rungs may instead carry explicit cache-role overrides.
    """
    if isinstance(cache_bits, Mapping):
        missing = set(r_by_rung) - set(cache_bits)
        if missing:
            raise ValueError(
                f"cache_bits mapping must cover every rung (missing "
                f"{sorted(missing)}): rungs with and without kv_cache "
                "leaves cannot share one pytree structure")
    if pack_planes and plane_count is None and len(r_by_rung) > 1:
        raise ValueError(
            "pack_planes over multiple rungs needs a pinned plane_count "
            "(e.g. serving.LADDER_PLANE_COUNT); per-rung value-exact plane "
            "counts give rungs different avals and break the one-compiled-"
            "decode-step invariant")
    cache = {}
    shardings = None
    for key, spec in r_by_rung.items():
        cb = (cache_bits.get(key) if isinstance(cache_bits, Mapping)
              else cache_bits)
        kw = dict(store_dtype=store_dtype, pack_planes=pack_planes,
                  plane_count=plane_count, calib=calib,
                  cache_bits=None if cb is None else int(cb))
        if isinstance(spec, pol.PolicyTree):
            v = quantize_params_for_serving(params, cfg, policy=spec, **kw)
        else:
            r, act_bits = spec if isinstance(spec, tuple) else (spec, None)
            v = quantize_params_for_serving(params, cfg, r=float(r),
                                            act_bits=act_bits, **kw)
        if mesh is not None:
            if shardings is None:     # variants share avals: compute once
                shardings = variant_shardings(v, mesh, par)
            v = jax.device_put(v, shardings)
        cache[key] = v
    return cache
