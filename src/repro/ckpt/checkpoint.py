"""Sharded checkpointing with atomic commits, keep-k retention, resume, and
elastic remesh (checkpoints are mesh-agnostic: full arrays keyed by pytree
path, restored under ANY mesh/sharding — the restore path re-shards).

Layout:
    <dir>/step_000123/arrays.npz   — flattened {path: np.ndarray}
    <dir>/step_000123/meta.json    — step, config name, user metadata
    <dir>/step_000123/COMMITTED    — written last; partial dirs are ignored

On a real multi-host pod, process 0 writes after a device_get of the
(globally-addressable) arrays; per-shard OCDBT-style writes are a noted
extension point — the API (save/restore/latest_step) is unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _key_str(k) -> str:
    if hasattr(k, "key"):      # DictKey
        return str(k.key)
    if hasattr(k, "idx"):      # SequenceKey
        return f"#{k.idx}"
    if hasattr(k, "name"):     # GetAttrKey (NamedTuple / dataclass fields)
        return str(k.name)
    return str(k)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _may_fallback(key: str, strict) -> bool:
    """strict=True: no leaf may be missing; strict=False: any may; a tuple
    of key prefixes: only those subtrees may (everything else still errors,
    so a truncated checkpoint can never masquerade as a resumable one)."""
    if strict is True:
        return False
    if strict is False:
        return True
    return any(key.startswith(p) for p in strict)


def _unflatten(template: Any, flat: dict[str, np.ndarray],
               strict=True) -> Any:
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    fellback = []
    for path, leaf in paths_and_leaves:
        key = "/".join(_key_str(k) for k in path)
        if key not in flat:
            if _may_fallback(key, strict):
                # forward-compat resume: a state collection added after the
                # checkpoint was written (e.g. the QAT calibration ranges
                # on a pre-calibration checkpoint) keeps its template init
                fellback.append(key)
                leaves.append(np.asarray(leaf))
                continue
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        leaves.append(arr)
    if fellback:
        print(f"[ckpt] {len(fellback)} leaves absent from the checkpoint "
              f"kept their template init: {fellback[:8]}"
              + (" ..." if len(fellback) > 8 else ""))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, tree: Any, *,
         meta: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically write a checkpoint; prune to the newest ``keep``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMITTED")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template: Any,
            shardings: Optional[Any] = None, strict=True) -> Any:
    """Restore into ``template``'s structure; ``shardings`` (a matching
    pytree of NamedSharding) re-shards onto the *current* mesh — this is the
    elastic-scaling path: the saving and restoring meshes may differ.
    ``strict`` may be a tuple of key prefixes (e.g. ``("calib/",)``) naming
    the only subtrees allowed to keep their template init when absent from
    the checkpoint; False allows any (logged), True (default) allows none."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(template, flat, strict=strict)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return tree


def read_meta(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
