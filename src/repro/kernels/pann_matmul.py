"""Pallas TPU kernel: PANN bit-plane matmul (the paper's Eq. 10/11 adapted to
the MXU — see DESIGN.md §2).

Weights are stored as binary bit-planes of the unsigned-split PANN integer
codes: planes_pos/planes_neg of shape (P, K, N) with P = b_R (2..6 bits in
practice, Table 14). Activations are unsigned integer codes (half-range,
App. A.4) in int8.

Two compute modes, numerically identical:

  * ``mode='fused'``  — reconstruct w_q = sum_k 2^k (B+_k - B-_k) in VMEM
    (VPU shifts/adds) and issue a single int8 x int8 MXU pass per tile.
    This is the fast path: the MXU is TPU's cheapest compute primitive.
  * ``mode='planes'`` — one MXU pass per binary plane with separate pos/neg
    int32 accumulators, combined by shift-add and one final subtraction —
    the literal Eq. (10) + Fig. 12(b) dataflow.

Both paths accumulate in int32 and fuse the output dequantization
(y = y_int * s_x * gamma[n]), so the integer result is bit-exact w.r.t. the
reference oracle in ``repro.kernels.ref``.

``pann_matmul_act`` is the fused-prologue variant (ROADMAP item 3): it takes
fp32 activations straight from HBM and computes the affine codes
``clip(round(x/s) + z, 0, n)`` tile-locally in VMEM — the int8 code tensor
never exists in HBM, removing the fp32→int8 round-trip the standalone
``quantize_act`` path pays per projection. The (s, z, n) scalars are computed
ONCE outside the kernel (a cheap global reduction; see ``dispatch``) with the
one ``core.quant`` affine convention, so fused and unfused paths stay
bit-exact. Weight planes are streamed with MANUAL double-buffered DMAs:
plane i+1 is in flight while plane i is being shift-added/multiplied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _pann_matmul_kernel(x_ref, pos_ref, neg_ref, sx_ref, gamma_ref, zcol_ref,
                        o_ref, acc_ref, *, n_planes: int, k_steps: int,
                        mode: str):
    """Grid = (M/bm, N/bn, K/bk); accumulates over the k dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                      # (bm, bk) int8, non-negative codes

    if mode == "fused":
        w = jnp.zeros(pos_ref.shape[1:], jnp.int8)
        for p in range(n_planes):
            w = w + (jnp.int8(1 << p)) * (pos_ref[p] - neg_ref[p])
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:  # 'planes': per-plane addition-only passes, pos/neg separated
        acc_p = jnp.zeros(acc_ref.shape, jnp.int32)
        acc_n = jnp.zeros(acc_ref.shape, jnp.int32)
        for p in range(n_planes):
            shift = jnp.int32(1 << p)
            acc_p += shift * jax.lax.dot_general(
                x, pos_ref[p], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            acc_n += shift * jax.lax.dot_general(
                x, neg_ref[p], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
        acc_ref[...] += acc_p - acc_n   # the one Eq.-(6) subtraction

    @pl.when(k == k_steps - 1)
    def _finalize():
        # the zero-point correction lands in the EXACT int32 accumulator
        # domain (kernels/dispatch: zcol = z * colsum(w_q)); only the two
        # dequant multiplies round
        y = (acc_ref[...] - zcol_ref[...]).astype(jnp.float32)
        o_ref[...] = y * sx_ref[...] * gamma_ref[...]


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "interpret"))
def pann_matmul(x_q: Array, planes_pos: Array, planes_neg: Array,
                s_x: Array, gamma: Array, zcol: Array | None = None, *,
                mode: str = "fused", bm: int = 128, bn: int = 128,
                bk: int = 128, interpret: bool = True) -> Array:
    """y[m, n] = ((x_q @ (W+ - W-))[m, n] - zcol[n]) * s_x[m] * gamma[n].

    x_q:        (M, K) int8, unsigned activation codes
    planes_pos: (P, K, N) int8 in {0, 1}
    planes_neg: (P, K, N) int8 in {0, 1}
    s_x:        (M, 1) f32 per-row activation scales
    gamma:      (N,)  f32 per-channel PANN steps
    zcol:       (N,) int32 zero-point row (z * colsum(w_q); None = 0) —
                the asymmetric-activation correction fused into the
                accumulator before dequant (DESIGN.md §4)
    """
    m, k = x_q.shape
    p, k2, n = planes_pos.shape
    assert k == k2 and planes_neg.shape == planes_pos.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    if zcol is None:
        zcol = jnp.zeros((n,), jnp.int32)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)

    kernel = functools.partial(_pann_matmul_kernel, n_planes=p,
                               k_steps=k_steps, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((p, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((p, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, planes_pos, planes_neg, s_x, gamma.reshape(1, -1),
      zcol.reshape(1, -1))


# ---------------------------------------------------------------------------
# Fused act-quant prologue + double-buffered plane DMAs
# ---------------------------------------------------------------------------

def _pann_matmul_act_kernel(qp_ref, x_hbm, pos_hbm, neg_hbm, gamma_ref,
                            zcol_ref, o_ref, xbuf, codes, pos_buf, neg_buf,
                            w_ref, acc_ref, xsem, pos_sem, neg_sem, *,
                            n_planes: int, k_steps: int, bk: int, mode: str,
                            depth: int, i_axis: int, j_axis: int,
                            encode_every_step: bool):
    """Grid = (.., .., K/bk), kk innermost; (i, j) axis order is tunable.

    Dataflow per grid step:
      * first visit of a row panel: DMA the (bm, bk) fp32 x chunk from HBM
        and encode it into the persistent (bm, K) int8 ``codes`` scratch
        with the affine map ``clip(round(x/s) + z, 0, n)`` — op-for-op
        ``core.quant.affine_encode``. Later visits re-read ``codes`` from
        VMEM, so the fp32 activations cross HBM exactly once and the codes
        never do. (In 'nmk' grid order with more than one row panel the
        panel is re-encoded per tile — see ``pann_matmul_act``.)
      * every step: the live weight-plane tiles stream through ``depth``
        VMEM slots with manual DMAs — plane p+depth-1's copy is started
        BEFORE plane p's wait, so transfers overlap the current plane's
        VPU shift-add (and MXU pass in 'planes' mode).
      * planes below the runtime ``plane_shift`` scalar (qparams[0, 3]) are
        DEAD: their DMAs are never started and their shift-adds/MXU passes
        are predicated away with ``pl.when``. Plane weights stay the STATIC
        ``2^p``, so a rung view over a max-R store computes exactly
        ``q @ (c >> s << s)`` and dequantizes with the unchanged max-R
        gamma (truncation-consistent views, DESIGN.md §11).
    """
    i, j = pl.program_id(i_axis), pl.program_id(j_axis)
    kk = pl.program_id(2)
    s = qp_ref[0, 0]
    z = qp_ref[0, 1]
    n_clip = qp_ref[0, 2]
    # plane_shift rides as DATA (SMEM scalar) so every ladder rung shares
    # one compiled kernel; (1, 3) callers predate views and mean shift 0
    if qp_ref.shape == (1, 4):
        shift = jnp.round(qp_ref[0, 3]).astype(jnp.int32)
    else:
        shift = jnp.int32(0)
    bm = xbuf.shape[0]
    bn = o_ref.shape[1]

    def _encode_panel():
        cp = pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)], xbuf, xsem)
        cp.start()
        cp.wait()
        # VERBATIM core.quant.affine_encode — change both or neither
        codes[:, pl.ds(kk * bk, bk)] = jnp.clip(
            jnp.round(xbuf[...] / s) + z, 0.0, n_clip).astype(jnp.int8)

    if encode_every_step:
        _encode_panel()
    else:
        pl.when(j == 0)(_encode_panel)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = codes[:, pl.ds(kk * bk, bk)]            # (bm, bk) int8 codes

    def plane_dma(buf, hbm, sem, slot, p):
        return pltpu.make_async_copy(
            hbm.at[p, pl.ds(kk * bk, bk), pl.ds(j * bn, bn)],
            buf.at[slot], sem.at[slot])

    # Predicated pipeline fill: exactly one branch fires — the first LIVE
    # plane — and primes depth-1 slots from there. Dead planes (p < shift)
    # get no DMA at all: the skip is a real HBM-traffic win, not a masked
    # multiply.
    for p0 in range(n_planes):
        @pl.when(shift == p0)
        def _fill(p0=p0):
            for d in range(depth - 1):
                if p0 + d < n_planes:
                    plane_dma(pos_buf, pos_hbm, pos_sem,
                              (p0 + d) % depth, p0 + d).start()
                    plane_dma(neg_buf, neg_hbm, neg_sem,
                              (p0 + d) % depth, p0 + d).start()

    if mode == "fused":
        # w lives in a VMEM scratch (not a loop-carried register) because
        # the per-plane bodies must be pl.when-predicated — a wait on a
        # never-started copy would hang — and predicated bodies can only
        # mutate refs
        w_ref[...] = jnp.zeros_like(w_ref)
        for p in range(n_planes):
            @pl.when(p >= shift)
            def _accum_plane(p=p, slot=p % depth):
                nxt = p + depth - 1
                if nxt < n_planes:
                    plane_dma(pos_buf, pos_hbm, pos_sem,
                              nxt % depth, nxt).start()
                    plane_dma(neg_buf, neg_hbm, neg_sem,
                              nxt % depth, nxt).start()
                plane_dma(pos_buf, pos_hbm, pos_sem, slot, p).wait()
                plane_dma(neg_buf, neg_hbm, neg_sem, slot, p).wait()
                w_ref[...] += jnp.int8(1 << p) * (pos_buf[slot]
                                                  - neg_buf[slot])
        acc_ref[...] += jax.lax.dot_general(
            x, w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:  # 'planes': per-plane addition-only passes, pos/neg separated
        for p in range(n_planes):
            @pl.when(p >= shift)
            def _accum_plane(p=p, slot=p % depth):
                nxt = p + depth - 1
                if nxt < n_planes:
                    plane_dma(pos_buf, pos_hbm, pos_sem,
                              nxt % depth, nxt).start()
                    plane_dma(neg_buf, neg_hbm, neg_sem,
                              nxt % depth, nxt).start()
                plane_dma(pos_buf, pos_hbm, pos_sem, slot, p).wait()
                plane_dma(neg_buf, neg_hbm, neg_sem, slot, p).wait()
                pw = jnp.int32(1 << p)
                # per-plane pos-neg subtraction is exact in int32, so the
                # Eq.-(6) result is unchanged vs one deferred subtraction
                acc_ref[...] += pw * jax.lax.dot_general(
                    x, pos_buf[slot], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc_ref[...] -= pw * jax.lax.dot_general(
                    x, neg_buf[slot], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)

    @pl.when(kk == k_steps - 1)
    def _finalize():
        y = (acc_ref[...] - zcol_ref[...]).astype(jnp.float32)
        o_ref[...] = y * s * gamma_ref[...]


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "depth", "grid_order",
                                             "interpret"))
def pann_matmul_act(x: Array, planes_pos: Array, planes_neg: Array,
                    qparams: Array, gamma: Array, zcol: Array | None = None,
                    *, mode: str = "fused", bm: int = 128, bn: int = 128,
                    bk: int = 128, depth: int = 2, grid_order: str = "mnk",
                    interpret: bool = True) -> Array:
    """Fused-prologue bit-plane matmul: quantize-in-kernel, codes never in HBM.

    y[m, n] = ((q(x) @ (W+ - W-))[m, n] - zcol[n]) * s * gamma[n]
    with q(x) = clip(round(x/s) + z, 0, n_lvl) computed in VMEM.

    x:          (M, K) f32 activations (HBM-resident; read once per row panel)
    planes_pos: (P, K, N) int8 in {0, 1}   (HBM; manually multi-buffered)
    planes_neg: (P, K, N) int8 in {0, 1}
    qparams:    (1, 4) f32 SMEM scalars [s, z, n_lvl, plane_shift] —
                (s, z) computed outside with ``core.quant.affine_scale_zp``
                so every backend shares one derivation (the bit-exactness
                contract); ``plane_shift`` is the number of LOW bit-planes
                to skip at runtime (0 = all planes live; a rung view over a
                max-R plane store passes s > 0 and the kernel never DMAs
                the dead planes). (1, 3) is accepted for pre-view callers
                and means plane_shift = 0.
    gamma:      (N,)  f32 per-channel PANN steps
    zcol:       (N,) int32 zero-point/bias row (z * colsum(w_q) [- b_q];
                None = 0), subtracted in the exact int32 accumulator
    depth:      DMA pipeline slots per plane stream (>= 2; autotuned)
    grid_order: "mnk" (row panels outermost; the x prologue encodes each
                panel once) or "nmk" (N outermost; with more than one row
                panel the prologue re-encodes per tile — only ever a win
                when M is a single panel, where both orders are identical
                traffic and the autotuner just picks the faster schedule)
    """
    m, k = x.shape
    p, k2, n = planes_pos.shape
    assert k == k2 and planes_neg.shape == planes_pos.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert qparams.shape in ((1, 3), (1, 4)), qparams.shape
    assert depth >= 2, depth
    assert grid_order in ("mnk", "nmk"), grid_order
    if zcol is None:
        zcol = jnp.zeros((n,), jnp.int32)
    k_steps = k // bk
    m_steps, n_steps = m // bm, n // bn
    if grid_order == "mnk":
        grid = (m_steps, n_steps, k_steps)
        i_axis, j_axis = 0, 1
        nidx = lambda a, b, kk: (0, b)      # noqa: E731
        oidx = lambda a, b, kk: (a, b)      # noqa: E731
    else:
        grid = (n_steps, m_steps, k_steps)
        i_axis, j_axis = 1, 0
        nidx = lambda a, b, kk: (0, a)      # noqa: E731
        oidx = lambda a, b, kk: (b, a)      # noqa: E731
    # 'nmk' revisits row panels under a changing i, so the persistent codes
    # scratch is only reusable when there is a single row panel
    encode_every_step = (grid_order == "nmk" and m_steps > 1)

    kernel = functools.partial(_pann_matmul_act_kernel, n_planes=p,
                               k_steps=k_steps, bk=bk, mode=mode,
                               depth=depth, i_axis=i_axis, j_axis=j_axis,
                               encode_every_step=encode_every_step)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # qparams
            pl.BlockSpec(memory_space=pltpu.ANY),        # x (manual DMA)
            pl.BlockSpec(memory_space=pltpu.ANY),        # planes_pos
            pl.BlockSpec(memory_space=pltpu.ANY),        # planes_neg
            pl.BlockSpec((1, bn), nidx),
            pl.BlockSpec((1, bn), nidx),
        ],
        out_specs=pl.BlockSpec((bm, bn), oidx),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), jnp.float32),           # fp32 x landing pad
            pltpu.VMEM((bm, k), jnp.int8),               # persistent codes
            pltpu.VMEM((depth, bk, bn), jnp.int8),       # plane slots (pos)
            pltpu.VMEM((depth, bk, bn), jnp.int8),       # plane slots (neg)
            pltpu.VMEM((bk, bn), jnp.int8),              # reconstructed w
            pltpu.VMEM((bm, bn), jnp.int32),             # accumulator
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        interpret=interpret,
    )(qparams, x, planes_pos, planes_neg, gamma.reshape(1, -1),
      zcol.reshape(1, -1))
