"""Block-shape autotuner for the Pallas serving matmuls.

The fused-prologue kernels (``pann_matmul_act`` / ``pann_matmul_packed_act``)
are shape-sensitive in two ways the old one-size heuristic was not: the
persistent VMEM codes panel costs ``bm * K`` bytes (large-K projections want
a smaller bm), and the double-buffered plane slots cost ``4 * bk * bn``
(unpacked) or ``bk * bn / 2`` (packed). This module owns

  * the VMEM cost model + deterministic heuristic (``heuristic_blocks``),
  * a persistent on-disk cache of measured-best blocks keyed by
    ``device_kind | backend | MxKxN | planes`` (``blocks_for`` /
    ``record``), and
  * the offline measurement loop (``tune``) that fills it.

Determinism contract: ``blocks_for`` is called at TRACE time inside the
jitted decode step, so it must be a pure function of (shape, cache state) —
it never measures, never mutates the cache, and therefore cannot retrace a
warmed engine (``ServeEngine.assert_no_recompile`` holds with the autotuner
active). ``tune`` runs strictly OFFLINE (``ServeEngine(autotune=True)``
before ``warmup``); off-TPU it records the heuristic without timing —
interpret-mode timings are emulator noise, but recording keeps the cache
read/write path exercised by CPU CI.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro_pann/autotune.json``. The file is versioned and rewritten
atomically; a corrupt or foreign-version file is ignored, never crashed on.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Callable, Iterable, Optional

import jax

CACHE_VERSION = 1

_ENV_VAR = "REPRO_AUTOTUNE_CACHE"

# process-local snapshot of the on-disk cache; loaded lazily, kept in sync
# by record(). Maps key -> [bm, bn, bk].
_cache: Optional[dict] = None


def device_kind() -> str:
    """Autotune cache namespace: the accelerator model ('TPU v5e', ...),
    'cpu' for interpret-mode hosts."""
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "cpu"


def cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_pann",
                        "autotune.json")


def cache_key(m: int, k: int, n: int, planes: int, backend: str,
              kind: Optional[str] = None) -> str:
    return f"{kind or device_kind()}|{backend}|{m}x{k}x{n}|p{planes}"


def _load() -> dict:
    global _cache
    if _cache is None:
        _cache = {}
        try:
            with open(cache_path()) as f:
                data = json.load(f)
            if data.get("version") == CACHE_VERSION:
                _cache = dict(data.get("blocks", {}))
        except (OSError, ValueError):
            pass
    return _cache


def _save() -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"version": CACHE_VERSION, "blocks": _load()}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def clear_memory_cache() -> None:
    """Drop the process-local snapshot (tests; after external file edits)."""
    global _cache
    _cache = None


def vmem_bytes(bm: int, bn: int, bk: int, k: int, packed: bool) -> int:
    """VMEM working set of the fused-prologue kernels for one grid step."""
    plane_slots = (bk // 8) * bn * 4 if packed else bk * bn * 4
    return (4 * bm * bk        # fp32 x landing pad
            + bm * k           # persistent int8 codes panel
            + plane_slots      # 2 double-buffer slots x 2 signs
            + 4 * bm * bn      # int32 accumulator
            + 4 * bm * bn)     # f32 output block


def heuristic_blocks(m: int, n: int, k: int, planes: int = 7,
                     packed: bool = False,
                     vmem_budget: int = 8 * 2 ** 20) -> tuple[int, int, int]:
    """Deterministic default: MXU-aligned blocks shrunk until the act-kernel
    working set fits the VMEM budget (bk first — cheapest to shrink — then
    bm, whose cost is dominated by the bm*K codes panel)."""
    bm = min(m, 128)
    bn = min(n, 128)
    bk = min(k, 512)
    if packed:
        bk = max(8, bk - bk % 8)
    floor_k = 128 if k >= 128 else bk
    while bk > floor_k and vmem_bytes(bm, bn, bk, k, packed) > vmem_budget:
        bk = max(floor_k, bk // 2)
        if packed:
            bk = max(8, bk - bk % 8)
    while bm > 8 and vmem_bytes(bm, bn, bk, k, packed) > vmem_budget:
        bm //= 2
    return bm, bn, bk


def blocks_for(m: int, k: int, n: int, planes: int, backend: str
               ) -> tuple[int, int, int]:
    """Trace-time block lookup: measured-best from the cache when present,
    the VMEM heuristic otherwise. Pure in (args, cache state)."""
    hit = _load().get(cache_key(m, k, n, planes, backend))
    if hit:
        bm, bn, bk = (int(v) for v in hit)
        return bm, bn, bk
    return heuristic_blocks(m, n, k, planes, packed=(backend == "packed"))


def record(m: int, k: int, n: int, planes: int, backend: str,
           blocks: tuple[int, int, int]) -> None:
    """Persist a tuning decision for ``blocks_for`` to find."""
    _load()[cache_key(m, k, n, planes, backend)] = list(blocks)
    _save()


def candidate_blocks(m: int, n: int, k: int, planes: int,
                     packed: bool = False,
                     vmem_budget: int = 8 * 2 ** 20
                     ) -> list[tuple[int, int, int]]:
    """The measurement grid: every MXU-aligned (bm, bn, bk) combination
    that fits the VMEM model, heuristic included."""
    bms = sorted({min(m, b) for b in (32, 64, 128)})
    bns = sorted({min(n, b) for b in (128, 256)})
    bks = sorted({min(k, b) for b in (128, 256, 512)})
    if packed:
        bks = sorted({max(8, b - b % 8) for b in bks})
    out = {heuristic_blocks(m, n, k, planes, packed, vmem_budget)}
    for bm in bms:
        for bn in bns:
            for bk in bks:
                if vmem_bytes(bm, bn, bk, k, packed) <= vmem_budget:
                    out.add((bm, bn, bk))
    return sorted(out)


def tune(m: int, k: int, n: int, planes: int, backend: str,
         runner: Optional[Callable[[tuple[int, int, int]], float]] = None,
         candidates: Optional[Iterable[tuple[int, int, int]]] = None
         ) -> tuple[int, int, int]:
    """Offline: pick the best blocks for one projection shape and persist.

    ``runner(blocks) -> seconds`` measures one candidate (built by
    ``dispatch.tune_projection``). Off-TPU — or with no runner — the
    heuristic is recorded without timing: interpret-mode measurements are
    emulator noise, but the recorded entry still exercises the cache path
    end-to-end in CPU CI. A cached entry short-circuits (idempotent warmup).
    """
    key = cache_key(m, k, n, planes, backend)
    hit = _load().get(key)
    if hit:
        bm, bn, bk = (int(v) for v in hit)
        return bm, bn, bk
    packed = backend == "packed"
    if runner is None or device_kind() == "cpu" or \
            jax.default_backend() != "tpu":
        best = heuristic_blocks(m, n, k, planes, packed)
    else:
        cands = list(candidates if candidates is not None
                     else candidate_blocks(m, n, k, planes, packed))
        timed = []
        for c in cands:
            try:
                timed.append((runner(c), c))
            except Exception:
                continue        # a candidate the compiler rejects is skipped
        best = min(timed)[1] if timed else \
            heuristic_blocks(m, n, k, planes, packed)
    record(m, k, n, planes, backend, best)
    return best
