"""Kernel-parameter autotuner for the Pallas serving matmuls.

The fused-prologue kernels (``pann_matmul_act`` / ``pann_matmul_packed_act``)
are shape-sensitive in two ways the old one-size heuristic was not: the
persistent VMEM codes panel costs ``bm * K`` bytes (large-K projections want
a smaller bm), and the multi-buffered plane slots cost
``depth * 2 * bk * bn`` (unpacked) or ``depth * 2 * bk * bn / 8`` (packed).
Beyond block shapes, the kernels expose two schedule knobs the tuner
searches: the DMA pipeline depth (VMEM slots per plane stream) and the grid
iteration order ('mnk' row-panel-outer vs 'nmk' N-outer). This module owns

  * the VMEM cost model + deterministic heuristic (``heuristic_blocks`` /
    ``heuristic_params``),
  * a persistent on-disk cache of measured-best parameters keyed by
    ``device_kind | backend | MxKxN | planes | planes_active``
    (``params_for`` / ``record``), and
  * the offline measurement loop (``tune``) that fills it.

``planes_active`` keying: the serving ladder runs EVERY rung through one
compiled kernel (the plane shift is data), so its trace-time lookups key on
the full plane count (active = planes, the default). Offline tuning of a
single-point artifact — where the live plane count is static — may pass
``active`` to record per-count winners; the keys never collide with the
ladder's.

Determinism contract: ``params_for`` is called at TRACE time inside the
jitted decode step, so it must be a pure function of (shape, cache state) —
it never measures, never mutates the cache, and therefore cannot retrace a
warmed engine (``ServeEngine.assert_no_recompile`` holds with the autotuner
active). ``tune`` runs strictly OFFLINE (``ServeEngine(autotune=True)``
before ``warmup``); off-TPU it records the heuristic without timing —
interpret-mode timings are emulator noise, but recording keeps the cache
read/write path exercised by CPU CI.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro_pann/autotune.json``. The file is versioned and rewritten
atomically; a corrupt or foreign-version file is ignored, never crashed on.
Version history: v1 stored bare [bm, bn, bk] triples; v2 adds the schedule
knobs ({"blocks", "depth", "order"}) and the planes_active key segment.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Callable, Iterable, NamedTuple, Optional

import jax

CACHE_VERSION = 2

_ENV_VAR = "REPRO_AUTOTUNE_CACHE"

GRID_ORDERS = ("mnk", "nmk")
DMA_DEPTHS = (2, 3)

# process-local snapshot of the on-disk cache; loaded lazily, kept in sync
# by record(). Maps key -> {"blocks": [bm, bn, bk], "depth": d, "order": o}.
_cache: Optional[dict] = None


class KernelParams(NamedTuple):
    """One tuning decision: block shapes + schedule knobs."""
    bm: int
    bn: int
    bk: int
    depth: int = 2
    order: str = "mnk"

    @property
    def blocks(self) -> tuple[int, int, int]:
        return (self.bm, self.bn, self.bk)


def _as_params(value) -> KernelParams:
    """Normalize a (bm, bn, bk) triple, KernelParams, or cache dict."""
    if isinstance(value, KernelParams):
        return value
    if isinstance(value, dict):
        bm, bn, bk = (int(v) for v in value["blocks"])
        return KernelParams(bm, bn, bk, int(value.get("depth", 2)),
                            str(value.get("order", "mnk")))
    vals = list(value)
    if len(vals) == 3:
        return KernelParams(int(vals[0]), int(vals[1]), int(vals[2]))
    return KernelParams(int(vals[0]), int(vals[1]), int(vals[2]),
                        int(vals[3]), str(vals[4]))


def device_kind() -> str:
    """Autotune cache namespace: the accelerator model ('TPU v5e', ...),
    'cpu' for interpret-mode hosts."""
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "cpu"


def cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_pann",
                        "autotune.json")


def cache_key(m: int, k: int, n: int, planes: int, backend: str,
              kind: Optional[str] = None,
              active: Optional[int] = None) -> str:
    active = planes if active is None else active
    return (f"{kind or device_kind()}|{backend}|{m}x{k}x{n}"
            f"|p{planes}a{active}")


def _load() -> dict:
    global _cache
    if _cache is None:
        _cache = {}
        try:
            with open(cache_path()) as f:
                data = json.load(f)
            if data.get("version") == CACHE_VERSION:
                _cache = dict(data.get("blocks", {}))
        except (OSError, ValueError):
            pass
    return _cache


def _save() -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"version": CACHE_VERSION, "blocks": _load()}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def clear_memory_cache() -> None:
    """Drop the process-local snapshot (tests; after external file edits)."""
    global _cache
    _cache = None


def vmem_bytes(bm: int, bn: int, bk: int, k: int, packed: bool,
               depth: int = 2) -> int:
    """VMEM working set of the fused-prologue kernels for one grid step."""
    plane_tile = (bk // 8) * bn if packed else bk * bn
    return (4 * bm * bk            # fp32 x landing pad
            + bm * k               # persistent int8 codes panel
            + depth * 2 * plane_tile   # DMA slots x 2 signs
            + bk * bn              # reconstructed-w int8 scratch
            + 4 * bm * bn          # int32 accumulator
            + 4 * bm * bn)         # f32 output block


def heuristic_blocks(m: int, n: int, k: int, planes: int = 7,
                     packed: bool = False,
                     vmem_budget: int = 8 * 2 ** 20) -> tuple[int, int, int]:
    """Deterministic default: MXU-aligned blocks shrunk until the act-kernel
    working set fits the VMEM budget (bk first — cheapest to shrink — then
    bm, whose cost is dominated by the bm*K codes panel)."""
    bm = min(m, 128)
    bn = min(n, 128)
    bk = min(k, 512)
    if packed:
        bk = max(8, bk - bk % 8)
    floor_k = 128 if k >= 128 else bk
    while bk > floor_k and vmem_bytes(bm, bn, bk, k, packed) > vmem_budget:
        bk = max(floor_k, bk // 2)
        if packed:
            bk = max(8, bk - bk % 8)
    while bm > 8 and vmem_bytes(bm, bn, bk, k, packed) > vmem_budget:
        bm //= 2
    return bm, bn, bk


def heuristic_params(m: int, n: int, k: int, planes: int = 7,
                     packed: bool = False,
                     vmem_budget: int = 8 * 2 ** 20) -> KernelParams:
    """Heuristic blocks + the conservative schedule (double-buffer, 'mnk'
    row-panel-outer — the order whose prologue never re-encodes)."""
    return KernelParams(*heuristic_blocks(m, n, k, planes, packed,
                                          vmem_budget))


def params_for(m: int, k: int, n: int, planes: int, backend: str,
               active: Optional[int] = None) -> KernelParams:
    """Trace-time parameter lookup: measured-best from the cache when
    present, the VMEM heuristic otherwise. Pure in (args, cache state)."""
    hit = _load().get(cache_key(m, k, n, planes, backend, active=active))
    if hit:
        return _as_params(hit)
    return heuristic_params(m, n, k, planes, packed=(backend == "packed"))


def blocks_for(m: int, k: int, n: int, planes: int, backend: str,
               active: Optional[int] = None) -> tuple[int, int, int]:
    """Block-shape view of ``params_for`` (compat shim for callers that
    only consume (bm, bn, bk))."""
    return params_for(m, k, n, planes, backend, active).blocks


def record(m: int, k: int, n: int, planes: int, backend: str,
           params, active: Optional[int] = None) -> None:
    """Persist a tuning decision for ``params_for`` to find. Accepts a
    KernelParams or a bare (bm, bn, bk) triple (depth 2, order 'mnk')."""
    p = _as_params(params)
    _load()[cache_key(m, k, n, planes, backend, active=active)] = {
        "blocks": list(p.blocks), "depth": p.depth, "order": p.order}
    _save()


def candidate_blocks(m: int, n: int, k: int, planes: int,
                     packed: bool = False,
                     vmem_budget: int = 8 * 2 ** 20
                     ) -> list[tuple[int, int, int]]:
    """The block-shape grid: every MXU-aligned (bm, bn, bk) combination
    that fits the VMEM model, heuristic included."""
    bms = sorted({min(m, b) for b in (32, 64, 128)})
    bns = sorted({min(n, b) for b in (128, 256)})
    bks = sorted({min(k, b) for b in (128, 256, 512)})
    if packed:
        bks = sorted({max(8, b - b % 8) for b in bks})
    out = {heuristic_blocks(m, n, k, planes, packed, vmem_budget)}
    for bm in bms:
        for bn in bns:
            for bk in bks:
                if vmem_bytes(bm, bn, bk, k, packed) <= vmem_budget:
                    out.add((bm, bn, bk))
    return sorted(out)


def candidate_params(m: int, n: int, k: int, planes: int,
                     packed: bool = False,
                     vmem_budget: int = 8 * 2 ** 20) -> list[KernelParams]:
    """The full measurement grid: block shapes x DMA depths x grid orders,
    filtered by the depth-aware VMEM model."""
    out = {heuristic_params(m, n, k, planes, packed, vmem_budget)}
    for bm, bn, bk in candidate_blocks(m, n, k, planes, packed, vmem_budget):
        for depth in DMA_DEPTHS:
            if vmem_bytes(bm, bn, bk, k, packed, depth) > vmem_budget:
                continue
            for order in GRID_ORDERS:
                out.add(KernelParams(bm, bn, bk, depth, order))
    return sorted(out)


def tune(m: int, k: int, n: int, planes: int, backend: str,
         runner: Optional[Callable[[KernelParams], float]] = None,
         candidates: Optional[Iterable] = None,
         active: Optional[int] = None) -> KernelParams:
    """Offline: pick the best kernel parameters for one projection shape
    and persist.

    ``runner(params) -> seconds`` measures one candidate (built by
    ``dispatch.tune_projection``). Off-TPU — or with no runner — the
    heuristic is recorded without timing: interpret-mode measurements are
    emulator noise, but the recorded entry still exercises the cache path
    end-to-end in CPU CI. A cached entry short-circuits (idempotent warmup).
    """
    key = cache_key(m, k, n, planes, backend, active=active)
    hit = _load().get(key)
    if hit:
        return _as_params(hit)
    packed = backend == "packed"
    if runner is None or device_kind() == "cpu" or \
            jax.default_backend() != "tpu":
        best = heuristic_params(m, n, k, planes, packed)
    else:
        cands = [_as_params(c) for c in
                 (candidates if candidates is not None
                  else candidate_params(m, n, k, planes, packed))]
        timed = []
        for c in cands:
            try:
                timed.append((runner(c), c))
            except Exception:
                continue        # a candidate the compiler rejects is skipped
        best = min(timed)[1] if timed else \
            heuristic_params(m, n, k, planes, packed)
    record(m, k, n, planes, backend, best, active=active)
    return best
