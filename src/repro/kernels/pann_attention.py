"""Pallas TPU kernel: one-token GQA decode attention read DIRECTLY off the
packed bit-plane KV cache (docs/kv_cache.md; DESIGN.md §10).

The cache stores K/V as unsigned affine codes, bit-plane-decomposed and
packed 8 bits/byte along head_dim (``kernels.ref.pack_cache_codes`` — NOT
the weight-plane ``pack_planes``, which packs along K). One grid cell per
(batch, kv_head); each cell unpacks its (P, S, hd/8) plane panel in VMEM,
runs the exact int32 QK^T with BOTH zero points corrected inside the
accumulator (the serving_linear ``zcol`` convention, applied twice), the
fp32 softmax epilogue in the oracle's exact op sequence, then re-quantizes
the probabilities to a fixed 2^14 grid for an exact int32 PV pass —
``sum_s p = 1`` bounds ``pq @ vq`` by ``127 * 2^14``, int32-safe for ANY
sequence length. Bit-identical (fp32) to ``kernels.ref.decode_attention_ref``
(tests/test_kv_cache_quant.py).

Whole-S blocks: decode reads every cached position once per token, so the
panel (7 planes x S x hd/8 bytes) must fit VMEM — ~57 KB at S=4096,
hd=128. No K-grid accumulation loop is needed at these sizes; a
sequence-blocked online-softmax variant is the follow-up if contexts
outgrow VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import CACHE_PLANES, PROB_SCALE

Array = jax.Array

NEG_INF = -1e30     # matches models.attention.NEG_INF / ref._CACHE_NEG_INF


def _unpack_panel(pk: Array) -> Array:
    """(P, S, d8) uint8 packed planes -> (S, hd) int32 codes, in-VMEM.
    Byte j, bit i -> element 8j+i; plane p -> bit p of the code — the exact
    inverse of ``ref.pack_cache_codes``."""
    p, s, d8 = pk.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 8), 3)
    bits = (pk[..., None].astype(jnp.int32) >> shifts) & 1   # (P, S, d8, 8)
    bits = bits.reshape(p, s, d8 * 8)
    plane_w = jnp.left_shift(
        jnp.int32(1), jax.lax.broadcasted_iota(jnp.int32, (p, 1, 1), 0))
    return jnp.sum(bits * plane_w, axis=0)                   # (S, hd)


def _decode_attention_kernel(qp_ref, pos_ref, q_ref, kp_ref, ks_ref, kz_ref,
                             vp_ref, vs_ref, vz_ref, o_ref, *, hd: int,
                             window, softcap: float, prob_scale: float):
    """Grid = (B, K): one cell per (batch, kv_head)."""
    qz = qp_ref[0, 0].astype(jnp.int32)
    q_scale = qp_ref[0, 1]                      # s_q * hd**-0.5, sealed
    pos = pos_ref[0, 0]

    qq = q_ref[...][0, 0]                       # (G, hd) int32 affine codes
    kq = _unpack_panel(kp_ref[...][0, :, :, 0, :])           # (S, hd) int32
    s = kq.shape[0]

    # exact int32 QK^T: (qq - z_q) . (kq - z_k) expanded inside the
    # accumulator — codes <= 127 and hd <= 256 keep every term int32-safe
    dots = jax.lax.dot_general(
        qq.astype(jnp.int8), kq.astype(jnp.int8), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)       # (G, S)
    colsum_k = jnp.sum(kq, axis=-1)             # (S,)
    rowsum_q = jnp.sum(qq, axis=-1)             # (G,)
    kz = jnp.round(kz_ref[...][0]).astype(jnp.int32)         # (S,)
    i32 = (dots - qz * colsum_k[None, :] - kz[None, :] * rowsum_q[:, None]
           + qz * kz[None, :] * hd)

    # fp32 epilogue — the oracle's exact op sequence (ref.py): change both
    # or neither, the parity suite holds them bit-identical
    sc = (i32.astype(jnp.float32) * q_scale) * ks_ref[...][0][None, :]
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)
    valid = k_pos <= pos
    if window is not None:
        valid &= (pos - k_pos) < window
    sc = jnp.where(valid, sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    # exact int32 PV: rescale every position into the largest valid V scale,
    # re-quantize the probabilities, subtract the V zero point in-accumulator
    vq = _unpack_panel(vp_ref[...][0, :, :, 0, :])           # (S, hd) int32
    vs = vs_ref[...][0]                                      # (S,)
    sv_ref = jnp.maximum(jnp.max(jnp.where(valid[0], vs, 0.0)), 1e-12)
    ratio = vs / sv_ref
    pq = jnp.round(p * ratio[None, :] * prob_scale).astype(jnp.int32)
    pv = jax.lax.dot_general(pq, vq, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)  # (G, hd)
    vz = jnp.round(vz_ref[...][0]).astype(jnp.int32)
    corr = jnp.sum(pq * vz[None, :], axis=-1)                # (G,)
    scale = sv_ref / prob_scale
    out = (pv - corr[:, None]).astype(jnp.float32) * scale
    o_ref[...] = out.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def decode_attention(qq: Array, q_z: Array, q_scale: Array,
                     k_planes: Array, k_s: Array, k_z: Array,
                     v_planes: Array, v_s: Array, v_z: Array,
                     pos: Array, *, window=None, softcap: float = 0.0,
                     interpret: bool = True) -> Array:
    """out[b, k, g, :] = softmax-attention of query group (b, k, g) over the
    packed bit-plane KV cache. Argument shapes match
    ``kernels.ref.decode_attention_ref`` exactly (its docstring is the
    spec), except ``pos`` must be a scalar — the engine's caches share one
    ``length`` across the batch.
    """
    b, kh, g, hd = qq.shape
    _, n_planes, s, kh2, d8 = k_planes.shape
    assert kh == kh2 and d8 * 8 == hd, (qq.shape, k_planes.shape)
    assert v_planes.shape == k_planes.shape
    assert n_planes <= CACHE_PLANES, n_planes
    qp = jnp.stack([jnp.asarray(q_z, jnp.float32).reshape(()),
                    jnp.asarray(q_scale, jnp.float32).reshape(())]
                   ).reshape(1, 2)
    pos2 = jnp.asarray(pos, jnp.int32).reshape(1, 1)

    kernel = functools.partial(_decode_attention_kernel, hd=hd,
                               window=window, softcap=softcap,
                               prob_scale=PROB_SCALE)
    plane_spec = pl.BlockSpec((1, n_planes, s, 1, d8),
                              lambda bi, ki: (bi, 0, 0, ki, 0))
    row_spec = pl.BlockSpec((1, s), lambda bi, ki: (bi, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # [q_z, q_scale]
            pl.BlockSpec(memory_space=pltpu.SMEM),           # pos
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
            plane_spec, row_spec, row_spec,                  # K planes/s/z
            plane_spec, row_spec, row_spec,                  # V planes/s/z
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), jnp.float32),
        interpret=interpret,
    )(qp, pos2, qq.astype(jnp.int32), k_planes, k_s, k_z,
      v_planes, v_s, v_z)
