"""Pallas TPU kernel: one-token GQA decode attention read DIRECTLY off the
packed bit-plane KV cache (docs/kv_cache.md; DESIGN.md §10).

The cache stores K/V as unsigned affine codes, bit-plane-decomposed and
packed 8 bits/byte along head_dim (``kernels.ref.pack_cache_codes`` — NOT
the weight-plane ``pack_planes``, which packs along K). One grid cell per
(batch, kv_head); each cell streams its (S, hd/8) plane panels through
double-buffered manual DMAs, accumulates the unpacked codes into an int32
(S, hd) panel, runs the exact int32 QK^T with BOTH zero points corrected
inside the accumulator (the serving_linear ``zcol`` convention, applied
twice), the fp32 softmax epilogue in the oracle's exact op sequence, then
re-quantizes the probabilities to a fixed 2^14 grid for an exact int32 PV
pass — ``sum_s p = 1`` bounds ``pq @ vq`` by ``127 * 2^14``, int32-safe for
ANY sequence length. Bit-identical (fp32) to
``kernels.ref.decode_attention_ref`` (tests/test_kv_cache_quant.py).

Plane skipping: cache codes are <= n_lvl < 2^b, so only the LOW
``planes_active`` planes can be nonzero (the opposite prefix from the
weight kernels, which skip low planes under a view shift). The per-role
active counts ride in as SMEM DATA scalars — derived from the cache's
``k_nlvl``/``v_nlvl`` leaves — so a 2-bit cache rung DMAs and shift-adds 2
planes, not 7, while every rung shares one compiled kernel. Skipped planes
are all-zero in the cache by construction, so the jnp oracle needs no
planes_active argument and the parity suite is unchanged.

Whole-S blocks: decode reads every cached position once per token, so the
panel (7 planes x S x hd/8 bytes) must fit VMEM — ~57 KB at S=4096,
hd=128. No K-grid accumulation loop is needed at these sizes; a
sequence-blocked online-softmax variant is the follow-up if contexts
outgrow VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import CACHE_PLANES, PROB_SCALE

Array = jax.Array

NEG_INF = -1e30     # matches models.attention.NEG_INF / ref._CACHE_NEG_INF


def _unpack_plane(pk: Array) -> Array:
    """(S, d8) uint8 — ONE packed plane — -> (S, hd) int32 {0,1} bits.
    Byte j, bit i -> element 8j+i: the per-plane slice of the exact inverse
    of ``ref.pack_cache_codes``."""
    s, d8 = pk.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8), 2)
    bits = (pk[..., None].astype(jnp.int32) >> shifts) & 1   # (S, d8, 8)
    return bits.reshape(s, d8 * 8)


def _decode_attention_kernel(qp_ref, pos_ref, q_ref, kp_hbm, ks_ref, kz_ref,
                             vp_hbm, vs_ref, vz_ref, o_ref, kcode, vcode,
                             kbuf, vbuf, ksem, vsem, *, n_planes: int,
                             hd: int, window, softcap: float,
                             prob_scale: float):
    """Grid = (B, K): one cell per (batch, kv_head)."""
    bi, ki = pl.program_id(0), pl.program_id(1)
    qz = qp_ref[0, 0].astype(jnp.int32)
    q_scale = qp_ref[0, 1]                      # s_q * hd**-0.5, sealed
    k_pact = jnp.round(qp_ref[0, 2]).astype(jnp.int32)
    v_pact = jnp.round(qp_ref[0, 3]).astype(jnp.int32)
    pos = pos_ref[0, 0]
    s = kcode.shape[0]

    def plane_dma(buf, hbm, sem, slot, p):
        return pltpu.make_async_copy(hbm.at[bi, p, :, ki, :],
                                     buf.at[slot], sem.at[slot])

    # plane 0 is live for ANY level count >= 1; higher planes are started
    # and waited under matching predicates so the semaphores stay balanced
    plane_dma(kbuf, kp_hbm, ksem, 0, 0).start()
    plane_dma(vbuf, vp_hbm, vsem, 0, 0).start()

    # accumulate codes = sum_p 2^p * plane_p over the LIVE prefix only;
    # the dead high planes are all-zero in the cache, so the sum equals the
    # full 7-plane unpack bit-for-bit
    kcode[...] = jnp.zeros_like(kcode)
    for p in range(n_planes):
        @pl.when(p < k_pact)
        def _accum_k(p=p, slot=p % 2):
            if p + 1 < n_planes:
                @pl.when(p + 1 < k_pact)
                def _prefetch():
                    plane_dma(kbuf, kp_hbm, ksem, 1 - (p % 2), p + 1).start()
            plane_dma(kbuf, kp_hbm, ksem, slot, p).wait()
            kcode[...] += jnp.int32(1 << p) * _unpack_plane(kbuf[slot])

    qq = q_ref[...][0, 0]                       # (G, hd) int32 affine codes
    kq = kcode[...]                             # (S, hd) int32

    # exact int32 QK^T: (qq - z_q) . (kq - z_k) expanded inside the
    # accumulator — codes <= 127 and hd <= 256 keep every term int32-safe
    dots = jax.lax.dot_general(
        qq.astype(jnp.int8), kq.astype(jnp.int8), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)       # (G, S)
    colsum_k = jnp.sum(kq, axis=-1)             # (S,)
    rowsum_q = jnp.sum(qq, axis=-1)             # (G,)
    kz = jnp.round(kz_ref[...][0]).astype(jnp.int32)         # (S,)
    i32 = (dots - qz * colsum_k[None, :] - kz[None, :] * rowsum_q[:, None]
           + qz * kz[None, :] * hd)

    # fp32 epilogue — the oracle's exact op sequence (ref.py): change both
    # or neither, the parity suite holds them bit-identical
    sc = (i32.astype(jnp.float32) * q_scale) * ks_ref[...][0][None, :]
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)
    valid = k_pos <= pos
    if window is not None:
        valid &= (pos - k_pos) < window
    sc = jnp.where(valid, sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p_ = jnp.exp(sc - m)
    p_ = p_ / jnp.sum(p_, axis=-1, keepdims=True)

    # stream + accumulate the V planes (their DMAs overlapped the QK^T work)
    vcode[...] = jnp.zeros_like(vcode)
    for p in range(n_planes):
        @pl.when(p < v_pact)
        def _accum_v(p=p, slot=p % 2):
            if p + 1 < n_planes:
                @pl.when(p + 1 < v_pact)
                def _prefetch():
                    plane_dma(vbuf, vp_hbm, vsem, 1 - (p % 2), p + 1).start()
            plane_dma(vbuf, vp_hbm, vsem, slot, p).wait()
            vcode[...] += jnp.int32(1 << p) * _unpack_plane(vbuf[slot])

    # exact int32 PV: rescale every position into the largest valid V scale,
    # re-quantize the probabilities, subtract the V zero point in-accumulator
    vq = vcode[...]                                          # (S, hd) int32
    vs = vs_ref[...][0]                                      # (S,)
    sv_ref = jnp.maximum(jnp.max(jnp.where(valid[0], vs, 0.0)), 1e-12)
    ratio = vs / sv_ref
    pq = jnp.round(p_ * ratio[None, :] * prob_scale).astype(jnp.int32)
    pv = jax.lax.dot_general(pq, vq, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)  # (G, hd)
    vz = jnp.round(vz_ref[...][0]).astype(jnp.int32)
    corr = jnp.sum(pq * vz[None, :], axis=-1)                # (G,)
    scale = sv_ref / prob_scale
    out = (pv - corr[:, None]).astype(jnp.float32) * scale
    o_ref[...] = out.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def decode_attention(qq: Array, q_z: Array, q_scale: Array,
                     k_planes: Array, k_s: Array, k_z: Array,
                     v_planes: Array, v_s: Array, v_z: Array,
                     pos: Array, k_pact: Array | None = None,
                     v_pact: Array | None = None, *, window=None,
                     softcap: float = 0.0, interpret: bool = True) -> Array:
    """out[b, k, g, :] = softmax-attention of query group (b, k, g) over the
    packed bit-plane KV cache. Argument shapes match
    ``kernels.ref.decode_attention_ref`` exactly (its docstring is the
    spec), except ``pos`` must be a scalar — the engine's caches share one
    ``length`` across the batch — and ``k_pact``/``v_pact`` (traced scalar
    counts of LIVE low planes, from the cache level counts; None = all)
    have no oracle counterpart because the skipped planes are all-zero.
    """
    b, kh, g, hd = qq.shape
    _, n_planes, s, kh2, d8 = k_planes.shape
    assert kh == kh2 and d8 * 8 == hd, (qq.shape, k_planes.shape)
    assert v_planes.shape == k_planes.shape
    assert n_planes <= CACHE_PLANES, n_planes
    if k_pact is None:
        k_pact = jnp.float32(n_planes)
    if v_pact is None:
        v_pact = jnp.float32(n_planes)
    qp = jnp.stack([jnp.asarray(q_z, jnp.float32).reshape(()),
                    jnp.asarray(q_scale, jnp.float32).reshape(()),
                    jnp.clip(jnp.asarray(k_pact, jnp.float32).reshape(()),
                             1.0, float(n_planes)),
                    jnp.clip(jnp.asarray(v_pact, jnp.float32).reshape(()),
                             1.0, float(n_planes))]).reshape(1, 4)
    pos2 = jnp.asarray(pos, jnp.int32).reshape(1, 1)

    kernel = functools.partial(_decode_attention_kernel, n_planes=n_planes,
                               hd=hd, window=window, softcap=softcap,
                               prob_scale=PROB_SCALE)
    row_spec = pl.BlockSpec((1, s), lambda bi, ki: (bi, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # [q_z, q_scale, pacts]
            pl.BlockSpec(memory_space=pltpu.SMEM),   # pos
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),    # K planes (manual DMA)
            row_spec, row_spec,                      # K s/z
            pl.BlockSpec(memory_space=pltpu.ANY),    # V planes (manual DMA)
            row_spec, row_spec,                      # V s/z
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((s, hd), jnp.int32),          # accumulated K codes
            pltpu.VMEM((s, hd), jnp.int32),          # accumulated V codes
            pltpu.VMEM((2, s, d8), jnp.uint8),       # K plane slots
            pltpu.VMEM((2, s, d8), jnp.uint8),       # V plane slots
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(qp, pos2, qq.astype(jnp.int32), k_planes, k_s, k_z,
      v_planes, v_s, v_z)
