"""Pallas TPU kernel: PANN bit-plane matmul with PACKED plane storage.

The deployment-optimal layout: the binary planes of the unsigned-split PANN
codes are packed 8 bits per byte along K, so weight HBM bytes are
2 * P * K * N / 8 (P = b_R plane count) — e.g. b_R=3 costs 0.75 byte/weight
for BOTH signs vs 2 bytes for bf16 (2.7x) and 1 byte for int8 codes.
Planes are unpacked in VMEM with shifts (VPU) and fed to the same int8 MXU
pass as kernels/pann_matmul.

Layout: packed[p, k8, n] holds bit (k8*8 + j) of plane p in bit j.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def pack_planes(planes: Array) -> Array:
    """(..., K, N) {0,1} int8 -> (..., K/8, N) uint8 (K padded to 8).

    Packing runs along axis -2 (the reduction dim); any leading dims —
    the plane axis, and for serving artifacts the scan-stacked layer/group
    dims, which must stay leading so ``lax.scan`` slices them — pass
    through untouched. packed[..., k8, n] holds bit (k8*8 + j) in bit j.
    """
    *lead, k, n = planes.shape
    pad = (-k) % 8
    if pad:
        planes = jnp.pad(planes,
                         [(0, 0)] * len(lead) + [(0, pad), (0, 0)])
        k += pad
    bits = planes.reshape(*lead, k // 8, 8, n).astype(jnp.uint8)
    weights = (1 << jnp.arange(8, dtype=jnp.uint8)).reshape(8, 1)
    return jnp.sum(bits * weights, axis=-2).astype(jnp.uint8)


def unpack_planes(packed: Array, k: int) -> Array:
    """Inverse of pack_planes (reference / in-kernel helper)."""
    *lead, k8, n = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(8, 1)
    bits = (packed[..., :, None, :] >> shifts) & jnp.uint8(1)
    return bits.reshape(*lead, k8 * 8, n)[..., :k, :].astype(jnp.int8)


def _kernel(x_ref, pos_ref, neg_ref, sx_ref, gamma_ref, zcol_ref, o_ref,
            acc_ref, *, n_planes: int, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk) int8
    bk = x.shape[1]
    shifts = jnp.arange(8, dtype=jnp.uint8)

    def unpack(ref, p):
        pk = ref[p]                                 # (bk//8, bn) uint8
        bits = (pk[:, None, :] >> shifts[None, :, None]) & jnp.uint8(1)
        return bits.reshape(bk, -1).astype(jnp.int8)

    w = jnp.zeros((bk, o_ref.shape[1]), jnp.int8)
    for p in range(n_planes):
        w = w + jnp.int8(1 << p) * (unpack(pos_ref, p) - unpack(neg_ref, p))
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(kk == k_steps - 1)
    def _done():
        o_ref[...] = ((acc_ref[...] - zcol_ref[...]).astype(jnp.float32)
                      * sx_ref[...] * gamma_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pann_matmul_packed(x_q: Array, packed_pos: Array, packed_neg: Array,
                       s_x: Array, gamma: Array, zcol: Array | None = None,
                       *, bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = True) -> Array:
    """y = ((x_q @ (W+ - W-)) - zcol) * s_x * gamma with bit-packed planes.

    x_q (M, K) int8; packed_pos/neg (P, K/8, N) uint8; K % bk == 0, bk % 8.
    zcol (N,) int32: zero-point row (z * colsum(w_q); None = 0), subtracted
    in the exact int32 accumulator before the fused dequant.
    """
    m, k = x_q.shape
    p, k8, n = packed_pos.shape
    assert k8 * 8 == k and bk % 8 == 0
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    if zcol is None:
        zcol = jnp.zeros((n,), jnp.int32)
    k_steps = k // bk
    kernel = functools.partial(_kernel, n_planes=p, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((p, bk // 8, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((p, bk // 8, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, packed_pos, packed_neg, s_x, gamma.reshape(1, -1),
      zcol.reshape(1, -1))
