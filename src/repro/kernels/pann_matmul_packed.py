"""Pallas TPU kernel: PANN bit-plane matmul with PACKED plane storage.

The deployment-optimal layout: the binary planes of the unsigned-split PANN
codes are packed 8 bits per byte along K, so weight HBM bytes are
2 * P * K * N / 8 (P = b_R plane count) — e.g. b_R=3 costs 0.75 byte/weight
for BOTH signs vs 2 bytes for bf16 (2.7x) and 1 byte for int8 codes.
Planes are unpacked in VMEM with shifts (VPU) and fed to the same int8 MXU
pass as kernels/pann_matmul.

Layout: packed[p, k8, n] holds bit (k8*8 + j) of plane p in bit j.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def pack_planes(planes: Array) -> Array:
    """(..., K, N) {0,1} int8 -> (..., K/8, N) uint8 (K padded to 8).

    Packing runs along axis -2 (the reduction dim); any leading dims —
    the plane axis, and for serving artifacts the scan-stacked layer/group
    dims, which must stay leading so ``lax.scan`` slices them — pass
    through untouched. packed[..., k8, n] holds bit (k8*8 + j) in bit j.
    """
    *lead, k, n = planes.shape
    pad = (-k) % 8
    if pad:
        planes = jnp.pad(planes,
                         [(0, 0)] * len(lead) + [(0, pad), (0, 0)])
        k += pad
    bits = planes.reshape(*lead, k // 8, 8, n).astype(jnp.uint8)
    weights = (1 << jnp.arange(8, dtype=jnp.uint8)).reshape(8, 1)
    return jnp.sum(bits * weights, axis=-2).astype(jnp.uint8)


def unpack_planes(packed: Array, k: int) -> Array:
    """Inverse of pack_planes (reference / in-kernel helper)."""
    *lead, k8, n = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(8, 1)
    bits = (packed[..., :, None, :] >> shifts) & jnp.uint8(1)
    return bits.reshape(*lead, k8 * 8, n)[..., :k, :].astype(jnp.int8)


def _kernel(x_ref, pos_ref, neg_ref, sx_ref, gamma_ref, zcol_ref, o_ref,
            acc_ref, *, n_planes: int, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk) int8
    bk = x.shape[1]
    shifts = jnp.arange(8, dtype=jnp.uint8)

    def unpack(ref, p):
        pk = ref[p]                                 # (bk//8, bn) uint8
        bits = (pk[:, None, :] >> shifts[None, :, None]) & jnp.uint8(1)
        return bits.reshape(bk, -1).astype(jnp.int8)

    w = jnp.zeros((bk, o_ref.shape[1]), jnp.int8)
    for p in range(n_planes):
        w = w + jnp.int8(1 << p) * (unpack(pos_ref, p) - unpack(neg_ref, p))
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(kk == k_steps - 1)
    def _done():
        o_ref[...] = ((acc_ref[...] - zcol_ref[...]).astype(jnp.float32)
                      * sx_ref[...] * gamma_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pann_matmul_packed(x_q: Array, packed_pos: Array, packed_neg: Array,
                       s_x: Array, gamma: Array, zcol: Array | None = None,
                       *, bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = True) -> Array:
    """y = ((x_q @ (W+ - W-)) - zcol) * s_x * gamma with bit-packed planes.

    x_q (M, K) int8; packed_pos/neg (P, K/8, N) uint8; K % bk == 0, bk % 8.
    zcol (N,) int32: zero-point row (z * colsum(w_q); None = 0), subtracted
    in the exact int32 accumulator before the fused dequant.
    """
    m, k = x_q.shape
    p, k8, n = packed_pos.shape
    assert k8 * 8 == k and bk % 8 == 0
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    if zcol is None:
        zcol = jnp.zeros((n,), jnp.int32)
    k_steps = k // bk
    kernel = functools.partial(_kernel, n_planes=p, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((p, bk // 8, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((p, bk // 8, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, packed_pos, packed_neg, s_x, gamma.reshape(1, -1),
      zcol.reshape(1, -1))


# ---------------------------------------------------------------------------
# Fused act-quant prologue + double-buffered packed-plane DMAs
# ---------------------------------------------------------------------------

def _act_kernel(qp_ref, x_hbm, pos_hbm, neg_hbm, gamma_ref, zcol_ref, o_ref,
                xbuf, codes, pos_buf, neg_buf, w_ref, acc_ref, xsem, pos_sem,
                neg_sem, *, n_planes: int, k_steps: int, bk: int, depth: int,
                i_axis: int, j_axis: int, encode_every_step: bool):
    """Packed twin of ``pann_matmul._pann_matmul_act_kernel`` (see its
    docstring for the dataflow): fp32 x is DMA'd + affine-encoded into a
    persistent VMEM codes panel on its first visit, and the (bk/8, bn)
    uint8 plane tiles stream through ``depth`` VMEM slots with the copy of
    plane p+depth-1 started before plane p's wait, overlapping transfer
    with the VPU unpack/shift-add. Planes below the runtime plane_shift
    scalar (qparams[0, 3]) are dead: no DMA, no unpack, no shift-add."""
    i, j = pl.program_id(i_axis), pl.program_id(j_axis)
    kk = pl.program_id(2)
    s = qp_ref[0, 0]
    z = qp_ref[0, 1]
    n_clip = qp_ref[0, 2]
    if qp_ref.shape == (1, 4):
        shift = jnp.round(qp_ref[0, 3]).astype(jnp.int32)
    else:
        shift = jnp.int32(0)
    bm = xbuf.shape[0]
    bn = o_ref.shape[1]
    shifts = jnp.arange(8, dtype=jnp.uint8)

    def _encode_panel():
        cp = pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)], xbuf, xsem)
        cp.start()
        cp.wait()
        # VERBATIM core.quant.affine_encode — change both or neither
        codes[:, pl.ds(kk * bk, bk)] = jnp.clip(
            jnp.round(xbuf[...] / s) + z, 0.0, n_clip).astype(jnp.int8)

    if encode_every_step:
        _encode_panel()
    else:
        pl.when(j == 0)(_encode_panel)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = codes[:, pl.ds(kk * bk, bk)]            # (bm, bk) int8 codes

    def plane_dma(buf, hbm, sem, slot, p):
        return pltpu.make_async_copy(
            hbm.at[p, pl.ds(kk * (bk // 8), bk // 8), pl.ds(j * bn, bn)],
            buf.at[slot], sem.at[slot])

    def unpack(tile):                           # (bk//8, bn) -> (bk, bn)
        bits = (tile[:, None, :] >> shifts[None, :, None]) & jnp.uint8(1)
        return bits.reshape(bk, bn).astype(jnp.int8)

    # predicated pipeline fill from the first LIVE plane (see pann_matmul)
    for p0 in range(n_planes):
        @pl.when(shift == p0)
        def _fill(p0=p0):
            for d in range(depth - 1):
                if p0 + d < n_planes:
                    plane_dma(pos_buf, pos_hbm, pos_sem,
                              (p0 + d) % depth, p0 + d).start()
                    plane_dma(neg_buf, neg_hbm, neg_sem,
                              (p0 + d) % depth, p0 + d).start()

    w_ref[...] = jnp.zeros_like(w_ref)
    for p in range(n_planes):
        @pl.when(p >= shift)
        def _accum_plane(p=p, slot=p % depth):
            nxt = p + depth - 1
            if nxt < n_planes:
                plane_dma(pos_buf, pos_hbm, pos_sem, nxt % depth,
                          nxt).start()
                plane_dma(neg_buf, neg_hbm, neg_sem, nxt % depth,
                          nxt).start()
            plane_dma(pos_buf, pos_hbm, pos_sem, slot, p).wait()
            plane_dma(neg_buf, neg_hbm, neg_sem, slot, p).wait()
            w_ref[...] += jnp.int8(1 << p) * (unpack(pos_buf[slot])
                                              - unpack(neg_buf[slot]))
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kk == k_steps - 1)
    def _done():
        o_ref[...] = ((acc_ref[...] - zcol_ref[...]).astype(jnp.float32)
                      * s * gamma_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "depth",
                                             "grid_order", "interpret"))
def pann_matmul_packed_act(x: Array, packed_pos: Array, packed_neg: Array,
                           qparams: Array, gamma: Array,
                           zcol: Array | None = None, *, bm: int = 128,
                           bn: int = 128, bk: int = 128, depth: int = 2,
                           grid_order: str = "mnk",
                           interpret: bool = True) -> Array:
    """Fused-prologue packed-plane matmul: quantize-in-kernel on the
    2*P/8-bytes-per-weight deployment artifact.

    x (M, K) f32; packed_pos/neg (P, K/8, N) uint8; K % bk == 0, bk % 8 == 0.
    qparams (1, 4) f32 SMEM scalars [s, z, n_lvl, plane_shift]
    (``quant.affine_scale_zp`` outside the kernel — the shared
    cross-backend derivation; plane_shift = LOW planes to skip at runtime,
    see ``pann_matmul.pann_matmul_act``; (1, 3) accepted = shift 0).
    zcol (N,) int32: zero-point/bias row, subtracted in the exact int32
    accumulator. ``depth``/``grid_order`` as in ``pann_matmul_act``.
    """
    m, k = x.shape
    p, k8, n = packed_pos.shape
    assert k8 * 8 == k and bk % 8 == 0
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert qparams.shape in ((1, 3), (1, 4)), qparams.shape
    assert depth >= 2, depth
    assert grid_order in ("mnk", "nmk"), grid_order
    if zcol is None:
        zcol = jnp.zeros((n,), jnp.int32)
    k_steps = k // bk
    m_steps, n_steps = m // bm, n // bn
    if grid_order == "mnk":
        grid = (m_steps, n_steps, k_steps)
        i_axis, j_axis = 0, 1
        nidx = lambda a, b, kk: (0, b)      # noqa: E731
        oidx = lambda a, b, kk: (a, b)      # noqa: E731
    else:
        grid = (n_steps, m_steps, k_steps)
        i_axis, j_axis = 1, 0
        nidx = lambda a, b, kk: (0, a)      # noqa: E731
        oidx = lambda a, b, kk: (b, a)      # noqa: E731
    encode_every_step = (grid_order == "nmk" and m_steps > 1)
    kernel = functools.partial(_act_kernel, n_planes=p, k_steps=k_steps,
                               bk=bk, depth=depth, i_axis=i_axis,
                               j_axis=j_axis,
                               encode_every_step=encode_every_step)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # qparams
            pl.BlockSpec(memory_space=pltpu.ANY),        # x (manual DMA)
            pl.BlockSpec(memory_space=pltpu.ANY),        # packed_pos
            pl.BlockSpec(memory_space=pltpu.ANY),        # packed_neg
            pl.BlockSpec((1, bn), nidx),
            pl.BlockSpec((1, bn), nidx),
        ],
        out_specs=pl.BlockSpec((bm, bn), oidx),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), jnp.float32),           # fp32 x landing pad
            pltpu.VMEM((bm, k), jnp.int8),               # persistent codes
            pltpu.VMEM((depth, bk // 8, bn), jnp.uint8),  # plane slots (pos)
            pltpu.VMEM((depth, bk // 8, bn), jnp.uint8),  # plane slots (neg)
            pltpu.VMEM((bk, bn), jnp.int8),              # reconstructed w
            pltpu.VMEM((bm, bn), jnp.int32),             # accumulator
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        interpret=interpret,
    )(qparams, x, packed_pos, packed_neg, gamma.reshape(1, -1),
      zcol.reshape(1, -1))
