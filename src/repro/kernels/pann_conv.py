"""Bit-plane conv projections as im2col over the serving matmuls.

A conv layer is served as a MATMUL: the artifact stores the kernel FLAT as
a ``(kh*kw*Cin, Cout)`` weight — the exact shape ``models/serving`` already
quantizes (per-output-channel gamma along the last axis, bit-plane packing
along K, zero-copy ``plane_shift`` rung views) — and the input is expanded
to patch rows at run time (im2col). Geometry (kernel size, stride, padding)
is static config, never artifact data, so the one mmap-able weight store
needs no new leaf types for conv.

Why im2col is bit-exact here and not merely close (DESIGN.md §4 applies
unchanged):

  * activation codes are affine-encoded with ``include_zero`` ranges, so a
    zero-padded fp border encodes to exactly the zero point z; the int32
    ``zcol`` correction subtracts z * colsum(w) per output channel, which
    makes padded positions exact no-ops — zero-padding the fp input and
    then encoding equals encoding and then padding with code z;
  * patch extraction is pure gather (elementwise with respect to values),
    so it commutes with the affine encode: patches-of-codes equal
    codes-of-patches, and the kernels' in-VMEM encode of the patch rows
    produces the identical int8 codes;
  * the int32 patch matmul and the int32 convolution sum the same integer
    products — integer addition is associative, so
    ``dot(patches(q), w_flat) == conv(q, w)`` holds bit-for-bit, which is
    what lets ``dispatch.serving_conv_oracle`` check the Pallas backends
    against ``lax.conv_general_dilated`` with ``assert_array_equal``.

Feature order is the single layout contract: patch row index
``(di*kw + dj)*Cin + c`` ⇔ ``w_flat.reshape(kh, kw, Cin, Cout)`` (HWIO).
Everything in this module is plain jax — no dispatch import, so the
kernel/dispatch layering stays acyclic (dispatch imports us).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    """Output extent of a VALID conv over a ``pad``-padded input."""
    out = (size + 2 * pad - k) // stride + 1
    if out < 1:
        raise ValueError(
            f"conv geometry yields empty output: size={size} k={k} "
            f"stride={stride} pad={pad}")
    return out


def pad_nhwc(x: Array, ph: int, pw: int) -> Array:
    """Zero-pad the spatial dims of an (B, H, W, C) input — in fp, BEFORE
    the activation encode, so the border lands on the zero point exactly."""
    if ph == 0 and pw == 0:
        return x
    return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))


def extract_patches(xpad: Array, kh: int, kw: int, sh: int, sw: int
                    ) -> Array:
    """im2col: (B, Hp, Wp, C) → (B, Ho, Wo, kh*kw*C) patch rows.

    The feature axis is ordered (di, dj, c) — row-major over the kernel
    window, channels fastest — matching ``w_flat.reshape(kh, kw, C, N)``.
    Implemented as kh*kw strided slices (pure gather: XLA fuses these into
    the consumer, and values are never transformed, so extraction commutes
    with the affine encode).
    """
    b, hp, wp, c = xpad.shape
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    slabs = [xpad[:, di:di + sh * (ho - 1) + 1:sh,
                  dj:dj + sw * (wo - 1) + 1:sw, :]
             for di in range(kh) for dj in range(kw)]
    return jnp.concatenate(slabs, axis=-1)


def conv_int32(q: Array, w_flat: Array, kh: int, kw: int, sh: int, sw: int
               ) -> Array:
    """Exact int32 VALID convolution of code tensors — the oracle's core.

    ``q``: (B, Hp, Wp, Cin) integer activation codes (already padded);
    ``w_flat``: (kh*kw*Cin, Cout) integer weight codes in the flat layout.
    Bit-identical to ``extract_patches(q) @ w_flat`` (associative int sums).
    """
    c_in = q.shape[-1]
    w4 = w_flat.astype(jnp.int32).reshape(kh, kw, c_in, -1)
    return jax.lax.conv_general_dilated(
        q.astype(jnp.int32), w4, window_strides=(sh, sw), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
