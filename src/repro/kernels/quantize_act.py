"""Pallas TPU kernel: standalone per-row (per-token) activation quantization.

STATUS: reference oracle only. No serving path calls this kernel anymore —
every production projection quantizes activations inside the matmul
prologue (``pann_matmul_act`` / ``pann_matmul_packed_act``), where the fp32
activations cross HBM once and the codes never do. This kernel is retained
as the measured BASELINE for that fusion (benchmarks/kernel_bench.py times
both) and as a parity target for the standalone-quantization tests
(tests/test_kernels.py); new callers should go through
``kernels.dispatch.serving_linear`` or ``ops.pann_matmul`` instead.

Computes, in one VMEM pass per row-tile:
    amax[m]  = max(relu(x[m, :]))
    scale[m] = amax[m] / qmax          (qmax = 2^(b-1) - 1, half-range App. A.4)
    q[m, k]  = clip(round(x[m, k] / scale[m]), 0, qmax)  as int8

Per-row scales keep the unsigned-code convention of Sec. 4 (activations are
non-negative post-ReLU / post-softmax) and avoid a second HBM pass for the
scale reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ops

Array = jax.Array


def _quantize_kernel(x_ref, q_ref, s_ref, *, qmax: int):
    x = jnp.maximum(x_ref[...].astype(jnp.float32), 0.0)
    amax = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), 0, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def quantize_act(x: Array, *, bits: int = 8, bm: int = 128,
                 interpret: bool | None = None) -> tuple[Array, Array]:
    """x (M, K) float -> (codes int8 (M, K), scales f32 (M, 1)).

    ``interpret=None`` resolves by platform (``ops.on_tpu``), matching the
    matmul wrappers — the old unconditional ``interpret=True`` default ran
    the emulator even on TPU. Ragged M (not a multiple of ``bm``) is padded
    up and sliced back: padded rows are all-zero, so their amax floors at
    the 1e-12 epsilon and their codes are exact zeros — callers see only
    the true rows either way.
    """
    interpret = (not ops.on_tpu()) if interpret is None else interpret
    m, k = x.shape
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    mp = m + pad
    qmax = (1 << (bits - 1)) - 1  # half-range unsigned (App. A.4)
    kernel = functools.partial(_quantize_kernel, qmax=qmax)
    q, s = pl.pallas_call(
        kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, k), jnp.int8),
                   jax.ShapeDtypeStruct((mp, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:m], s[:m]
