"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pann_matmul_ref(x_q: Array, planes_pos: Array, planes_neg: Array,
                    s_x: Array, gamma: Array) -> Array:
    """Oracle for kernels.pann_matmul: reconstruct signed integer weights from
    bit-planes, integer matmul, dequantize."""
    p = planes_pos.shape[0]
    weights = (2 ** jnp.arange(p, dtype=jnp.int32)).reshape(p, 1, 1)
    w_q = jnp.sum(weights * (planes_pos.astype(jnp.int32)
                             - planes_neg.astype(jnp.int32)), axis=0)
    y = jnp.matmul(x_q.astype(jnp.int32), w_q,
                   preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) * s_x * gamma.reshape(1, -1)


def quantize_act_ref(x: Array, bits: int = 8) -> tuple[Array, Array]:
    """Oracle for kernels.quantize_act (per-row half-range unsigned codes)."""
    qmax = (1 << (bits - 1)) - 1
    xp = jnp.maximum(x.astype(jnp.float32), 0.0)
    amax = jnp.max(xp, axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xp / scale), 0, qmax).astype(jnp.int8)
    return q, scale


def unsigned_matmul_ref(x_q: Array, w_q: Array, s_x: Array, s_w: Array
                        ) -> Array:
    """Oracle for kernels.unsigned_matmul: plain signed integer matmul."""
    y = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                   preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) * s_x * s_w.reshape(1, -1)
