"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pann_matmul_ref(x_q: Array, planes_pos: Array, planes_neg: Array,
                    s_x: Array, gamma: Array) -> Array:
    """Oracle for kernels.pann_matmul: reconstruct signed integer weights from
    bit-planes, integer matmul, dequantize."""
    p = planes_pos.shape[0]
    weights = (2 ** jnp.arange(p, dtype=jnp.int32)).reshape(p, 1, 1)
    w_q = jnp.sum(weights * (planes_pos.astype(jnp.int32)
                             - planes_neg.astype(jnp.int32)), axis=0)
    y = jnp.matmul(x_q.astype(jnp.int32), w_q,
                   preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) * s_x * gamma.reshape(1, -1)


def quantize_act_ref(x: Array, bits: int = 8) -> tuple[Array, Array]:
    """Oracle for kernels.quantize_act (per-row half-range unsigned codes)."""
    qmax = (1 << (bits - 1)) - 1
    xp = jnp.maximum(x.astype(jnp.float32), 0.0)
    amax = jnp.max(xp, axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xp / scale), 0, qmax).astype(jnp.int8)
    return q, scale


def unsigned_matmul_ref(x_q: Array, w_q: Array, s_x: Array, s_w: Array
                        ) -> Array:
    """Oracle for kernels.unsigned_matmul: plain signed integer matmul."""
    y = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                   preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) * s_x * s_w.reshape(1, -1)


# ---------------------------------------------------------------------------
# Quantized KV-cache codec + decode-attention oracle (docs/kv_cache.md)
# ---------------------------------------------------------------------------

# The cache layout pins this many bit-planes whatever the rung's cache bits
# are: rungs that use fewer bits write zero high planes, so one jitted
# decode step serves every cache rung (the LADDER_PLANE_COUNT analogue for
# the cache; unsigned affine codes are clipped to n <= 127 = 2^7 - 1).
CACHE_PLANES = 7

# Probabilities are re-quantized to this fixed-point scale for the exact
# int32 PV pass: sum_s p = 1, so sum_s round(p * 2^14) ~ 2^14 and
# pq @ vq <= 127 * 2^14 — int32-safe for ANY sequence length.
PROB_SCALE = float(1 << 14)

_CACHE_NEG_INF = -1e30   # matches models.attention.NEG_INF


def pack_cache_codes(codes: Array, n_planes: int = CACHE_PLANES) -> Array:
    """Pack unsigned integer codes (..., d) in [0, 2^n_planes) into
    bit-planes of 8 bits/byte along the LAST axis: (n_planes, ..., d//8)
    uint8. Plane p holds bit p of every code; byte j of a plane holds
    positions 8j..8j+7, element 8j+i at bit i. Requires d % 8 == 0
    (head dims are; asserted). Distinct from ``core.pann.pack_planes``,
    which packs the weight planes along axis -2 for the matmul kernels."""
    d = codes.shape[-1]
    assert d % 8 == 0, f"cache codec packs along head_dim; {d} % 8 != 0"
    c = codes.astype(jnp.int32)
    shifts = jnp.arange(n_planes, dtype=jnp.int32).reshape(
        (n_planes,) + (1,) * c.ndim)
    planes = (c[None] >> shifts) & 1                      # (P, ..., d)
    bits = planes.reshape(planes.shape[:-1] + (d // 8, 8))
    weights = (1 << jnp.arange(8, dtype=jnp.int32))
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def unpack_cache_codes(packed: Array) -> Array:
    """Inverse of :func:`pack_cache_codes`: (P, ..., d//8) uint8 ->
    (..., d) int32."""
    p = packed.shape[0]
    bits = (packed[..., None].astype(jnp.int32)
            >> jnp.arange(8, dtype=jnp.int32)) & 1        # (P, ..., d8, 8)
    bits = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * 8,))
    weights = (1 << jnp.arange(p, dtype=jnp.int32)).reshape(
        (p,) + (1,) * (packed.ndim - 1))
    return jnp.sum(bits * weights, axis=0)


def decode_attention_ref(qq: Array, q_z: Array, q_scale: Array,
                         k_planes: Array, k_s: Array, k_z: Array,
                         v_planes: Array, v_s: Array, v_z: Array,
                         pos: Array, *, window=None, softcap: float = 0.0,
                         prob_scale: float = PROB_SCALE) -> Array:
    """Oracle for kernels.pann_attention.decode_attention: one-token GQA
    decode attention read DIRECTLY off the packed bit-plane KV cache.

    Shapes: qq (B, K, G, hd) int32 affine q codes (zero point ``q_z``,
    scalar int32); ``q_scale`` = s_q * hd**-0.5, scalar fp32; k_planes /
    v_planes (B, P, S, K, hd//8) uint8; k_s/k_z/v_s/v_z (B, S) fp32
    per-position quantizer rows (z integer-valued); pos () or (B,) int32.

    The integer passes are EXACT (both zero points corrected inside int32;
    probabilities re-quantized at ``prob_scale``); the fp32 epilogue is the
    op sequence the Pallas kernel replicates VERBATIM, so ref and kernel
    are bit-identical in fp32 (tests/test_kv_cache_quant.py).
    """
    b, kh, g, hd = qq.shape
    s = k_planes.shape[2]
    kq = unpack_cache_codes(jnp.moveaxis(k_planes, 1, 0))   # (B, S, K, hd)
    vq = unpack_cache_codes(jnp.moveaxis(v_planes, 1, 0))
    qq = qq.astype(jnp.int32)
    qz = jnp.asarray(q_z, jnp.int32)
    kz = jnp.round(k_z).astype(jnp.int32)                   # (B, S)
    vz = jnp.round(v_z).astype(jnp.int32)
    # exact int32 QK^T with BOTH zero points corrected in the accumulator:
    # (qq - z_q) . (kq - z_k) = qq.kq - z_q*colsum(kq) - z_k*rowsum(qq)
    #                           + z_q*z_k*hd
    dots = jnp.einsum("bkgh,bskh->bkgs", qq, kq,
                      preferred_element_type=jnp.int32)
    colsum_k = jnp.sum(kq, axis=-1)                         # (B, S, K)
    rowsum_q = jnp.sum(qq, axis=-1)                         # (B, K, G)
    kz_b = kz[:, None, None, :]                             # (B, 1, 1, S)
    i32 = (dots
           - qz * jnp.moveaxis(colsum_k, 1, -1)[:, :, None, :]
           - kz_b * rowsum_q[..., None]
           + qz * kz_b * hd)
    # fp32 epilogue — fixed association, replicated in the kernel
    sc = (i32.astype(jnp.float32) * jnp.asarray(q_scale, jnp.float32)
          ) * k_s[:, None, None, :]
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    k_pos = jnp.arange(s, dtype=jnp.int32)
    valid = k_pos[None, :] <= pos_b[:, None]                # (B, S)
    if window is not None:
        valid &= (pos_b[:, None] - k_pos[None, :]) < window
    sc = jnp.where(valid[:, None, None, :], sc, _CACHE_NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    # exact int32 PV: probs are rescaled into V's largest per-batch scale,
    # re-quantized at prob_scale, and the V zero point is subtracted inside
    # the accumulator (same zcol convention as serving_linear)
    sv_ref = jnp.maximum(jnp.max(jnp.where(valid, v_s, 0.0), axis=-1),
                         1e-12)                             # (B,)
    ratio = v_s / sv_ref[:, None]                           # (B, S)
    pq = jnp.round(p * ratio[:, None, None, :] * prob_scale
                   ).astype(jnp.int32)                      # (B, K, G, S)
    pv = jnp.einsum("bkgs,bskh->bkgh", pq, vq,
                    preferred_element_type=jnp.int32)
    corr = jnp.einsum("bkgs,bs->bkg", pq, vz,
                      preferred_element_type=jnp.int32)
    scale = sv_ref / prob_scale                             # (B,)
    return ((pv - corr[..., None]).astype(jnp.float32)
            * scale[:, None, None, None])
