"""Pluggable backends for the serving matmul — the single choke point that
turns the PANN deployment artifact ({"w_q", "w_scale", ...}; built by
``models/serving.quantize_params_for_serving``) into projection outputs.

Backends (selected per engine via ``ModelConfig.kernel_backend`` and threaded
through ``models.layers.apply_linear``):

  ``ref``     plain-jnp integer dataflow — runs on any platform; the oracle.
  ``fused``   Pallas bit-plane matmul (``kernels/pann_matmul``, mode='fused'):
              bit-planes are rebuilt from the int8 codes at trace time and
              fed to one int8 MXU pass per tile.
  ``packed``  Pallas packed-plane matmul (``kernels/pann_matmul_packed``):
              reads the bit-packed ``w_planes_pos``/``w_planes_neg`` artifact
              leaves (8 codes/byte along K — 2*P/8 bytes/weight HBM for plane
              count P = the module's b_R), unpacking in VMEM.

The Pallas backends use the FUSED-PROLOGUE kernels (``pann_matmul_act`` /
``pann_matmul_packed_act``): fp32 activations go straight into the kernel,
which affine-encodes them tile-locally in VMEM — the int8 code tensor never
round-trips through HBM (ROADMAP item 3; ``kernel_bench`` accounts the
eliminated bytes). Only the (s, z) SCALARS are computed outside (a global
range reduction can't be tile-local), by the one ``core.quant`` derivation
all backends share; for export-frozen calibration they are precomputed
artifact leaves (``act_s``/``act_z``, hoisted by ``models/serving``).
Block shapes come from ``kernels.autotune`` — measured-best per
(M, K, N, planes) from a persistent per-device cache, VMEM-model heuristic
otherwise; the lookup is deterministic at trace time so warmed engines
never retrace.

Every backend realizes the SAME integer dataflow, so their fp32 outputs are
bit-identical (asserted in tests/test_kernel_dispatch.py, gated in CI by
``benchmarks/kernel_bench.py --check``):

  1. activations are affine-quantized to unsigned codes
     ``q = clip(round(x/s) + z, 0, n)`` with ``n = min(act_n, 127)`` — the
     zero point z absorbs signed transformer activations (DESIGN.md §4) and
     n is capped at the kernels' half-range int8 code space (App. A.4);
     the ref backend applies ``quant.affine_encode`` in XLA, the Pallas
     backends apply the same formula in-kernel on the same sealed (s, z);
  2. ``y_int = q @ w_q - z * colsum(w_q) + round(b / (s*gamma))`` is
     computed exactly in int32 (MXU pass or jnp; the kernels fuse the
     combined zero-point/bias row ``zcol`` into the accumulator) — the
     per-output-channel correction keeps the MACs genuinely unsigned
     (Observation 1 / Eq. 5-6), and the bias lands on the output grid the
     way integer inference engines add it;
  3. ``y = y_int * s * gamma`` — two fp32 multiplies, identical
     association everywhere, and nothing downstream for XLA to
     fma-contract differently per backend.

Fallback policy (``resolve_backend``): 'fused'/'packed' degrade to 'ref' off
TPU, where the Pallas kernels would only be emulated. Appending ``:force`` (e.g.
"packed:force") runs the Pallas kernel anyway — interpret mode off-TPU;
slow, test/CI only, bit-identical by construction. Pad-to-block handling
lives HERE, not in callers: inputs are padded to tile multiples with zero
codes / zero planes (exact no-ops) and the result is sliced back.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.pann import bitplane_decompose, masked_codes
from repro.kernels import autotune
from repro.kernels import ops
from repro.kernels import pann_attention as _pa
from repro.kernels import pann_conv as _pc
from repro.kernels import pann_matmul as _pm
from repro.kernels import pann_matmul_packed as _pk
from repro.kernels import ref as _ref

Array = jax.Array

BACKENDS = ("ref", "fused", "packed")

# int8 serving codes are clipped to +-127 = 2^7 - 1, so 7 planes always
# reconstruct them exactly — the envelope used when no packed artifact
# pins the module's plane count.
INT8_PLANES = 7

# n = 2^7 - 1: the kernels' int8 lanes hold unsigned codes in [0, 127]
# (the paper's App.-A.4 half-range convention), so b~x >= 8 operating
# points run their activations at this ceiling inside the kernels.
HALF_RANGE_LEVELS = 127.0


def parse_backend(spec: str) -> tuple[str, bool]:
    """'fused' -> ('fused', False); 'packed:force' -> ('packed', True)."""
    name, _, opt = spec.partition(":")
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"have {BACKENDS}")
    if opt not in ("", "force"):
        raise ValueError(f"unknown backend option {opt!r} in {spec!r}; "
                         "only ':force' (run Pallas in interpret mode "
                         "off-TPU) is recognized")
    return name, opt == "force"


def resolve_backend(spec: str, p: dict) -> tuple[str, bool]:
    """(effective backend, interpret flag) for artifact ``p`` on this host.

    Non-TPU hosts without ':force' resolve to 'ref' (ragged shapes are not
    misfits — padding below absorbs them). A 'packed' request against a
    variant built without plane leaves is a build error, not a misfit —
    raised, never silently degraded.
    """
    name, force = parse_backend(spec)
    if name == "ref":
        return "ref", False
    if name == "packed" and "w_planes_pos" not in p:
        raise ValueError(
            "backend 'packed' needs the w_planes_pos/w_planes_neg artifact "
            "leaves; build the variant with "
            "quantize_params_for_serving(..., pack_planes=True)")
    if not ops.on_tpu() and not force:
        return "ref", False
    return name, not ops.on_tpu()


def _pick_bk(bk: int, mult: int) -> int:
    """Largest multiple of ``mult`` <= bk (floor at ``mult``)."""
    return max(mult, bk - bk % mult)


def _matmul_ref(q8: Array, w_q: Array, s, gamma: Array, zcol: Array
                ) -> Array:
    """jnp oracle of the kernels' finalize: exact int32 matmul, exact int32
    zero-point subtraction, then the identical fp32 multiply chain
    (y * s * gamma, in that association)."""
    y_int = jax.lax.dot_general(q8, w_q, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    return (y_int - zcol).astype(jnp.float32) * s * gamma


def _qparams(s, z, n_lvl, shift=None) -> Array:
    """(1, 4) f32 SMEM block [s, z, n_lvl, plane_shift] for the
    fused-prologue kernels. ``shift`` is the count of LOW bit-planes the
    kernel skips at runtime (a rung view over a max-R plane store); None
    means 0 — all planes live."""
    if shift is None:
        shift = jnp.float32(0.0)
    return jnp.stack([jnp.asarray(s, jnp.float32).reshape(()),
                      jnp.asarray(z, jnp.float32).reshape(()),
                      jnp.asarray(n_lvl, jnp.float32).reshape(()),
                      jnp.asarray(shift, jnp.float32).reshape(())]
                     ).reshape(1, 4)


def _matmul_fused(xf: Array, w_q: Array, s, z, n_lvl, gamma: Array,
                  zcol: Array, n_planes: int, interpret: bool,
                  shift=None,
                  params: autotune.KernelParams | None = None) -> Array:
    """Fused-prologue bit-plane kernel on planes rebuilt from the int8
    codes: fp32 activations in, affine-encoded in VMEM (codes never touch
    HBM). Padded fp32 rows/cols encode to the code z, which multiplies the
    zero-padded plane region — an exact no-op, then sliced away. With a
    view ``shift``, the codes are the max-R store's and the kernel skips
    the dead low planes at runtime."""
    pos = bitplane_decompose(jnp.maximum(w_q, 0), n_planes)
    neg = bitplane_decompose(jnp.maximum(-w_q.astype(jnp.int32), 0),
                             n_planes)
    m, k = xf.shape
    n = w_q.shape[-1]
    if params is None:
        params = autotune.params_for(m, k, n, n_planes, "fused")
    bm, bn, bk = params.blocks
    xp = ops._pad_to(ops._pad_to(xf, bm, 0), bk, 1)
    pp = ops._pad_to(ops._pad_to(pos, bk, 1), bn, 2)
    pn = ops._pad_to(ops._pad_to(neg, bk, 1), bn, 2)
    gp = ops._pad_to(gamma, bn, 0)
    zp = ops._pad_to(zcol, bn, 0)
    y = _pm.pann_matmul_act(xp, pp, pn, _qparams(s, z, n_lvl, shift), gp,
                            zp, mode="fused", bm=bm, bn=bn, bk=bk,
                            depth=params.depth, grid_order=params.order,
                            interpret=interpret)
    return y[:m, :n]


def _matmul_packed(xf: Array, pp: Array, pn: Array, s, z, n_lvl,
                   gamma: Array, zcol: Array, interpret: bool, shift=None,
                   params: autotune.KernelParams | None = None) -> Array:
    """Fused-prologue packed-plane kernel on the uint8 artifact leaves."""
    m, k = xf.shape
    k_full = pp.shape[-2] * 8        # pack_planes padded K up to 8
    n = pp.shape[-1]
    n_planes = pp.shape[-3]
    if params is None:
        params = autotune.params_for(m, k_full, n, n_planes, "packed")
    bm, bn, bk = params.blocks
    bk = _pick_bk(bk, 8)             # the packed kernel needs bk % 8 == 0
    xp = ops._pad_to(ops._pad_to(xf, bm, 0), k_full, 1)
    xp = ops._pad_to(xp, bk, 1)
    k_pad = xp.shape[1]
    ppp = ops._pad_to(ops._pad_to(pp, k_pad // 8, 1), bn, 2)
    pnp = ops._pad_to(ops._pad_to(pn, k_pad // 8, 1), bn, 2)
    gp = ops._pad_to(gamma, bn, 0)
    zp = ops._pad_to(zcol, bn, 0)
    y = _pk.pann_matmul_packed_act(xp, ppp, pnp,
                                   _qparams(s, z, n_lvl, shift),
                                   gp, zp, bm=bm, bn=bn, bk=bk,
                                   depth=params.depth,
                                   grid_order=params.order,
                                   interpret=interpret)
    return y[:m, :n]


def _act_scalars(xf: Array, p: dict) -> tuple[Array, Array, Array]:
    """The per-projection activation-quantizer scalars (s, z, n_lvl).

    PREFERS the artifact leaves hoisted by ``models/serving``:
    ``act_nlvl`` (= min(act_n, 127), saving a min per projection per decode
    step) and — for export-frozen calibration — ``act_s``/``act_z``, which
    turn the whole derivation into two leaf reads. Both hoists are computed
    at build time with the IDENTICAL ``core.quant`` op sequence used here,
    so hoisted and derived artifacts are bit-exact. Fallbacks keep
    pre-hoist artifacts (and hand-built test leaves) serving unchanged.

    include_zero (inside ``act_range_bounds``) bounds z to [0, n]: without
    it, activations that do not span zero produce |z| far outside int32 and
    the zcol correction wraps.
    """
    nlvl = p.get("act_nlvl")
    if nlvl is not None:
        n_lvl = jnp.asarray(nlvl, jnp.float32).reshape(())
    else:
        act_n = p.get("act_n")
        if act_n is None:
            n_lvl = jnp.float32(HALF_RANGE_LEVELS)
        else:
            n_lvl = jnp.minimum(jnp.asarray(act_n, jnp.float32).reshape(()),
                                HALF_RANGE_LEVELS)
    act_s = p.get("act_s")
    if act_s is not None:
        # frozen calibration with build-time-hoisted scalars
        return (jnp.asarray(act_s, jnp.float32).reshape(()),
                jnp.asarray(p["act_z"], jnp.float32).reshape(()),
                n_lvl)
    act_lo = p.get("act_lo")
    if act_lo is not None:
        # export-frozen EMA calibration without the hoist (older
        # artifacts): same zero-extended frozen-range convention as the
        # QAT forward — one range convention everywhere
        lo, hi = quant.act_range_bounds(
            xf, jnp.asarray(act_lo, jnp.float32).reshape(()),
            jnp.asarray(p["act_hi"], jnp.float32).reshape(()))
    else:
        lo, hi = quant.act_range_bounds(xf, include_zero=True)
    s, z = quant.affine_scale_zp(lo, hi, n_lvl)
    return s, z, n_lvl


def _shift_leaf(p: dict):
    """The module's ``plane_shift`` view leaf as a traced f32 scalar.

    A rung VIEW over a max-R plane store (models/serving build_rung_views)
    marks its dead low planes with this DATA leaf; the kernels skip them at
    runtime, so every rung shares one compilation. Artifacts without the
    leaf get None -> shift 0 -> the pre-view dataflow.
    """
    shift = p.get("plane_shift")
    if shift is None:
        return None
    return jnp.asarray(shift, jnp.float32).reshape(())


def _gamma_zcol(p: dict, s, z, shift) -> tuple[Array, Array]:
    """(gamma, zcol): the per-output-channel dequant scale and the EXACT
    int32 zero-point/bias row — s(q - z) @ (gamma*w) = s*gamma*(q @ w_q
    - z*colsum(w_q)). Subtracting inside the integer accumulator (kernels
    take zcol; the jnp oracles mirror it) keeps the epilogue free of fp
    adds, which XLA would contract into backend-dependent fmas — the
    backends' bit-exactness depends on this.

    The artifact carries colsum precomputed (models/serving.py) so the
    packed backend never has to stream the full int8 code tensor just for
    this reduction; recomputing is the fallback for hand-built leaves.
    """
    w_q = p["w_q"]
    gamma = p["w_scale"].astype(jnp.float32).reshape(-1)
    colsum = p.get("w_colsum")
    if colsum is None:
        wc = (masked_codes(w_q, shift) if shift is not None
              else w_q.astype(jnp.int32))
        colsum = jnp.sum(wc, axis=-2)
    zcol = z.astype(jnp.int32) * colsum
    if "b" in p:
        # bias joins the accumulator too, quantized onto the output grid
        # s*gamma — the standard integer-inference bias treatment
        # (gemmlowp/TFLite) and the only formulation whose rounding XLA
        # cannot re-associate differently per backend (an fp "+ b" after
        # the dequant multiplies gets fma-contracted next to a jnp dot but
        # not next to a pallas call). Clipped so zcol - b_q stays well
        # inside int32 whatever the scales are.
        b_q = jnp.clip(jnp.round(p["b"].astype(jnp.float32) / (s * gamma)),
                       -2.0 ** 30, 2.0 ** 30).astype(jnp.int32)
        zcol = zcol - b_q
    return gamma, zcol


def _dispatch_rows(xf: Array, p: dict, s, z, n_lvl, gamma: Array,
                   zcol: Array, shift, name: str, interpret: bool) -> Array:
    """The backend branch on SEALED scalars: fp32 patch/token rows in,
    (M, N) fp32 out — shared verbatim by ``serving_linear`` and
    ``serving_conv``, which is what makes the conv projection inherit the
    matmuls' cross-backend bit-exactness rather than re-prove it."""
    w_q = p["w_q"]
    if name == "fused":
        n_planes = (p["w_planes_pos"].shape[-3] if "w_planes_pos" in p
                    else INT8_PLANES)
        return _matmul_fused(xf, w_q, s, z, n_lvl, gamma, zcol, n_planes,
                             interpret, shift=shift)
    if name == "packed":
        return _matmul_packed(xf, p["w_planes_pos"], p["w_planes_neg"],
                              s, z, n_lvl, gamma, zcol, interpret,
                              shift=shift)
    # the jnp oracle materializes the codes (quant.affine_encode — the
    # formula the kernels inline) and seals them so XLA cannot re-fuse
    # the encode into the dot differently than the kernels would
    q8 = jax.lax.optimization_barrier(
        quant.affine_encode(xf, s, z, n_lvl).astype(jnp.int8))
    # view shift: mask the dead low planes out of the codes — the jnp
    # mirror of the kernels' plane skip (masked * gamma_R is exactly
    # the truncated-code weight at the rung step gamma_R * 2^shift)
    w_ref_q = (masked_codes(w_q, shift).astype(jnp.int8)
               if shift is not None else w_q)
    return _matmul_ref(q8, w_ref_q, s, gamma, zcol)


def serving_linear(x: Array, p: dict, backend: str) -> Array:
    """The serving projection: y = affine-quant(x) @ deq(w_q) [+ b] through
    the selected backend. ``p`` is one module's serving artifact (2-D w_q —
    scan bodies slice stacked leaves before we ever see them).

    Output dtype follows x; the fp32 result is bit-identical across
    backends (module docstring). ``act_n`` (2^b~x - 1, a data leaf so
    ladder rungs share one compilation) sets the activation levels; absent,
    activations quantize at the 8-bit operating point's half-range.
    """
    name, interpret = resolve_backend(backend, p)
    w_q = p["w_q"]
    assert w_q.ndim == 2, (
        f"serving_linear wants a per-layer (K, N) weight slice, got "
        f"{w_q.shape} — scan bodies must slice stacked leaves first")
    lead, k = x.shape[:-1], x.shape[-1]
    n_out = w_q.shape[-1]

    # entry barrier: seal the backend-specific subgraph off from upstream
    # fusion/layout decisions, so the surrounding (graph-identical) program
    # compiles the same way whichever backend sits between the barriers —
    # the bit-exactness contract must survive jit, not just eager mode
    xf = jax.lax.optimization_barrier(x.reshape(-1, k).astype(jnp.float32))
    s, z, n_lvl = _act_scalars(xf, p)
    shift = _shift_leaf(p)
    # seal the quantizer scalars: left open, XLA folds their derivation
    # into the backend-specific consumer cluster (e.g. strength-reducing
    # the x/s divide differently next to a dot than next to a pallas call)
    # and the codes stop matching across backends. The Pallas backends
    # consume these SAME sealed scalars — the in-kernel encode and the ref
    # encode below run the identical affine map on identical inputs.
    s, z, n_lvl = jax.lax.optimization_barrier((s, z, n_lvl))
    gamma, zcol = _gamma_zcol(p, s, z, shift)
    y = _dispatch_rows(xf, p, s, z, n_lvl, gamma, zcol, shift,
                       name, interpret)
    return y.reshape(*lead, n_out).astype(x.dtype)


def serving_conv(x: Array, p: dict, spec, backend: str) -> Array:
    """The serving CONV projection: im2col over the serving matmuls.

    ``x``: (B, H, W, Cin) fp input; ``p``: the layer's serving artifact with
    the kernel FLAT as (kh*kw*Cin, Cout) w_q (kernels/pann_conv layout
    contract — same leaves, plane packing, and rung views as any linear);
    ``spec``: the static geometry (any object with kh/kw/sh/sw/ph/pw ints,
    e.g. ``configs.base.ConvSpec``). Returns (B, Ho, Wo, Cout) in x.dtype.

    One deliberate divergence from ``serving_linear``: the activation
    scalars are derived from the PADDED INPUT tensor, not the patch rows.
    Strided geometry may leave pixels out of every patch, so patch-derived
    ranges could differ between geometries over the same input; deriving
    from the input keeps the quantizer a function of the tensor alone, and
    ``serving_conv_oracle`` consumes the identical sealed scalars so the
    bit-exactness contract is unaffected. Padding happens in fp BEFORE the
    encode: with include_zero ranges the border encodes to exactly z and
    the zcol correction makes it an exact no-op (pann_conv docstring).
    """
    name, interpret = resolve_backend(backend, p)
    w_q = p["w_q"]
    assert w_q.ndim == 2 and x.ndim == 4, (w_q.shape, x.shape)
    b = x.shape[0]
    n_out = w_q.shape[-1]
    # entry barrier on the padded fp input — the conv analogue of sealing
    # the (-1, K) rows: everything backend-specific hangs off this value
    xpad = jax.lax.optimization_barrier(
        _pc.pad_nhwc(x.astype(jnp.float32), spec.ph, spec.pw))
    s, z, n_lvl = _act_scalars(xpad.reshape(-1, xpad.shape[-1]), p)
    shift = _shift_leaf(p)
    s, z, n_lvl = jax.lax.optimization_barrier((s, z, n_lvl))
    gamma, zcol = _gamma_zcol(p, s, z, shift)
    patches = _pc.extract_patches(xpad, spec.kh, spec.kw, spec.sh, spec.sw)
    ho, wo = patches.shape[1], patches.shape[2]
    xf = patches.reshape(-1, patches.shape[-1])
    y = _dispatch_rows(xf, p, s, z, n_lvl, gamma, zcol, shift,
                       name, interpret)
    return y.reshape(b, ho, wo, n_out).astype(x.dtype)


def serving_conv_oracle(x: Array, p: dict, spec) -> Array:
    """jnp int32 convolution oracle for ``serving_conv``: the same sealed
    scalars and zcol row, but the integer accumulation runs through
    ``lax.conv_general_dilated`` instead of im2col + matmul. Integer sums
    are associative, so every backend of ``serving_conv`` must match this
    bit-for-bit in fp32 (asserted in tests/test_encoder_serving.py) — the
    conv counterpart of ``_matmul_ref``."""
    w_q = p["w_q"]
    xpad = jax.lax.optimization_barrier(
        _pc.pad_nhwc(x.astype(jnp.float32), spec.ph, spec.pw))
    s, z, n_lvl = _act_scalars(xpad.reshape(-1, xpad.shape[-1]), p)
    shift = _shift_leaf(p)
    s, z, n_lvl = jax.lax.optimization_barrier((s, z, n_lvl))
    gamma, zcol = _gamma_zcol(p, s, z, shift)
    q = jax.lax.optimization_barrier(
        quant.affine_encode(xpad, s, z, n_lvl).astype(jnp.int8))
    w_int = (masked_codes(w_q, shift) if shift is not None
             else w_q.astype(jnp.int32))
    y_int = _pc.conv_int32(q, w_int, spec.kh, spec.kw, spec.sh, spec.sw)
    y = (y_int - zcol).astype(jnp.float32) * s * gamma
    return y.astype(x.dtype)


def cache_planes_active(n_lvl) -> Array:
    """Live LOW bit-planes of a cache code space with ``n_lvl`` levels:
    codes <= n_lvl < 2^b zero every plane >= b = log2(n_lvl + 1). Traced —
    the level count is a DATA leaf so ladder rungs share one compilation."""
    n = jnp.asarray(n_lvl, jnp.float32).reshape(())
    return jnp.ceil(jnp.log2(n + 1.0) - 1e-6)


def decode_attention(q: Array, kv, backend, *, num_kv_heads: int,
                     window=None, softcap: float = 0.0,
                     k_nlvl=None, v_nlvl=None) -> Array:
    """Decode attention over a quantized KV cache — the attention analogue
    of ``serving_linear``, one dispatch point for every backend.

    ``q``: (B, H, hd) fp queries of the current token (RoPE applied).
    ``kv``: a quantized cache, duck-typed — any object with ``k_planes`` /
    ``v_planes`` (B, P, S, K, hd//8) uint8, ``k_s``/``k_z``/``v_s``/``v_z``
    (B, S) f32 and scalar ``length`` (``models.attention.QuantKVCache``; no
    models import here, same reason serving_linear takes a plain dict).

    Queries are affine-quantized per-tensor at the kernels' half-range
    ceiling (q is transient — the cache codes are the power knob, DESIGN.md
    §10), with the same sealed-scalar discipline as ``serving_linear`` so
    'ref' and a ':force'd Pallas run consume identical codes. Backend
    fallback mirrors ``resolve_backend``: 'fused'/'packed' both name the
    one bit-plane attention kernel and degrade to the jnp oracle off-TPU
    unless forced.

    ``k_nlvl``/``v_nlvl`` (traced scalars; the cache's level-count leaves)
    let the kernel skip the DMA + unpack of the dead HIGH planes — codes
    <= n_lvl leave planes >= log2(n_lvl+1) all-zero, so skipping them is
    bit-exact and the oracle needs no counterpart. None = all planes live.
    """
    name, force = parse_backend(backend or "ref")
    use_kernel = name != "ref" and (ops.on_tpu() or force)
    b, h, hd = q.shape
    g = h // num_kv_heads
    # entry barrier + sealed quantizer scalars: the serving_linear contract
    qf = jax.lax.optimization_barrier(
        q.astype(jnp.float32).reshape(b, num_kv_heads, g, hd))
    lo, hi = quant.act_range_bounds(qf, include_zero=True)
    s_q, z_q = quant.affine_scale_zp(lo, hi, HALF_RANGE_LEVELS)
    q_scale = s_q * jnp.float32(hd) ** -0.5   # fold the 1/sqrt(hd) in once
    s_q, z_q, q_scale = jax.lax.optimization_barrier((s_q, z_q, q_scale))
    qq = jax.lax.optimization_barrier(
        quant.affine_encode(qf, s_q, z_q, HALF_RANGE_LEVELS)
        .astype(jnp.int32))
    args = (qq, z_q, q_scale, kv.k_planes, kv.k_s, kv.k_z,
            kv.v_planes, kv.v_s, kv.v_z, kv.length)
    if use_kernel:
        k_pact = (cache_planes_active(k_nlvl) if k_nlvl is not None
                  else None)
        v_pact = (cache_planes_active(v_nlvl) if v_nlvl is not None
                  else None)
        out = _pa.decode_attention(*args, k_pact, v_pact, window=window,
                                   softcap=softcap,
                                   interpret=not ops.on_tpu())
    else:
        out = _ref.decode_attention_ref(*args, window=window,
                                        softcap=softcap)
    return out.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# Offline block autotuning (ServeEngine(autotune=True) / launch --autotune)
# ---------------------------------------------------------------------------

def _time_call(fn, iters: int = 3) -> float:
    fn()                               # compile + warm
    t0 = time.perf_counter()
    r = None
    for _ in range(iters):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def tune_projection(m: int, p: dict, backend: str,
                    planes_active: int | None = None) -> None:
    """Measure-and-cache the best kernel parameters (blocks + DMA depth +
    grid order) for one projection artifact at decode row count ``m``.
    Strictly offline: call before ``warmup`` — ``serving_linear`` then
    picks the cached parameters up at trace time (``autotune.params_for``).
    Off-TPU the heuristic is recorded untimed (interpret-mode timings are
    emulator noise; see ``kernels.autotune``).

    ``planes_active`` keys a single-point tuning run where the live plane
    count is STATIC (a fixed deployment at one rung). The serving ladder
    leaves it None: one compiled kernel serves every rung (the shift is
    data), so its lookups key on the full plane count.
    """
    name, _ = parse_backend(backend)
    if name == "ref":
        return
    w_q = p["w_q"]
    assert w_q.ndim == 2, w_q.shape
    k, n = w_q.shape
    n_planes = (p["w_planes_pos"].shape[-3] if "w_planes_pos" in p
                else INT8_PLANES)
    key = jax.random.PRNGKey(0)
    xf = jax.random.normal(key, (m, k), jnp.float32)
    s, z, n_lvl = _act_scalars(xf, p)
    shift = p.get("plane_shift")
    if shift is not None:
        shift = jnp.asarray(shift, jnp.float32).reshape(())
    colsum = p.get("w_colsum")
    if colsum is None:
        wc = (masked_codes(w_q, shift) if shift is not None
              else w_q.astype(jnp.int32))
        colsum = jnp.sum(wc, axis=-2)
    zcol = z.astype(jnp.int32) * colsum
    gamma = p["w_scale"].astype(jnp.float32).reshape(-1)
    k_eff = p["w_planes_pos"].shape[-2] * 8 if name == "packed" else k

    def runner(params):
        if name == "packed":
            fn = lambda: _matmul_packed(
                xf, p["w_planes_pos"], p["w_planes_neg"], s, z, n_lvl,
                gamma, zcol, interpret=not ops.on_tpu(), shift=shift,
                params=params)
        else:
            fn = lambda: _matmul_fused(
                xf, w_q, s, z, n_lvl, gamma, zcol, n_planes,
                interpret=not ops.on_tpu(), shift=shift, params=params)
        return _time_call(fn)

    autotune.tune(m, k_eff, n, n_planes, name, runner,
                  active=planes_active)
