"""Pluggable backends for the serving matmul — the single choke point that
turns the PANN deployment artifact ({"w_q", "w_scale", ...}; built by
``models/serving.quantize_params_for_serving``) into projection outputs.

Backends (selected per engine via ``ModelConfig.kernel_backend`` and threaded
through ``models.layers.apply_linear``):

  ``ref``     plain-jnp integer dataflow — runs on any platform; the oracle.
  ``fused``   Pallas bit-plane matmul (``kernels/pann_matmul``, mode='fused'):
              bit-planes are rebuilt from the int8 codes at trace time and
              fed to one int8 MXU pass per tile.
  ``packed``  Pallas packed-plane matmul (``kernels/pann_matmul_packed``):
              reads the bit-packed ``w_planes_pos``/``w_planes_neg`` artifact
              leaves (8 codes/byte along K — 2*P/8 bytes/weight HBM for plane
              count P = the module's b_R), unpacking in VMEM.

Every backend realizes the SAME integer dataflow, so their fp32 outputs are
bit-identical (asserted in tests/test_kernel_dispatch.py, gated in CI by
``benchmarks/kernel_bench.py --check``):

  1. activations are affine-quantized to unsigned codes
     ``q = clip(round(x/s) + z, 0, n)`` with ``n = min(act_n, 127)`` — the
     zero point z absorbs signed transformer activations (DESIGN.md §4) and
     n is capped at the kernels' half-range int8 code space (App. A.4);
  2. ``y_int = q @ w_q - z * colsum(w_q) + round(b / (s*gamma))`` is
     computed exactly in int32 (MXU pass or jnp; the kernels fuse the
     combined zero-point/bias row ``zcol`` into the accumulator) — the
     per-output-channel correction keeps the MACs genuinely unsigned
     (Observation 1 / Eq. 5-6), and the bias lands on the output grid the
     way integer inference engines add it;
  3. ``y = y_int * s * gamma`` — two fp32 multiplies, identical
     association everywhere, and nothing downstream for XLA to
     fma-contract differently per backend.

Fallback policy (``resolve_backend``): 'fused'/'packed' degrade to 'ref' off
TPU, where the Pallas kernels would only be emulated. Appending ``:force`` (e.g.
"packed:force") runs the Pallas kernel anyway — interpret mode off-TPU;
slow, test/CI only, bit-identical by construction. Pad-to-block handling
lives HERE, not in callers: inputs are padded to tile multiples with zero
codes / zero planes (exact no-ops) and the result is sliced back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.pann import bitplane_decompose
from repro.kernels import ops
from repro.kernels import pann_matmul as _pm
from repro.kernels import pann_matmul_packed as _pk

Array = jax.Array

BACKENDS = ("ref", "fused", "packed")

# int8 serving codes are clipped to +-127 = 2^7 - 1, so 7 planes always
# reconstruct them exactly — the envelope used when no packed artifact
# pins the module's plane count.
INT8_PLANES = 7

# n = 2^7 - 1: the kernels' int8 lanes hold unsigned codes in [0, 127]
# (the paper's App.-A.4 half-range convention), so b~x >= 8 operating
# points run their activations at this ceiling inside the kernels.
HALF_RANGE_LEVELS = 127.0


def parse_backend(spec: str) -> tuple[str, bool]:
    """'fused' -> ('fused', False); 'packed:force' -> ('packed', True)."""
    name, _, opt = spec.partition(":")
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"have {BACKENDS}")
    if opt not in ("", "force"):
        raise ValueError(f"unknown backend option {opt!r} in {spec!r}; "
                         "only ':force' (run Pallas in interpret mode "
                         "off-TPU) is recognized")
    return name, opt == "force"


def resolve_backend(spec: str, p: dict) -> tuple[str, bool]:
    """(effective backend, interpret flag) for artifact ``p`` on this host.

    Non-TPU hosts without ':force' resolve to 'ref' (ragged shapes are not
    misfits — padding below absorbs them). A 'packed' request against a
    variant built without plane leaves is a build error, not a misfit —
    raised, never silently degraded.
    """
    name, force = parse_backend(spec)
    if name == "ref":
        return "ref", False
    if name == "packed" and "w_planes_pos" not in p:
        raise ValueError(
            "backend 'packed' needs the w_planes_pos/w_planes_neg artifact "
            "leaves; build the variant with "
            "quantize_params_for_serving(..., pack_planes=True)")
    if not ops.on_tpu() and not force:
        return "ref", False
    return name, not ops.on_tpu()


def _pick_bk(bk: int, mult: int) -> int:
    """Largest multiple of ``mult`` <= bk (floor at ``mult``)."""
    return max(mult, bk - bk % mult)


def _matmul_ref(q8: Array, w_q: Array, s, gamma: Array, zcol: Array
                ) -> Array:
    """jnp oracle of the kernels' finalize: exact int32 matmul, exact int32
    zero-point subtraction, then the identical fp32 multiply chain
    (y * s * gamma, in that association)."""
    y_int = jax.lax.dot_general(q8, w_q, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    return (y_int - zcol).astype(jnp.float32) * s * gamma


def _matmul_fused(q8: Array, w_q: Array, s, gamma: Array, zcol: Array,
                  n_planes: int, interpret: bool) -> Array:
    """Bit-plane Pallas kernel on planes rebuilt from the int8 codes."""
    pos = bitplane_decompose(jnp.maximum(w_q, 0), n_planes)
    neg = bitplane_decompose(jnp.maximum(-w_q.astype(jnp.int32), 0),
                             n_planes)
    m, k = q8.shape
    n = w_q.shape[-1]
    bm, bn, bk = ops._pick_blocks(m, n, k)
    xp = ops._pad_to(ops._pad_to(q8, bm, 0), bk, 1)
    pp = ops._pad_to(ops._pad_to(pos, bk, 1), bn, 2)
    pn = ops._pad_to(ops._pad_to(neg, bk, 1), bn, 2)
    sx = jnp.broadcast_to(jnp.reshape(s, (1, 1)), (xp.shape[0], 1))
    gp = ops._pad_to(gamma, bn, 0)
    zp = ops._pad_to(zcol, bn, 0)
    y = _pm.pann_matmul(xp, pp, pn, sx, gp, zp, mode="fused",
                        bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n]


def _matmul_packed(q8: Array, pp: Array, pn: Array, s, gamma: Array,
                   zcol: Array, interpret: bool) -> Array:
    """Packed-plane Pallas kernel on the uint8 artifact leaves."""
    m, k = q8.shape
    k_full = pp.shape[-2] * 8        # pack_planes padded K up to 8
    n = pp.shape[-1]
    bm, bn, bk = ops._pick_blocks(m, n, k_full)
    bk = _pick_bk(bk, 8)             # the packed kernel needs bk % 8 == 0
    xp = ops._pad_to(ops._pad_to(q8, bm, 0), bk, 1)
    k_pad = xp.shape[1]
    ppp = ops._pad_to(ops._pad_to(pp, k_pad // 8, 1), bn, 2)
    pnp = ops._pad_to(ops._pad_to(pn, k_pad // 8, 1), bn, 2)
    sx = jnp.broadcast_to(jnp.reshape(s, (1, 1)), (xp.shape[0], 1))
    gp = ops._pad_to(gamma, bn, 0)
    zp = ops._pad_to(zcol, bn, 0)
    y = _pk.pann_matmul_packed(xp, ppp, pnp, sx, gp, zp,
                               bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n]


def serving_linear(x: Array, p: dict, backend: str) -> Array:
    """The serving projection: y = affine-quant(x) @ deq(w_q) [+ b] through
    the selected backend. ``p`` is one module's serving artifact (2-D w_q —
    scan bodies slice stacked leaves before we ever see them).

    Output dtype follows x; the fp32 result is bit-identical across
    backends (module docstring). ``act_n`` (2^b~x - 1, a data leaf so
    ladder rungs share one compilation) sets the activation levels; absent,
    activations quantize at the 8-bit operating point's half-range.
    """
    name, interpret = resolve_backend(backend, p)
    w_q = p["w_q"]
    assert w_q.ndim == 2, (
        f"serving_linear wants a per-layer (K, N) weight slice, got "
        f"{w_q.shape} — scan bodies must slice stacked leaves first")
    lead, k = x.shape[:-1], x.shape[-1]
    n_out = w_q.shape[-1]

    # entry barrier: seal the backend-specific subgraph off from upstream
    # fusion/layout decisions, so the surrounding (graph-identical) program
    # compiles the same way whichever backend sits between the barriers —
    # the bit-exactness contract must survive jit, not just eager mode
    xf = jax.lax.optimization_barrier(x.reshape(-1, k).astype(jnp.float32))
    act_n = p.get("act_n")
    if act_n is None:
        n_lvl = jnp.float32(HALF_RANGE_LEVELS)
    else:
        n_lvl = jnp.minimum(
            jnp.asarray(act_n, jnp.float32).reshape(()), HALF_RANGE_LEVELS)
    # include_zero bounds z to [0, n]: without it, activations that do not
    # span zero produce |z| far outside int32 and the zcol correction wraps
    act_lo = p.get("act_lo")
    if act_lo is not None:
        # export-frozen EMA calibration (models/serving.
        # quantize_params_for_serving(calib=...)): quantize against the
        # static training-time range. affine_from_range applies the same
        # zero extension as the dynamic path below (z stays in [0, n]) and
        # is the SAME function the QAT forward and the legacy serving
        # branch use — one range convention everywhere. All backends share
        # this one quantizer, so their bit-exactness contract holds for
        # calibrated artifacts too.
        q, s, z = quant.affine_from_range(
            xf, n_lvl,
            jnp.asarray(act_lo, jnp.float32).reshape(()),
            jnp.asarray(p["act_hi"], jnp.float32).reshape(()))
    else:
        q, s, z = quant.affine_quant_levels(xf, n_lvl, include_zero=True)
    # seal the quantization chain as well: left open, XLA folds it into the
    # backend-specific consumer cluster (e.g. strength-reducing the x/s
    # divide differently next to a dot than next to a pallas call) and the
    # codes themselves stop matching across backends
    q8, s, z = jax.lax.optimization_barrier(
        (q.astype(jnp.int8), s, z))
    gamma = p["w_scale"].astype(jnp.float32).reshape(-1)
    # the zero-point correction as an EXACT int32 row: s(q - z) @ (gamma*w)
    # = s*gamma*(q @ w_q - z*colsum(w_q)). Subtracting inside the integer
    # accumulator (kernels take zcol; the jnp oracle mirrors it) keeps the
    # epilogue free of fp adds, which XLA would contract into backend-
    # dependent fmas — the backends' bit-exactness depends on this.
    # the artifact carries colsum precomputed (models/serving.py) so the
    # packed backend never has to stream the full int8 code tensor just for
    # this reduction; recomputing is the fallback for hand-built leaves
    colsum = p.get("w_colsum")
    if colsum is None:
        colsum = jnp.sum(w_q.astype(jnp.int32), axis=-2)
    zcol = z.astype(jnp.int32) * colsum
    if "b" in p:
        # bias joins the accumulator too, quantized onto the output grid
        # s*gamma — the standard integer-inference bias treatment
        # (gemmlowp/TFLite) and the only formulation whose rounding XLA
        # cannot re-associate differently per backend (an fp "+ b" after
        # the dequant multiplies gets fma-contracted next to a jnp dot but
        # not next to a pallas call). Clipped so zcol - b_q stays well
        # inside int32 whatever the scales are.
        b_q = jnp.clip(jnp.round(p["b"].astype(jnp.float32) / (s * gamma)),
                       -2.0 ** 30, 2.0 ** 30).astype(jnp.int32)
        zcol = zcol - b_q

    if name == "fused":
        n_planes = (p["w_planes_pos"].shape[-3] if "w_planes_pos" in p
                    else INT8_PLANES)
        y = _matmul_fused(q8, w_q, s, gamma, zcol, n_planes, interpret)
    elif name == "packed":
        y = _matmul_packed(q8, p["w_planes_pos"], p["w_planes_neg"],
                           s, gamma, zcol, interpret)
    else:
        y = _matmul_ref(q8, w_q, s, gamma, zcol)
    return y.reshape(*lead, n_out).astype(x.dtype)
