"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * pad ragged shapes up to MXU-aligned tile multiples (and slice back),
  * pick block shapes that keep the working set inside VMEM (~16 MiB),
  * fall back to interpret mode off-TPU (this container is CPU-only; the
    kernels are written for TPU and validated via interpret=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pann as pann_core
from repro.core import quant
from repro.core.unsigned import unsigned_split
from repro.kernels import pann_matmul as _pm
from repro.kernels import quantize_act as _qa
from repro.kernels import unsigned_matmul as _um

Array = jax.Array


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_blocks(m: int, n: int, k: int, *, vmem_budget: int = 8 * 2 ** 20
                 ) -> tuple[int, int, int]:
    """Simple VMEM-aware block choice: MXU-aligned, shrink k if needed."""
    bm = min(m, 128)
    bn = min(n, 128)
    bk = min(k, 512)
    # int8 tiles: x (bm*bk) + w (bk*bn) + acc f32 (bm*bn)*4
    while bk > 128 and (bm * bk + bk * bn + 4 * bm * bn) > vmem_budget:
        bk //= 2
    return bm, bn, bk


# ---------------------------------------------------------------------------
# quantize_act
# ---------------------------------------------------------------------------

def quantize_act(x: Array, bits: int = 8, interpret: bool | None = None
                 ) -> tuple[Array, Array]:
    """Per-row unsigned activation quantization. x: (..., K) -> int8 codes.

    Oracle/benchmark path only (see ``kernels.quantize_act``): serving
    quantizes activations inside the fused matmul prologue, never through
    this standalone pass."""
    interpret = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    bm = min(128, m) if m % 8 == 0 or m < 8 else 8
    x2p = _pad_to(x2, bm, 0)
    q, s = _qa.quantize_act(x2p, bits=bits, bm=bm, interpret=interpret)
    q = q[:m]
    s = s[:m]
    return q.reshape(*lead, -1), s.reshape(*lead, 1)


# ---------------------------------------------------------------------------
# unsigned_matmul
# ---------------------------------------------------------------------------

def unsigned_matmul(x_q: Array, w_q: Array, s_x: Array, s_w: Array,
                    interpret: bool | None = None) -> Array:
    """Sec.-4 split matmul on integer codes; pads to tile multiples."""
    interpret = (not on_tpu()) if interpret is None else interpret
    m, k = x_q.shape
    _, n = w_q.shape
    bm, bn, bk = _pick_blocks(m, n, k)
    xp = _pad_to(_pad_to(x_q, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    sxp = _pad_to(s_x, bm, 0)
    swp = _pad_to(s_w.reshape(-1), bn, 0)
    y = _um.unsigned_matmul(xp, wp, sxp, swp, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    return y[:m, :n]


# ---------------------------------------------------------------------------
# pann_matmul
# ---------------------------------------------------------------------------

def pann_pack_weights(w: Array, r: float, axis=0) -> dict:
    """Offline packing: PANN-quantize, unsigned-split, bit-plane decompose.

    Returns the deployment artifact consumed by ``pann_matmul``.
    """
    w_q, gamma = pann_core.pann_quantize(w, r, axis=axis)
    pos, neg = unsigned_split(w_q)
    n_planes = pann_core.weight_storage_bits(w_q)
    return {
        "planes_pos": pann_core.bitplane_decompose(pos, n_planes),
        "planes_neg": pann_core.bitplane_decompose(neg, n_planes),
        "gamma": gamma.reshape(-1),
        "n_planes": n_planes,
        "r": r,
    }


def pann_matmul(x: Array, packed: dict, act_bits: int = 8,
                mode: str = "fused", interpret: bool | None = None) -> Array:
    """End-to-end PANN linear through the FUSED act-quant prologue: the
    activations are affine-encoded inside ``pann_matmul_act`` against one
    per-tensor (s, z) — no standalone ``quantize_act`` pass, the fp32
    activations cross HBM once and the codes never do. The (s, z)
    derivation and the int32 ``zcol`` zero-point row are the exact
    ``kernels.dispatch`` serving conventions (``act_range_bounds`` with
    include_zero + ``affine_scale_zp``; levels capped at 127 so codes fit
    int8), so this path is the two-arg mirror of ``serving_linear`` and is
    held to the same jnp affine oracle in tests/test_kernels.py.

    x: (M, K) float; ``packed`` from ``pann_pack_weights``.
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    planes_pos, planes_neg = packed["planes_pos"], packed["planes_neg"]
    gamma = packed["gamma"]
    m, k = x.shape
    p, _, n = planes_pos.shape
    n_lvl = jnp.float32(quant.cap_levels(int(act_bits)))
    lo, hi = quant.act_range_bounds(x.astype(jnp.float32),
                                    include_zero=True)
    s, z = quant.affine_scale_zp(lo, hi, n_lvl)
    # zero-point correction row: z * colsum(w_q), with w_q reconstructed
    # from the signed plane split (pos - neg summed over plane weights)
    shifts = (jnp.int32(1) << jnp.arange(p, dtype=jnp.int32))
    w_q = jnp.sum((planes_pos.astype(jnp.int32)
                   - planes_neg.astype(jnp.int32))
                  * shifts[:, None, None], axis=0)
    zcol = z.astype(jnp.int32) * jnp.sum(w_q, axis=0)
    bm, bn, bk = _pick_blocks(m, n, k)
    xp = _pad_to(_pad_to(x.astype(jnp.float32), bm, 0), bk, 1)
    pp = _pad_to(_pad_to(planes_pos, bk, 1), bn, 2)
    pn = _pad_to(_pad_to(planes_neg, bk, 1), bn, 2)
    gp = _pad_to(gamma, bn, 0)
    zp = _pad_to(zcol, bn, 0)
    qparams = jnp.stack([s, z, n_lvl]).reshape(1, 3).astype(jnp.float32)
    y = _pm.pann_matmul_act(xp, pp, pn, qparams, gp, zp, mode=mode,
                            bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n]
