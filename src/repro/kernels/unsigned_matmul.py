"""Pallas TPU kernel: unsigned-split integer matmul (paper Sec. 4, Fig. 12b).

Takes signed int8 weight codes, splits them into W+ = max(W, 0) and
W- = max(-W, 0) *inside the kernel* (a VPU op, no extra HBM traffic), runs
two unsigned MXU accumulations, and applies the single Eq.-(6) subtraction
per output element, fused with dequantization.

y[m, n] = (x_q @ W+ - x_q @ W-)[m, n] * s_x[m] * s_w[n]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _unsigned_matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref,
                            acc_p, acc_n, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_p[...] = jnp.zeros_like(acc_p)
        acc_n[...] = jnp.zeros_like(acc_n)

    x = x_ref[...]
    w = w_ref[...]
    # contract: w codes are symmetric, in [-127, 127] (our quantizers never
    # emit -128), so both halves of the split fit int8
    w_pos = jnp.maximum(w, 0).astype(jnp.int8)
    w_neg = jnp.maximum(-w.astype(jnp.int32), 0).astype(jnp.int8)
    dims = (((1,), (0,)), ((), ()))
    acc_p[...] += jax.lax.dot_general(x, w_pos, dims,
                                      preferred_element_type=jnp.int32)
    acc_n[...] += jax.lax.dot_general(x, w_neg, dims,
                                      preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _finalize():
        y = (acc_p[...] - acc_n[...]).astype(jnp.float32)
        o_ref[...] = y * sx_ref[...] * sw_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def unsigned_matmul(x_q: Array, w_q: Array, s_x: Array, s_w: Array, *,
                    bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = True) -> Array:
    """x_q (M, K) int8 >= 0; w_q (K, N) int8 signed in [-127, 127];
    s_x (M, 1); s_w (N,)."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    k_steps = k // bk
    kernel = functools.partial(_unsigned_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, s_x, s_w.reshape(1, -1))
