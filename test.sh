#!/usr/bin/env bash
# Tier-1 test entry point. Runs the suite on the host CPU; multi-device
# tests fork their own subprocesses with a larger forced device count, so
# THIS process must keep the default (1 device) — do not raise it here.
set -euo pipefail
cd "$(dirname "$0")"

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=1}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

exec python -m pytest "$@"  # e.g.: bash test.sh tests/test_moe.py -x
