"""Shared test setup: fall back to the vendored hypothesis stub when the
real package is absent (nothing may be pip-installed in this container)."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install()
