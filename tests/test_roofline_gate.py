"""Off-TPU validation of the roofline CI gate (benchmarks/roofline.py).

The timing leg only runs on TPU, so the CPU CI legs would otherwise never
exercise the threshold decision itself. Here the gate's pass/fail logic is
driven with SYNTHETIC backend measurements (monkeypatched in place of the
TPU timings) so a broken floor comparison — or a nonsense GATE_THRESHOLDS
edit — fails immediately on CPU, not on the next TPU run.
"""
import math
import sys

import pytest

sys.path.insert(0, ".")  # for benchmarks.*

from benchmarks import roofline  # noqa: E402
from benchmarks.common import device_peaks  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402


def test_gate_thresholds_are_sane_floors():
    assert set(roofline.GATE_THRESHOLDS) == {"fused", "packed"}
    for backend, floor in roofline.GATE_THRESHOLDS.items():
        assert 0.0 < floor < 1.0, (backend, floor)
    # packed trades HBM bytes for VPU unpack work — its floor must sit
    # below fused's, or the docs/kernels.md rationale is stale
    assert roofline.GATE_THRESHOLDS["packed"] < roofline.GATE_THRESHOLDS["fused"]
    m, k, n = roofline.GATE_SHAPE
    assert m % 128 == 0 and k % 128 == 0 and n % 128 == 0


def test_analyze_record_synthetic_math():
    """The hand-checkable record from assert_invariants, verified term by
    term against the peaks table rather than just for finiteness."""
    pk = device_peaks("TPU v5e")
    rec = {
        "arch": "synthetic", "shape": "s", "mesh": "single", "n_devices": 4,
        "flops_per_device_corrected": 1e12,
        "bytes_per_device_corrected": 1e9,
        "collective_bytes_corrected": 1e8,
        "model_flops_global": 3e12,
    }
    a = roofline.analyze_record(rec)
    assert math.isclose(a["t_compute_s"], 1e12 / pk["peak_flops"])
    assert math.isclose(a["t_memory_s"], 1e9 / pk["hbm_bw"])
    assert math.isclose(a["t_collective_s"], 1e8 / pk["ici_bw"])
    assert a["dominant"] == "compute"
    assert math.isclose(a["useful_ratio"], 0.75)
    ideal = 3e12 / pk["peak_flops"] / 4
    assert math.isclose(a["roofline_fraction"], ideal / a["t_compute_s"])
    roofline.assert_invariants()  # and the bundled self-check still holds


def _synthetic_measurements(fractions):
    peaks = device_peaks()
    return {
        backend: {
            "us": 100.0,
            "achieved_int8_ops": frac * peaks["peak_int8"],
            "fraction_of_peak": frac,
        }
        for backend, frac in fractions.items()
    }


def test_gate_passes_on_synthetic_measurements_above_floor(monkeypatch):
    monkeypatch.setattr(kops, "on_tpu", lambda: True)
    meas = _synthetic_measurements(
        {b: f + 0.01 for b, f in roofline.GATE_THRESHOLDS.items()})
    monkeypatch.setattr(roofline, "_gate_measurements", lambda: meas)
    record = roofline.gate(check=True)
    assert record["failures"] == []
    assert record["measurements"] == meas


@pytest.mark.parametrize("breached", ["fused", "packed"])
def test_gate_fails_on_synthetic_measurement_below_floor(monkeypatch,
                                                         breached):
    monkeypatch.setattr(kops, "on_tpu", lambda: True)
    fractions = {b: f + 0.01 for b, f in roofline.GATE_THRESHOLDS.items()}
    fractions[breached] = roofline.GATE_THRESHOLDS[breached] - 0.01
    monkeypatch.setattr(roofline, "_gate_measurements",
                        lambda: _synthetic_measurements(fractions))
    with pytest.raises(SystemExit):
        roofline.gate(check=True)
    # without --check semantics the breach is recorded, not raised
    record = roofline.gate(check=False)
    assert len(record["failures"]) == 1 and breached in record["failures"][0]


def test_gate_off_tpu_skips_timing_but_asserts_invariants():
    record = roofline.gate(check=True)  # CPU container: must not raise
    assert "skipped" in record and record["failures"] == []
    assert record["thresholds"] == roofline.GATE_THRESHOLDS
