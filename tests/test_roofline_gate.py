"""Off-TPU validation of the roofline CI gate (benchmarks/roofline.py).

The timing leg only runs on TPU, so the CPU CI legs would otherwise never
exercise the threshold decision itself. Here the gate's pass/fail logic is
driven with SYNTHETIC backend measurements (monkeypatched in place of the
TPU timings) so a broken floor comparison — or a nonsense GATE_THRESHOLDS
edit — fails immediately on CPU, not on the next TPU run.
"""
import math
import sys

import pytest

sys.path.insert(0, ".")  # for benchmarks.*

from benchmarks import roofline  # noqa: E402
from benchmarks.common import device_peaks  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402


def test_gate_thresholds_are_sane_floors():
    assert set(roofline.GATE_THRESHOLDS) == {"fused", "packed"}
    for backend, floor in roofline.GATE_THRESHOLDS.items():
        assert 0.0 < floor < 1.0, (backend, floor)
    # packed trades HBM bytes for VPU unpack work — its floor must sit
    # below fused's, or the docs/kernels.md rationale is stale
    assert roofline.GATE_THRESHOLDS["packed"] < roofline.GATE_THRESHOLDS["fused"]
    m, k, n = roofline.GATE_SHAPE
    assert m % 128 == 0 and k % 128 == 0 and n % 128 == 0


def test_analyze_record_synthetic_math():
    """The hand-checkable record from assert_invariants, verified term by
    term against the peaks table rather than just for finiteness."""
    pk = device_peaks("TPU v5e")
    rec = {
        "arch": "synthetic", "shape": "s", "mesh": "single", "n_devices": 4,
        "flops_per_device_corrected": 1e12,
        "bytes_per_device_corrected": 1e9,
        "collective_bytes_corrected": 1e8,
        "model_flops_global": 3e12,
    }
    a = roofline.analyze_record(rec)
    assert math.isclose(a["t_compute_s"], 1e12 / pk["peak_flops"])
    assert math.isclose(a["t_memory_s"], 1e9 / pk["hbm_bw"])
    assert math.isclose(a["t_collective_s"], 1e8 / pk["ici_bw"])
    assert a["dominant"] == "compute"
    assert math.isclose(a["useful_ratio"], 0.75)
    ideal = 3e12 / pk["peak_flops"] / 4
    assert math.isclose(a["roofline_fraction"], ideal / a["t_compute_s"])
    roofline.assert_invariants()  # and the bundled self-check still holds


def _synthetic_measurements(fractions):
    peaks = device_peaks()
    return {
        backend: {
            "us": 100.0,
            "achieved_int8_ops": frac * peaks["peak_int8"],
            "fraction_of_peak": frac,
        }
        for backend, frac in fractions.items()
    }


def test_gate_passes_on_synthetic_measurements_above_floor(monkeypatch):
    monkeypatch.setattr(kops, "on_tpu", lambda: True)
    meas = _synthetic_measurements(
        {b: f + 0.01 for b, f in roofline.GATE_THRESHOLDS.items()})
    monkeypatch.setattr(roofline, "_gate_measurements", lambda: meas)
    record = roofline.gate(check=True)
    assert record["failures"] == []
    assert record["measurements"] == meas


@pytest.mark.parametrize("breached", ["fused", "packed"])
def test_gate_fails_on_synthetic_measurement_below_floor(monkeypatch,
                                                         breached):
    monkeypatch.setattr(kops, "on_tpu", lambda: True)
    fractions = {b: f + 0.01 for b, f in roofline.GATE_THRESHOLDS.items()}
    fractions[breached] = roofline.GATE_THRESHOLDS[breached] - 0.01
    monkeypatch.setattr(roofline, "_gate_measurements",
                        lambda: _synthetic_measurements(fractions))
    with pytest.raises(SystemExit):
        roofline.gate(check=True)
    # without --check semantics the breach is recorded, not raised
    record = roofline.gate(check=False)
    assert len(record["failures"]) == 1 and breached in record["failures"][0]


def test_gate_off_tpu_skips_timing_but_asserts_invariants():
    record = roofline.gate(check=True)  # CPU container: must not raise
    assert "skipped" in record and record["failures"] == []
    assert record["thresholds"] == roofline.GATE_THRESHOLDS


# ---------------------------------------------------------------------------
# $REPRO_ROOFLINE_FLOORS override (docs/kernels.md "Re-measuring the
# roofline floors")
# ---------------------------------------------------------------------------

def test_floors_default_without_env(monkeypatch):
    monkeypatch.delenv(roofline.FLOORS_ENV, raising=False)
    floors = roofline.gate_thresholds()
    assert floors == roofline.GATE_THRESHOLDS
    # a fresh dict, not the module constant — callers can't mutate defaults
    assert floors is not roofline.GATE_THRESHOLDS


def test_floors_env_override_merges_over_defaults(monkeypatch):
    monkeypatch.setenv(roofline.FLOORS_ENV, '{"fused": 0.25}')
    floors = roofline.gate_thresholds()
    assert floors["fused"] == 0.25
    assert floors["packed"] == roofline.GATE_THRESHOLDS["packed"]


@pytest.mark.parametrize("bad", [
    "not json",                      # invalid JSON
    "[0.2, 0.1]",                    # not an object
    '{"fused": 0.2, "nope": 0.1}',   # unknown backend
    '{"fused": 1.5}',                # floor outside (0, 1)
    '{"fused": 0.0}',                # zero disables the gate silently
    '{"fused": "0.2"}',              # string, not a number
    '{"fused": true}',               # bool is not a fraction
])
def test_floors_env_rejects_garbage_loudly(monkeypatch, bad):
    monkeypatch.setenv(roofline.FLOORS_ENV, bad)
    with pytest.raises(SystemExit) as ei:
        roofline.gate_thresholds()
    assert roofline.FLOORS_ENV in str(ei.value)


def test_gate_enforces_overridden_floor(monkeypatch):
    """A floor raised via the env var must actually tighten the gate: a
    measurement that clears the committed default but not the override
    fails."""
    monkeypatch.setattr(kops, "on_tpu", lambda: True)
    default = roofline.GATE_THRESHOLDS["fused"]
    monkeypatch.setenv(roofline.FLOORS_ENV,
                       '{"fused": %s}' % (default + 0.10))
    fractions = {b: f + 0.01 for b, f in roofline.GATE_THRESHOLDS.items()}
    monkeypatch.setattr(roofline, "_gate_measurements",
                        lambda: _synthetic_measurements(fractions))
    record = roofline.gate(check=False)
    assert record["floors_overridden_via"] == roofline.FLOORS_ENV
    assert record["thresholds"]["fused"] == pytest.approx(default + 0.10)
    assert len(record["failures"]) == 1 and "fused" in record["failures"][0]
    with pytest.raises(SystemExit):
        roofline.gate(check=True)
    # and a loosened floor lets a below-default measurement through
    monkeypatch.setenv(roofline.FLOORS_ENV, '{"packed": 0.01}')
    fractions = {b: f + 0.01 for b, f in roofline.GATE_THRESHOLDS.items()}
    fractions["packed"] = 0.02
    record = roofline.gate(check=True)
    assert record["failures"] == []
