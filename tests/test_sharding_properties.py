"""Property tests (hypothesis) on the sharding rules and power models —
the invariants the 512-chip dry-run relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import power as pw
from repro.dist import sharding as SH

# ---------------------------------------------------------------------------
# greedy_spec invariants
# ---------------------------------------------------------------------------

def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Mesh-shaped stand-in exposing .shape/.axis_names like a real Mesh
    (class bodies can't close over function locals, so use type())."""
    return type("FakeMesh", (), {
        "axis_names": axes,
        "size": int(np.prod(shape)),
        "shape": dict(zip(axes, shape)),
    })


@given(st.lists(st.sampled_from([1, 2, 3, 8, 16, 24, 32, 128, 522, 4096,
                                 32768]), min_size=1, max_size=5))
@settings(max_examples=100, deadline=None)
def test_greedy_spec_divisibility_and_uniqueness(dims):
    mesh = _fake_mesh()
    spec = SH.greedy_spec(tuple(dims), mesh)
    used = []
    for dim, assignment in zip(dims, spec):
        if assignment is None:
            continue
        names = assignment if isinstance(assignment, tuple) else (assignment,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        assert dim % size == 0, (dims, spec)
        used.extend(names)
    assert len(used) == len(set(used)), f"axis reused: {spec}"


def test_greedy_spec_prefers_batch():
    mesh = _fake_mesh()
    spec = SH.greedy_spec((128, 32768, 8, 128), mesh)
    assert spec[0] == ("data",) or spec[0] == "data"


def test_cache_specs_never_shard_group_stack():
    mesh = _fake_mesh()
    tree = {"groups": (jax.ShapeDtypeStruct((32, 128, 1024, 8, 128),
                                            jnp.bfloat16),),
            "tail": [jax.ShapeDtypeStruct((128, 1024, 8, 128),
                                          jnp.bfloat16)]}
    specs = SH.cache_specs(tree, mesh)
    assert specs["groups"][0][0] is None          # stack dim unsharded
    assert specs["tail"][0][0] is not None        # batch still sharded


# ---------------------------------------------------------------------------
# param rule invariants
# ---------------------------------------------------------------------------

def test_param_specs_col_row_duality():
    from repro import configs
    from repro.models import model as MD
    from repro.configs.base import ParallelConfig
    cfg = configs.get_config("llama3-8b")
    shapes = jax.eval_shape(lambda k: MD.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = _fake_mesh()
    specs = SH.param_specs(shapes, mesh, ParallelConfig(fsdp=True))
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]

    def find(substr):
        out = []
        for path, s in flat:
            key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                           for k in path)
            if substr in key:
                out.append((key, s))
        return out

    for key, s in find("wq/w"):
        assert s[-1] == "model", (key, s)          # column-parallel
    for key, s in find("wo/w"):
        assert s[-2] == "model", (key, s)          # row-parallel
    for key, s in find("w_down/w"):
        assert s[-2] == "model", (key, s)
    for key, s in find("norm1"):
        assert all(x is None for x in s), (key, s)  # norms replicated
    # every sharded dim divides the axis size
    shape_flat = {"/".join(str(getattr(k, "key", getattr(k, "name", k)))
                           for k in path): l.shape
                  for path, l in
                  jax.tree_util.tree_flatten_with_path(shapes)[0]}
    for path, s in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        for dim, a in zip(shape_flat[key], tuple(s) + (None,) * 8):
            if a is not None:
                size = mesh.shape[a] if isinstance(a, str) else \
                    int(np.prod([mesh.shape[x] for x in a]))
                assert dim % size == 0, (key, s, shape_flat[key])


# ---------------------------------------------------------------------------
# power model properties
# ---------------------------------------------------------------------------

@given(st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_unsigned_never_worse_than_signed(b):
    assert pw.p_mac_unsigned(b) <= pw.p_mac_signed(b, 32)


@given(st.integers(2, 12), st.integers(2, 12))
@settings(max_examples=50, deadline=None)
def test_mixed_width_bounded_by_square(b_w, b_x):
    m = max(b_w, b_x)
    assert pw.p_mult_mixed(b_w, b_x) <= pw.p_mult_signed(m) + 1e-9
    assert pw.p_mult_mixed(m, m) == pytest.approx(pw.p_mult_signed(m))


@given(st.floats(0.25, 16.0), st.integers(2, 8))
@settings(max_examples=50, deadline=None)
def test_pann_power_monotone_in_r_and_bits(r, b):
    assert pw.p_pann(r + 0.5, b) > pw.p_pann(r, b)
    assert pw.p_pann(r, b + 1) > pw.p_pann(r, b)


@given(st.floats(6.0, 200.0))
@settings(max_examples=50, deadline=None)
def test_budget_inversion_roundtrip(p):
    for b in range(2, 9):
        r = pw.pann_r_for_budget(p, b)
        if r > 0:
            assert pw.p_pann(r, b) == pytest.approx(p, rel=1e-9)
