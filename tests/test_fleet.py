"""repro.serve_engine.fleet: multi-host serving under a global power cap.

The two disruption paths ISSUE'd for this subsystem, both held to the
bit-exactness bar:

  * a decode-host kill mid-decode — the restarted host re-maps the SAME
    mmap artifact and the replayed lanes resume bit-identically (and, at a
    generous cap, the whole run serves the exact tokens of a kill-free
    fleet);
  * a mid-run step of the GLOBAL Gbit-flips/sec cap — the governor drops
    its rung ceiling, in-flight lanes switch rungs mid-stream, and every
    segment still replays bit-identically on one uninterrupted engine.

Replays are verified wave-granular (``verify_streams``): activation quant
scales are per-tensor over the batch, so bit-comparison requires the same
batch composition — which is exactly what fleet restarts/switches preserve.
"""
import dataclasses

import jax
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import model as MD
from repro.serve_engine import ServeEngine
from repro.serve_engine import artifact as afct
from repro.serve_engine.fleet import (Fleet, FleetConfig, TrafficSpec,
                                      make_trace, verify_streams)

LADDER = (2, 4, 6)
MAX_LEN = 20


def _fc(**kw):
    base = dict(n_decode_hosts=2, n_prefill_hosts=1, ladder_bits=LADDER,
                cap_gbitflips_per_s=50.0, control_interval=3,
                max_batch=2, max_len=MAX_LEN, drain_tick_factor=16)
    base.update(kw)
    return FleetConfig(**base)


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    art = str(tmp_path_factory.mktemp("fleet_artifact"))
    # the first fleet quantizes once and writes the mmap artifact; every
    # later Fleet/engine in this module maps that same file (params=None)
    Fleet(cfg, _fc(), art, params=params)
    return cfg, art


@pytest.fixture(scope="module")
def ref_engine(setup):
    cfg, art = setup
    eng = ServeEngine(cfg, weight_store=afct.load_artifact(art),
                      ladder_bits=LADDER, max_batch=2, max_len=MAX_LEN)
    eng.warmup()
    return eng


def _spec(**kw):
    base = dict(seed=3, n_ticks=6, burst_prob=0.7, mean_burst=2.0,
                prompt_lens=(6,), gen_tokens=(6, 10),
                budget_mix=(2, 4, 6, 6), slo_prob=0.0)
    base.update(kw)
    return TrafficSpec(**base)


def _tokens_by_uid(report):
    return {s["uid"]: [t for seg in s["segments"]
                       for t in seg["tokens"]][:s["max_new_tokens"]]
            for s in report["streams"]}


def test_host_kill_mid_decode_resumes_bit_identically(setup, ref_engine):
    cfg, art = setup
    spec = _spec(host_kills=((2, 1),))
    killed = Fleet(cfg, _fc(), art)
    report = killed.run(make_trace(spec, cfg.vocab_size, killed.ladder))
    killed.assert_no_recompile()       # includes the restarted host

    assert report["host_restarts"] >= 1
    # the kill landed mid-decode: some stream was detached and replayed
    assert any(s["restarts"] >= 1 for s in report["streams"])
    # the reborn host re-maps the same artifact: every wave (disrupted or
    # not) equals one uninterrupted engine, token for token
    assert verify_streams(report, ref_engine) == []

    # and the end-to-end responses are EXACTLY a kill-free fleet's: at this
    # generous cap the kill may cost replay flips but never changes tokens
    calm = Fleet(cfg, _fc(), art)
    calm_report = calm.run(
        make_trace(_spec(), cfg.vocab_size, calm.ladder))
    assert calm_report["host_restarts"] == 0
    assert _tokens_by_uid(report) == _tokens_by_uid(calm_report)


def test_mid_run_global_budget_step_bit_exact(setup, ref_engine):
    cfg, art = setup
    spec = _spec(seed=5, n_ticks=12, budget_steps=((5, 0.03),))
    fleet = Fleet(cfg, _fc(cap_gbitflips_per_s=0.25), art)
    report = fleet.run(make_trace(spec, cfg.vocab_size, fleet.ladder))
    fleet.assert_no_recompile()        # ONE compiled step across replans

    # the cap step dropped the governor's rung ceiling...
    assert any(pt["ceiling_bits"] < max(LADDER)
               for pt in report["per_tick"])
    # ...and forced at least one in-flight lane down the ladder mid-stream
    assert any(s["switches"] >= 1 for s in report["streams"])
    # the per-tick grant is structural: the step never overspends a tick
    assert report["cap_violations"] == 0
    # bit-exact mid-stream switching: every segment (pre- and post-switch)
    # replays identically on one engine following the same rung schedule
    assert verify_streams(report, ref_engine) == []


def test_fleet_report_accounting(setup, ref_engine):
    """Realized flips come from ledgers, not the plan: decode + prefill
    ledger aggregates must add up to the reported fleet total."""
    cfg, art = setup
    fleet = Fleet(cfg, _fc(), art)
    report = fleet.run(make_trace(_spec(seed=9, n_ticks=4),
                                  cfg.vocab_size, fleet.ladder))
    assert report["served"] == report["requests"]
    total = report["decode_gbitflips"] + report["prefill_gbitflips"]
    assert report["realized_gbitflips"] == pytest.approx(total)
    assert report["realized_gbitflips"] > 0
    # ledgers charge each request exactly its quota; the histogram is
    # lane-aligned (a short row rides its wave to the wave's gen_max), so
    # it can only overcount, never undercount
    assert report["decode_tokens"] == sum(s["max_new_tokens"]
                                          for s in report["streams"])
    hist = report["rung_token_histogram"]
    assert sum(hist.values()) >= report["decode_tokens"]
    assert verify_streams(report, ref_engine) == []
