"""Per-module QuantPolicy trees and the layer-wise power-budget allocator.

Covers the PR's acceptance criteria:
  * allocator invariants (property-tested): total power <= budget, within
    1% of the matched uniform plan, theory score never worse than uniform;
  * `uniform_policy(qc)` forwards are bit-exact with the pre-policy path;
  * the layerwise serving ladder runs end to end through ONE jitted decode
    step with per-rung power parity and score dominance over uniform.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import costs, planner
from repro.core import policy as pol
from repro.core import power as pw
from repro.models import model as MD
from repro.models.serving import quantize_params_for_serving
from repro.serve_engine import Request, ServeEngine


# ---------------------------------------------------------------------------
# PolicyTree semantics
# ---------------------------------------------------------------------------

def test_policy_tree_lookup_prefix_and_default():
    base = pol.ModuleQuant(mode="pann", r=2.0, b_x_tilde=4)
    fine = pol.ModuleQuant(mode="pann", r=8.0, b_x_tilde=6)
    coarse = pol.ModuleQuant(mode="pann", r=1.0, b_x_tilde=3)
    tree = pol.policy_tree(base, {"attn.wq": fine, "mlp": coarse})
    assert tree.lookup("attn.wq") is fine          # exact
    assert tree.lookup("mlp.w_down") is coarse     # dotted prefix
    assert tree.lookup("mlp.w_up") is coarse
    assert tree.lookup("attn.wk") is base          # no match -> default
    assert tree.lookup("lm_head") is base


def test_module_quant_aliases_match_quant_config():
    qc = QuantConfig(mode="ruq", weight_bits=5, act_bits=6, r=3.0,
                     act_bits_tilde=7, acc_bits=24)
    mq = pol.as_module_quant(qc)
    assert (mq.weight_bits, mq.act_bits, mq.act_bits_tilde) == (5, 6, 7)
    assert (mq.b_w, mq.b_x, mq.b_x_tilde) == (5, 6, 7)
    assert mq.acc_bits == 24 and mq.r == 3.0 and mq.mode == "ruq"


def test_serving_path_mapping():
    assert pol.serving_path(("decoder", "groups", "layers", "attn",
                             "wq")) == "attn.wq"
    assert pol.serving_path(("xattn", "wk")) == "attn.wk"
    assert pol.serving_path(("shared_attn", "mlp", "w_up")) == "mlp.w_up"
    assert pol.serving_path(("tail", "tm", "decay_b")) == "rwkv.tm.decay_b"
    assert pol.serving_path(("cm", "wv")) == "rwkv.cm.wv"
    assert pol.serving_path(("ssm", "in_proj")) == "ssm.in_proj"
    assert pol.serving_path(("lm_head",)) == "lm_head"


# ---------------------------------------------------------------------------
# uniform_policy(qc) is bit-exact with the pre-policy path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b", "rwkv6-1.6b",
                                  "zamba2-1.2b"])
@pytest.mark.parametrize("mode", ["ruq", "pann"])
def test_uniform_policy_bit_exact(arch, mode):
    qc = QuantConfig(mode=mode, weight_bits=8, act_bits=8, r=4.0,
                     act_bits_tilde=8)
    cfg = dataclasses.replace(configs.reduced(configs.get_config(arch)),
                              quant=qc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    plain = MD.forward(params, cfg, tokens, remat=False).logits
    lifted = MD.forward(params, dataclasses.replace(
        cfg, policy=pol.uniform_policy(qc)), tokens, remat=False).logits
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(lifted))


# ---------------------------------------------------------------------------
# Allocator invariants (property test, hypothesis / vendored stub)
# ---------------------------------------------------------------------------

_PROFILES = {arch: costs.module_cost_profile(configs.get_config(arch))
             for arch in ("llama3-8b", "mixtral-8x7b", "rwkv6-1.6b",
                          "zamba2-1.2b", "seamless-m4t-medium")}


@settings(max_examples=40)
@given(st.floats(min_value=pw.p_mac_unsigned(2),
                 max_value=pw.p_mac_unsigned(8)),
       st.sampled_from(sorted(_PROFILES)))
def test_allocator_invariants(power_budget, arch):
    """For ANY budget and architecture: the layerwise plan's total network
    power never exceeds the budget, lands within 1% of the matched uniform
    plan's total (they are equal to float precision by the R-fill), and its
    theory score never trails the uniform tree's."""
    profile = _PROFILES[arch]
    lw = planner.allocate_layerwise(power_budget, profile)
    budget_total = power_budget * lw.total_macs
    assert lw.total_power <= budget_total * (1 + 1e-9)
    assert abs(lw.total_power - budget_total) <= 0.01 * budget_total
    assert lw.score >= lw.uniform_score - 1e-12
    # the recomputed scores agree with the plan's record
    assert pol.tree_theory_score(profile, lw.tree) == \
        pytest.approx(lw.score)
    assert pol.tree_theory_score(profile, lw.uniform_tree) == \
        pytest.approx(lw.uniform_score)


def test_allocator_beats_uniform_on_heterogeneous_fanins():
    """Real architectures have heterogeneous fan-ins, so the greedy spend
    should deliver a STRICT score improvement (not just the guarantee)."""
    for arch in ("llama3-8b", "rwkv6-1.6b", "zamba2-1.2b"):
        lw = planner.allocate_layerwise(planner.budget_from_bits(4),
                                        _PROFILES[arch])
        assert lw.score > lw.uniform_score, arch


def test_allocator_raises_below_floor_and_on_empty_profile():
    with pytest.raises(ValueError, match="too small|below the cheapest"):
        planner.allocate_layerwise(1.0, _PROFILES["llama3-8b"])
    with pytest.raises(ValueError, match="empty"):
        planner.allocate_layerwise(24.0, ())


def test_allocator_eval_backend_mirrors_plan_with_eval():
    """eval_fn(tree) scores both candidate trees; a judge that prefers the
    uniform tree must make the allocator return it (same contract as
    Algorithm 1's eval backend: measurements outrank theory)."""
    profile = _PROFILES["llama3-8b"]
    uni = planner.allocate_layerwise(24.0, profile).uniform_tree

    def prefers_uniform(tree):
        return 1.0 if tree == uni else 0.0

    lw = planner.allocate_layerwise(24.0, profile,
                                    eval_fn=prefers_uniform)
    assert lw.tree == uni and lw.score == 1.0


def test_plan_ladder_layerwise_axis():
    profile = _PROFILES["llama3-8b"]
    plans = planner.plan_ladder((2, 4, 6), allocation="layerwise",
                                profile=profile)
    assert [p.power_budget for p in plans] == \
        [planner.budget_from_bits(b) for b in (2, 4, 6)]
    assert all(isinstance(p, planner.LayerwisePlan) for p in plans)
    with pytest.raises(ValueError, match="profile"):
        planner.plan_ladder((2, 4), allocation="layerwise")
    with pytest.raises(ValueError, match="allocation"):
        planner.plan_ladder((2, 4), allocation="magic")
    # the per-(b~x, R) eval backend cannot score a tree: rejected loudly,
    # never silently dropped (build_ladder relies on this too)
    with pytest.raises(ValueError, match="allocate_layerwise"):
        planner.plan_ladder((2, 4), eval_fn=lambda b, r: 1.0,
                            allocation="layerwise", profile=profile)


def test_launch_serve_rejects_allocation_without_ladder():
    from repro.launch import serve as serve_launch
    with pytest.raises(SystemExit, match="power_ladder"):
        serve_launch.main(["--arch", "llama3-8b", "--reduced",
                           "--allocation", "layerwise", "--gen", "2",
                           "--prompt_len", "2", "--batch", "1"])


# ---------------------------------------------------------------------------
# Eq. 20 accumulator widths flow into the profile and the trees
# ---------------------------------------------------------------------------

def test_module_costs_use_eq20_acc_bits():
    """core/costs.py sizes accumulators by Eq. 20 per layer — not the
    global 32-bit default — wherever the fan-in permits."""
    profile = costs.module_cost_profile(configs.get_config("llama3-8b"))
    for m in profile:
        want = min(pw.DEFAULT_ACC_BITS,
                   pw.required_acc_bits(8, 8, m.fan_in))
        assert m.acc_bits(8, 8) == want
        # llama3 fan-ins (4096 / 14336) all permit narrower-than-32
        assert m.acc_bits(8, 8) < pw.DEFAULT_ACC_BITS
    # huge synthetic fan-in caps at the hardware default
    wide = costs.ModuleCost(path="x", macs=1.0, fan_in=1 << 40)
    assert wide.acc_bits(16, 16) == pw.DEFAULT_ACC_BITS


def test_allocator_trees_carry_eq20_acc_bits():
    profile = _PROFILES["rwkv6-1.6b"]
    lw = planner.allocate_layerwise(planner.budget_from_bits(4), profile)
    for m in profile:
        mq = lw.tree.lookup(m.path)
        want = min(pw.DEFAULT_ACC_BITS,
                   pw.required_acc_bits(mq.b_x_tilde, mq.b_w, m.fan_in))
        assert mq.acc_bits == want
    # the 64-fan-in decay_b head needs a much narrower accumulator than
    # the 7168-fan-in channel-mix down-projection
    narrow = lw.tree.lookup("rwkv.tm.decay_b").acc_bits
    wide = lw.tree.lookup("rwkv.cm.wv").acc_bits
    assert narrow < wide


# ---------------------------------------------------------------------------
# Layerwise serving ladder, end to end
# ---------------------------------------------------------------------------

LADDER_BITS = (2, 4, 6)


@pytest.fixture(scope="module")
def lw_engine():
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, ladder_bits=LADDER_BITS, max_batch=2,
                      max_len=24, allocation="layerwise")
    eng.warmup()
    return eng


def test_layerwise_rungs_match_uniform_power_and_dominate_score(lw_engine):
    """Each layerwise rung spends the same total bit-flip budget as its
    uniform twin (within 1%) and never scores below it."""
    profile = lw_engine.profile
    total_macs = sum(m.macs for m in profile)
    for op in lw_engine.ladder:
        assert op.allocation == "layerwise" and op.tree is not None
        lw_total, _ = pol.tree_power_per_token(profile, op.tree)
        uni_total = op.power * total_macs
        assert abs(lw_total - uni_total) <= 0.01 * uni_total
        assert pol.tree_theory_score(profile, op.tree) >= \
            pol.tree_theory_score(profile, op.lw.uniform_tree) - 1e-12


def test_layerwise_ladder_one_compilation(lw_engine):
    """All layerwise rungs share ONE compiled decode step, and serving
    mixed-budget traffic across them never retraces."""
    assert lw_engine.compilations_after_warmup == 1
    prompt = np.random.default_rng(0).integers(0, 512, 8).astype(np.int32)
    reqs = [Request(uid=i, prompt=prompt, max_new_tokens=4,
                    power_budget_bits=b) for i, b in enumerate(LADDER_BITS)]
    resps = lw_engine.generate(reqs)
    lw_engine.assert_no_recompile()
    assert [r.rung_bits for r in resps] == list(LADDER_BITS)
    for r in resps:
        assert r.metadata["allocation"] == "layerwise"
        share = r.metadata["per_module_share"]
        assert share and sum(share.values()) == pytest.approx(1.0, abs=0.01)
        # headline number equals the itemized breakdown for layerwise rungs
        per_mod = r.metadata["per_module_gbitflips_per_token"]
        assert sum(per_mod.values()) * 1e9 == \
            pytest.approx(r.metadata["est_bitflips_per_token"], rel=1e-6)
    # energy still orders with the rung
    per_tok = {r.rung_bits: r.metadata["est_bitflips_per_token"]
               for r in resps}
    assert per_tok[2] < per_tok[4] < per_tok[6]


def test_layerwise_variant_structure_matches_uniform(lw_engine):
    """A layerwise variant has the SAME pytree structure and avals as a
    uniform one — why one jit compilation covers both allocations — while
    its act_n leaves actually differ per module."""
    cfg = lw_engine.cfg
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    op = lw_engine.ladder[-1]
    v_lw = quantize_params_for_serving(params, cfg, policy=op.tree)
    v_uni = quantize_params_for_serving(params, cfg, r=op.r,
                                        act_bits=op.b_x_tilde)
    assert jax.tree_util.tree_structure(v_lw) == \
        jax.tree_util.tree_structure(v_uni)
    for a, b in zip(jax.tree_util.tree_leaves(v_lw),
                    jax.tree_util.tree_leaves(v_uni)):
        assert a.shape == b.shape and a.dtype == b.dtype

    # the tree genuinely differentiates modules at this rung (distinct R)
    assert len({round(mq.r, 4) for _, mq in op.tree.items()}) > 1


def _act_ns(tree):
    vals = set()
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if getattr(path[-1], "key", "") == "act_n":
            vals.update(np.asarray(leaf).reshape(-1).tolist())
    return vals


def test_layerwise_variant_mixes_act_bits_per_module():
    """Where the allocator assigns different b~x per module (zamba2's
    heterogeneous fan-ins even when reduced), the serving artifact carries
    per-module act_n values — as DATA, so the one-jit invariant holds."""
    cfg = configs.reduced(configs.get_config("zamba2-1.2b"))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    profile = costs.module_cost_profile(cfg)
    lw = planner.allocate_layerwise(planner.budget_from_bits(2), profile)
    assert len({mq.b_x_tilde for _, mq in lw.tree.items()}) > 1
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    v_lw = quantize_params_for_serving(params, cfg, policy=lw.tree)
    v_uni = quantize_params_for_serving(params, cfg, r=2.0, act_bits=4)
    assert len(_act_ns(v_lw)) > 1
    assert len(_act_ns(v_uni)) == 1
    assert jax.tree_util.tree_structure(v_lw) == \
        jax.tree_util.tree_structure(v_uni)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-1.2b"])
def test_layerwise_serving_recurrent_families(arch):
    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, ladder_bits=(2, 6), max_batch=2,
                      max_len=12, allocation="layerwise")
    eng.warmup()
    prompt = np.random.default_rng(1).integers(0, 512, 6).astype(np.int32)
    resps = eng.generate([Request(uid=i, prompt=prompt, max_new_tokens=4,
                                  power_budget_bits=b)
                          for i, b in enumerate((2, 6))])
    assert [r.rung_bits for r in resps] == [2, 6]
    eng.assert_no_recompile()


def test_launch_serve_layerwise_cli():
    """The acceptance-criterion entry point: --power_ladder --allocation
    layerwise serves every rung in one process (assert_no_recompile runs
    inside serve_ladder)."""
    from repro.launch import serve as serve_launch
    out = serve_launch.main([
        "--arch", "llama3-8b", "--reduced", "--power_ladder", "2,4",
        "--allocation", "layerwise", "--budgets", "2,4", "--batch", "2",
        "--prompt_len", "4", "--gen", "4"])
    assert out["engine"]["allocation"] == "layerwise"
    assert out["engine"]["compilations_after_warmup"] == 1
    assert {r["rung_bits"] for r in out["requests"]} == {2, 4}
    for r in out["requests"]:
        assert r["allocation"] == "layerwise"
        assert r["per_module_share"]
