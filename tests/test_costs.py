"""core/costs.py: analytic parameter counts vs actual initialized trees."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import costs
from repro.models import model as MD


def _actual_params(cfg) -> int:
    params = jax.eval_shape(
        lambda k: MD.init_params(k, cfg), jax.random.PRNGKey(0))
    return sum(int(jnp.prod(jnp.asarray(l.shape)))
               for l in jax.tree_util.tree_leaves(params))


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b", "gemma2-9b",
                                  "zamba2-1.2b", "rwkv6-1.6b",
                                  "seamless-m4t-medium",
                                  "llama-3.2-vision-90b"])
def test_param_count_matches_init(arch):
    cfg = configs.reduced(configs.get_config(arch))
    analytic = costs.param_count(cfg)
    actual = _actual_params(cfg)
    # analytic ignores norms / tiny vectors; must be within 5%
    assert analytic == pytest.approx(actual, rel=0.05), (analytic, actual)


def test_known_full_sizes():
    """Full configs land near their nameplate sizes."""
    cases = {
        "llama3-8b": (8.0e9, 0.1),
        "mixtral-8x7b": (46.7e9, 0.1),
        "dbrx-132b": (132e9, 0.12),
        "llama-3.2-vision-90b": (90e9, 0.25),  # includes cross-attn layers
        "rwkv6-1.6b": (1.6e9, 0.25),
        "zamba2-1.2b": (1.2e9, 0.35),
    }
    for arch, (want, tol) in cases.items():
        got = costs.param_count(configs.get_config(arch))
        assert got == pytest.approx(want, rel=tol), (arch, got)


def test_active_params_moe():
    dbrx = configs.get_config("dbrx-132b")
    total = costs.param_count(dbrx)
    active = costs.param_count(dbrx, active_only=True)
    assert active < 0.4 * total
    # dbrx-base quotes 36B active
    assert active == pytest.approx(36e9, rel=0.15)


def test_model_flops_conventions():
    cfg = configs.get_config("llama3-8b")
    train = costs.model_flops(cfg, configs.SHAPES_BY_NAME["train_4k"])
    prefill = costs.model_flops(cfg, configs.SHAPES_BY_NAME["prefill_32k"])
    decode = costs.model_flops(cfg, configs.SHAPES_BY_NAME["decode_32k"])
    n = costs.param_count(cfg)
    assert train == pytest.approx(6 * n * 4096 * 256)
    assert prefill == pytest.approx(2 * n * 32768 * 32)
    assert decode == pytest.approx(2 * n * 128)


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b", "gemma2-9b",
                                  "zamba2-1.2b", "rwkv6-1.6b",
                                  "seamless-m4t-medium",
                                  "llama-3.2-vision-90b", "dbrx-132b"])
def test_module_cost_profile_sums_to_weight_macs(arch):
    """The per-module profile is the same account as macs_per_token's
    weight side, just itemized — totals must agree to float precision."""
    cfg = configs.get_config(arch)
    profile = costs.module_cost_profile(cfg)
    total = sum(m.macs for m in profile)
    assert total == pytest.approx(
        costs.macs_per_token(cfg).weight_macs, rel=1e-9)
    assert all(m.macs > 0 and m.fan_in >= 1 for m in profile)
    # paths stay within the canonical vocabulary (core/policy.py)
    roots = {m.path.split(".")[0] for m in profile}
    assert roots <= {"attn", "mlp", "moe", "ssm", "rwkv", "lm_head", "conv"}


def test_macs_split_weight_vs_act():
    cfg = configs.get_config("llama3-8b")
    m = costs.macs_per_token(cfg, context_len=4096)
    assert m.weight_macs > 0 and m.act_macs > 0
    # attention act-MACs at 4k ctx: 2 * H * hd * ctx * L
    want = 2.0 * 32 * 128 * 4096 * 32
    assert m.act_macs == pytest.approx(want)
    # rwkv is attention-free -> no act MACs counted
    r = costs.macs_per_token(configs.get_config("rwkv6-1.6b"), 4096)
    assert r.act_macs == 0
