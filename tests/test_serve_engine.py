"""repro.serve_engine: ladder planning, variant cache, per-request rung
selection, mid-stream rung switching, and the no-recompilation claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import planner
from repro.models import model as MD
from repro.models.serving import (build_variant_cache,
                                  quantize_params_for_serving)
from repro.serve_engine import (Request, Scheduler, ServeEngine, build_ladder,
                                select_rung)

LADDER_BITS = (2, 4, 6)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, ladder_bits=LADDER_BITS, max_batch=2,
                      max_len=28)
    eng.warmup()
    return eng


def _prompt(seed=0, n=8, vocab=512):
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


# ---------------------------------------------------------------------------
# Ladder planning
# ---------------------------------------------------------------------------

def test_ladder_planning_deterministic():
    a = build_ladder(LADDER_BITS, d=64.0)
    b = build_ladder(list(reversed(LADDER_BITS)), d=64.0)
    assert a == b                      # pure function of (bits, d), any order
    plans = planner.plan_ladder(LADDER_BITS, d=64.0)
    assert [p.power_budget for p in plans] == sorted(p.power_budget
                                                     for p in plans)
    for op, plan in zip(a, plans):
        assert (op.b_x_tilde, op.r) == (plan.b_x_tilde, plan.r)


def test_ladder_matches_equal_power_budget():
    for op in build_ladder(LADDER_BITS, d=64.0):
        assert op.power == planner.budget_from_bits(op.bits)
        # the planned point sits on the rung's equal-power curve (Fig. 3)
        curve = dict(planner.equal_power_curve(op.bits))
        assert op.b_x_tilde in curve
        assert curve[op.b_x_tilde] == pytest.approx(op.r)


# ---------------------------------------------------------------------------
# Variant cache
# ---------------------------------------------------------------------------

def test_variant_cache_bit_exact(setup):
    cfg, params = setup
    ladder = build_ladder(LADDER_BITS, d=float(cfg.d_model))
    cache = build_variant_cache(params, cfg,
                                {op.bits: (op.r, op.b_x_tilde)
                                 for op in ladder})
    assert sorted(cache) == sorted(op.bits for op in ladder)
    for op in ladder:
        direct = quantize_params_for_serving(params, cfg, r=op.r,
                                             act_bits=op.b_x_tilde)
        flat_c = jax.tree_util.tree_leaves_with_path(cache[op.bits])
        flat_d = jax.tree_util.tree_leaves_with_path(direct)
        assert len(flat_c) == len(flat_d)
        for (pc, lc), (pd, ld) in zip(flat_c, flat_d):
            assert pc == pd
            assert lc.dtype == ld.dtype
            np.testing.assert_array_equal(np.asarray(lc), np.asarray(ld))


def test_variants_share_pytree_structure(engine):
    treedefs = {jax.tree_util.tree_structure(v)
                for v in engine.variants.values()}
    assert len(treedefs) == 1          # why one jit compilation covers all


def test_variants_carry_per_rung_act_bits(engine):
    """b~x is data in the variant, so rungs differ in BOTH (b~x, R)."""
    def act_ns(tree):
        vals = set()
        for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
            if getattr(path[-1], "key", "") == "act_n":
                vals.update(np.asarray(leaf).reshape(-1).tolist())
        return vals

    for op in engine.ladder:
        ns = act_ns(engine.variants[op.bits])
        assert ns == {float((1 << op.b_x_tilde) - 1)}


# ---------------------------------------------------------------------------
# Per-request rung selection
# ---------------------------------------------------------------------------

def test_select_rung_power_budget():
    ladder = build_ladder(LADDER_BITS, d=64.0)
    assert select_rung(ladder, power_budget_bits=6).bits == 6
    assert select_rung(ladder, power_budget_bits=5).bits == 4   # best <= 5
    assert select_rung(ladder, power_budget_bits=2).bits == 2
    assert select_rung(ladder, power_budget_bits=1).bits == 2   # clamped up
    assert select_rung(ladder).bits == 6                        # default: top


def test_select_rung_accuracy_floor():
    ladder = build_ladder(LADDER_BITS, d=64.0)
    scores = {op.bits: op.score for op in ladder}
    assert scores[2] < scores[4] < scores[6]    # -MSE rises with power
    # cheapest rung meeting the floor
    assert select_rung(ladder, min_score=scores[2]).bits == 2
    assert select_rung(ladder, min_score=scores[4]).bits == 4
    # unattainable floor -> best available
    assert select_rung(ladder, min_score=scores[6] + 1.0).bits == 6


def test_select_rung_honors_both_constraints():
    ladder = build_ladder(LADDER_BITS, d=64.0)
    scores = {op.bits: op.score for op in ladder}
    # cheapest rung meeting the floor within the budget
    sel = select_rung(ladder, power_budget_bits=6, min_score=scores[4])
    assert sel.bits == 4
    # floor unreachable within the budget -> refuse, never silently violate
    with pytest.raises(ValueError, match="power budget"):
        select_rung(ladder, power_budget_bits=2, min_score=scores[6])


def test_scheduler_routes_and_batches():
    ladder = build_ladder(LADDER_BITS, d=64.0)
    sched = Scheduler(ladder, max_batch=2)
    for i, bits in enumerate([4, 4, 2, 4]):
        sched.submit(Request(uid=i, prompt=_prompt(i),
                             power_budget_bits=bits))
    waves = []
    while sched.pending():
        waves.append(sched.next_wave())
    got = [(w.rung.bits, [r.uid for r in w.requests]) for w in waves]
    # max_batch=2 splits the three 4-bit requests; the 2-bit one interleaves
    assert (4, [0, 1]) in got and (2, [2]) in got and (4, [3]) in got


def test_generate_selects_rung_per_request(engine):
    budgets = [2, 6, 4, 2]
    reqs = [Request(uid=i, prompt=_prompt(1), max_new_tokens=4,
                    power_budget_bits=b) for i, b in enumerate(budgets)]
    resps = engine.generate(reqs)
    assert [r.uid for r in resps] == [0, 1, 2, 3]
    assert [r.rung_bits for r in resps] == budgets
    for r in resps:
        assert len(r.tokens) == 4
        assert r.metadata["b_x_tilde"] == engine.rungs[r.rung_bits].b_x_tilde
    # energy metadata orders with the rung's power
    per_tok = {r.rung_bits: r.metadata["est_bitflips_per_token"]
               for r in resps}
    assert per_tok[2] < per_tok[4] < per_tok[6]


# ---------------------------------------------------------------------------
# Rung switching without re-quantization / recompilation
# ---------------------------------------------------------------------------

def test_no_recompile_across_rungs(engine):
    assert engine.compilations_after_warmup == 1
    reqs = [Request(uid=i, prompt=_prompt(2), max_new_tokens=4,
                    power_budget_bits=b) for i, b in enumerate(LADDER_BITS)]
    engine.generate(reqs)
    engine.assert_no_recompile()
    assert engine.rung_switches > 0


def test_generate_rejects_oversized_requests_upfront(engine):
    ok = Request(uid=0, prompt=_prompt(4), max_new_tokens=4,
                 power_budget_bits=2)
    too_big = Request(uid=1, prompt=_prompt(4), max_new_tokens=1000,
                      power_budget_bits=2)
    with pytest.raises(ValueError, match="max_len"):
        engine.generate([ok, too_big])
    assert engine.scheduler.pending() == 0    # nothing was half-admitted
    assert len(engine.generate([ok])) == 1    # engine still serves


def test_generate_rejects_infeasible_constraints_upfront(engine):
    ok = Request(uid=0, prompt=_prompt(4), max_new_tokens=4,
                 power_budget_bits=2)
    infeasible = Request(uid=1, prompt=_prompt(4), max_new_tokens=4,
                         power_budget_bits=2, min_score=1e9)
    with pytest.raises(ValueError, match="power budget"):
        engine.generate([ok, infeasible])
    # the ok request must not be stranded in the queue and served (and
    # billed) inside a later, unrelated generate() call
    assert engine.scheduler.pending() == 0
    later = engine.generate([Request(uid=7, prompt=_prompt(4),
                                     max_new_tokens=4,
                                     power_budget_bits=2)])
    assert [r.uid for r in later] == [7]


def test_encdec_frontend_quantized_at_serving_rung():
    """For encdec, init_decode_state runs the encoder + cross-K/V projections
    through the variant — so different rungs must produce different states."""
    import jax.numpy as jnp
    from repro.data.pipeline import frontend_stub
    cfg = configs.reduced(configs.get_config("seamless-m4t-medium"))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)

    def fe_fn(batch):
        return {"enc_inputs": jnp.asarray(frontend_stub(cfg, batch, 0, 0))}

    eng = ServeEngine(cfg, params, ladder_bits=(2, 6), max_batch=2,
                      max_len=16, frontend_kwargs_fn=fe_fn)
    lo = eng._init_state(2)
    hi = eng._init_state(6)
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        lo.cross_kv, hi.cross_kv)
    assert max(jax.tree_util.tree_leaves(diff)) > 0.0


def test_midstream_switch_matches_fresh_server(setup, engine):
    cfg, params = setup
    prompt = _prompt(3, n=8)
    out = engine.decode_stream(prompt, [(2, 4), (6, 4)])
    assert len(out["tokens"]) == 8
    seg1, seg2 = out["segments"]
    assert (seg1["rung_bits"], seg2["rung_bits"]) == (2, 6)

    # a FRESH server at the target rung, given the same prefix, must produce
    # the identical continuation
    fresh = ServeEngine(cfg, params, ladder_bits=LADDER_BITS, max_batch=2,
                        max_len=28)
    fresh.warmup()
    prefix = np.concatenate([prompt, np.asarray(seg1["tokens"], np.int32)])
    fresh_out = fresh.decode_stream(prefix, [(6, 4)])
    assert fresh_out["tokens"] == seg2["tokens"]
    engine.assert_no_recompile()


def test_no_recompile_mixed_weight_and_cache_rungs(setup):
    """ONE jitted decode step serves a mixed weight-rung x cache-rung
    ladder: cache_bits='auto' gives every rung its own cache width
    (k_nlvl/v_nlvl DATA leaves), and the packed-plane cache layout is
    pinned at 7 planes — so serving traffic across all rungs must not add
    a single compilation past warmup."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, ladder_bits=LADDER_BITS, max_batch=2,
                      max_len=28, cache_bits="auto")
    eng.warmup()
    assert eng.compilations_after_warmup == 1
    # the rungs really do carry DIFFERENT cache widths (mixed ladder)
    assert len(set(eng._cache_bits_by_rung.values())) > 1
    reqs = [Request(uid=i, prompt=_prompt(7), max_new_tokens=4,
                    power_budget_bits=b) for i, b in enumerate(LADDER_BITS)]
    resps = eng.generate(reqs)
    eng.assert_no_recompile()
    assert eng.rung_switches > 0
    for r in resps:
        cb = r.metadata["cache_bits"]
        assert set(cb) == {"attn.k_cache", "attn.v_cache"}
        # the response itemizes the cache's own bit-flip spend
        assert r.metadata["per_module_gbitflips_per_token"][
            "attn.k_cache"] > 0


def test_midstream_switch_with_quantized_cache_matches_fresh_server(setup):
    """The rung-switch replay contract survives cache quantization: a
    switch re-encodes the prefix's cache codes from scratch at the target
    rung's width, so the continuation is bit-identical to a fresh server
    at that rung — quantized cache and all."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, ladder_bits=LADDER_BITS, max_batch=2,
                      max_len=28, cache_bits="auto")
    eng.warmup()
    prompt = _prompt(8, n=8)
    out = eng.decode_stream(prompt, [(2, 4), (6, 4)])
    seg1, seg2 = out["segments"]

    fresh = ServeEngine(cfg, params, ladder_bits=LADDER_BITS, max_batch=2,
                        max_len=28, cache_bits="auto")
    fresh.warmup()
    prefix = np.concatenate([prompt, np.asarray(seg1["tokens"], np.int32)])
    fresh_out = fresh.decode_stream(prefix, [(6, 4)])
    assert fresh_out["tokens"] == seg2["tokens"]
    eng.assert_no_recompile()


def test_decode_stream_zero_length_segment(engine):
    prompt = _prompt(5, n=8)
    out = engine.decode_stream(prompt, [(2, 0), (6, 3)])
    assert len(out["tokens"]) == 3            # zero segments emit no tokens
    assert out["segments"][0]["tokens"] == []
    assert len(out["segments"][1]["tokens"]) == 3


def test_decode_stream_rejects_unknown_rung_upfront(engine):
    before = dict(engine.steps_by_rung)
    with pytest.raises(KeyError, match="no rung"):
        engine.decode_stream(_prompt(6, n=8), [(2, 4), (5, 4)])
    # validation happens before any decode work, so no steps were burned
    assert engine.steps_by_rung == before


# ---------------------------------------------------------------------------
# Family and mesh coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-1.2b"])
def test_ladder_serving_recurrent_families(arch):
    """The act_n path must survive rwkv/mamba decode bodies, not just
    attention projections."""
    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, ladder_bits=(2, 6), max_batch=2,
                      max_len=12)
    eng.warmup()
    reqs = [Request(uid=i, prompt=_prompt(6), max_new_tokens=4,
                    power_budget_bits=b) for i, b in enumerate((2, 6))]
    resps = eng.generate(reqs)
    assert [r.rung_bits for r in resps] == [2, 6]
    assert all(len(r.tokens) == 4 for r in resps)
    eng.assert_no_recompile()


def test_variant_cache_mesh_sharded():
    """DESIGN.md §6's 'sharded like training params' claim, on a real
    (2, 4) mesh in an 8-device subprocess (multidev pattern)."""
    from test_dist_multidev import run_py
    r = run_py("""
        import dataclasses, json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh
        from repro import configs
        from repro.configs.base import QuantConfig
        from repro.models import model as MD
        from repro.models.serving import (build_variant_cache,
                                          quantize_params_for_serving)

        cfg = configs.reduced(configs.get_config("llama3-8b"))
        cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        cache = build_variant_cache(params, cfg, {2: (2.83, 3)}, mesh=mesh)
        direct = quantize_params_for_serving(params, cfg, r=2.83, act_bits=3)

        n_wq = n_wq_sharded = 0
        exact = True
        repl_ok = True
        for (path, leaf), (_, ref) in zip(
                jax.tree_util.tree_leaves_with_path(cache[2]),
                jax.tree_util.tree_leaves_with_path(direct)):
            key = getattr(path[-1], "key", "")
            exact &= bool(np.array_equal(np.asarray(leaf), np.asarray(ref)))
            if key == "w_q":
                n_wq += 1
                n_wq_sharded += int(any(leaf.sharding.spec))
            if key in ("w_scale", "act_n"):
                repl_ok &= not any(leaf.sharding.spec)
        print(json.dumps({"n_wq": n_wq, "n_wq_sharded": n_wq_sharded,
                          "exact": exact, "repl_ok": repl_ok}))
    """)
    assert r["n_wq"] > 0 and r["n_wq_sharded"] == r["n_wq"]
    assert r["exact"]          # sharding never changes the codes
    assert r["repl_ok"]        # scales and act_n replicated
