"""The 512-chip dry-run must run end-to-end on this CPU container.

Runs the real CLI in a subprocess (the launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax,
so it must own its process) with --reduced configs: the mesh construction,
greedy sharding, SPMD lowering/compile, HLO collective parsing, and the
resumable JSON output all execute for real — only the layer widths shrink.
"""
import json
import os
import subprocess
import sys

ENV = {**os.environ,
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
ENV.pop("XLA_FLAGS", None)  # the dryrun module sets its own


def test_dryrun_cli_end_to_end(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3-8b", "--shape", "train_4k", "--mesh", "single",
         "--reduced", "--no-probe", "--out", str(tmp_path)],
        env=ENV, capture_output=True, text=True, timeout=560,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"

    path = tmp_path / "dryrun_single_reduced.json"
    assert path.exists(), out.stdout[-2000:]
    rec = json.loads(path.read_text())
    assert not rec["failures"], rec["failures"]
    (cell,) = rec["records"]
    assert cell["arch"] == "llama3-8b" and cell["n_devices"] == 256
    # the roofline inputs were extracted from the compiled artifact
    assert cell["flops_per_device"] > 0
    assert cell["collective_bytes_per_device"]["total"] > 0
    assert cell["temp_size_in_bytes"] > 0

    # resumability: a second invocation must skip the recorded cell
    again = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3-8b", "--shape", "train_4k", "--mesh", "single",
         "--reduced", "--no-probe", "--out", str(tmp_path)],
        env=ENV, capture_output=True, text=True, timeout=120,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert again.returncode == 0, again.stderr[-2000:]
    assert "resuming: 1 records already present" in again.stdout
