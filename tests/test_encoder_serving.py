"""Encoder & conv serving (PR 10): the conv projection's bit-exactness
contract, the conv cost/policy plumbing, and the batch-oriented
``EncodeEngine`` — on reduced ``llama-3.2-vision-90b`` (vision conv stem)
and ``seamless-m4t-medium`` (speech conv stem + bidirectional encoder).

The conv claims mirror the linear ones (tests/test_kernel_dispatch.py):

  * ``dispatch.serving_conv`` is bit-identical (fp32 ``assert_array_equal``)
    to the jnp int32 conv oracle (``serving_conv_oracle``) across all three
    backends — ref, fused Pallas, packed planes;
  * across RUNG VIEWS of one weight store the same identity holds per view
    (the view's masked codes feed both sides);
  * ``costs`` accounts conv MACs exactly (kh·kw·Cin·Cout·Ho·Wo) and
    ``allocate_layerwise`` prices the ``conv.s{i}`` roles under one budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import costs
from repro.core import policy as pol
from repro.data import pipeline
from repro.kernels import dispatch, pann_conv
from repro.models import model as MD
from repro.models import serving
from repro.serve_engine import EncodeEngine, EncodeRequest

BACKENDS = ("ref", "fused:force", "packed:force")
ARCHS = ("llama-3.2-vision-90b", "seamless-m4t-medium")


def _reduced(arch):
    cfg = configs.reduced(configs.get_config(arch))
    return dataclasses.replace(cfg, quant=QuantConfig(mode="none"))


@pytest.fixture(scope="module", params=ARCHS)
def setup(request):
    cfg = _reduced(request.param)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    raw = pipeline.frontend_raw_stub(cfg, 2, step=0)
    return cfg, params, jnp.asarray(raw)


# ---------------------------------------------------------------------------
# im2col plumbing
# ---------------------------------------------------------------------------

def test_extract_patches_matches_flat_weight_layout():
    """The layout contract: patch features in (di, dj, c) order match
    ``w_flat.reshape(kh, kw, c_in, c_out)`` read as HWIO — so the patch
    matmul IS the conv, verified in float against lax.conv."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 9, 7, 5)), jnp.float32)
    w_flat = jnp.asarray(rng.standard_normal((3 * 2 * 5, 4)), jnp.float32)
    patches = pann_conv.extract_patches(x, 3, 2, 2, 1)
    y_mat = patches.reshape(-1, patches.shape[-1]) @ w_flat
    y_conv = jax.lax.conv_general_dilated(
        x, w_flat.reshape(3, 2, 5, 4), window_strides=(2, 1),
        padding="VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y_mat).reshape(y_conv.shape),
                               np.asarray(y_conv), rtol=1e-5, atol=1e-5)


def test_conv_out_size_rejects_empty_output():
    with pytest.raises(ValueError):
        pann_conv.conv_out_size(2, 5, 1, 0)


# ---------------------------------------------------------------------------
# Bit-exactness: serving_conv vs int32 conv oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_serving_conv_bit_identical_to_oracle(setup, backend):
    cfg, params, raw = setup
    sp = serving.quantize_params_for_serving(
        params, cfg, spec=serving.ServingQuantSpec(
            r=4.0, act_bits=6, pack_planes=backend.startswith("packed")))
    for i, spec in enumerate(cfg.conv_stem):
        p = sp["conv_stem"][f"s{i}"]
        x = raw if i == 0 else jnp.zeros(
            (2,) + cfg.conv_stem[i - 1].out_hw(*cfg.frontend_hw)
            + (spec.c_in,), jnp.float32)
        y = dispatch.serving_conv(x, p, spec, backend)
        oracle = dispatch.serving_conv_oracle(x, p, spec)
        assert y.dtype == x.dtype
        np.testing.assert_array_equal(np.asarray(y), np.asarray(oracle))


@pytest.mark.parametrize("backend", BACKENDS)
def test_serving_conv_exact_across_rung_views(setup, backend):
    """One store, every rung a view: the conv projection stays bit-exact
    vs the oracle THROUGH the view's plane_shift masking, per rung."""
    cfg, params, raw = setup
    ws = serving.build_weight_store(
        params, cfg, {2: (2.0, 6), 6: (16.0, 6)},
        spec=serving.ServingQuantSpec(pack_planes=True))
    spec = cfg.conv_stem[0]
    outs = {}
    for rung, view in ws.views.items():
        p = view["conv_stem"]["s0"]
        assert "plane_shift" in p
        y = dispatch.serving_conv(raw, p, spec, backend)
        oracle = dispatch.serving_conv_oracle(raw, p, spec)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(oracle))
        outs[rung] = np.asarray(y)
    # the narrow rung genuinely differs (planes were dropped) — the
    # cross-view equality above is not vacuous
    assert not np.array_equal(outs[2], outs[6])


def test_zero_padding_is_exact_not_approximate(setup):
    """Padding soundness: scalars come from the PADDED input (include_zero
    ranges), so padding zeros encode exactly to the zero point and the
    border contributes exactly b_q - zcol. Checked by comparing against
    manual fp padding + the same conv on a pad-free spec."""
    cfg, params, raw = setup
    spec = cfg.conv_stem[0]
    if spec.ph == 0 and spec.pw == 0:
        pytest.skip("first stem layer of this arch is unpadded")
    sp = serving.quantize_params_for_serving(
        params, cfg, spec=serving.ServingQuantSpec(r=4.0, act_bits=6))
    p = sp["conv_stem"]["s0"]
    y = dispatch.serving_conv(raw, p, spec, "ref")
    xpad = pann_conv.pad_nhwc(raw, spec.ph, spec.pw)
    spec0 = dataclasses.replace(spec, ph=0, pw=0)
    y_manual = dispatch.serving_conv(xpad, p, spec0, "ref")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_manual))


# ---------------------------------------------------------------------------
# Costs & allocator: conv roles under one budget
# ---------------------------------------------------------------------------

def test_conv_macs_exact_account(setup):
    cfg, _, _ = setup
    rows = costs.conv_stem_item_costs(cfg)
    assert len(rows) == len(cfg.conv_stem)
    h, w = cfg.frontend_hw
    for row, spec in zip(rows, cfg.conv_stem):
        ho, wo = spec.out_hw(h, w)
        assert row.macs == spec.kh * spec.kw * spec.c_in * spec.c_out \
            * ho * wo
        assert row.fan_in == spec.fan_in
        h, w = ho, wo


def test_profile_roots_include_conv_and_sum_matches(setup):
    cfg, _, _ = setup
    profile = costs.module_cost_profile(cfg)
    roots = {m.path.split(".")[0] for m in profile}
    assert "conv" in roots
    total = sum(m.macs for m in profile)
    assert total == pytest.approx(costs.macs_per_token(cfg).weight_macs,
                                  rel=1e-9)


def test_allocator_spends_budget_across_conv_roles(setup):
    """``allocate_layerwise`` on the per-item encoder profile must assign
    every conv role its own operating point AND keep the total power at
    the uniform budget — conv bits genuinely trade against the rest."""
    from repro.core import planner
    cfg, _, _ = setup
    profile = costs.encoder_cost_profile(cfg)
    conv_paths = [m.path for m in profile if m.path.startswith("conv.")]
    assert conv_paths
    lw = planner.allocate_layerwise(planner.budget_from_bits(4), profile)
    for path in conv_paths:
        mq = lw.tree.lookup(path)
        assert mq.mode == "pann" and mq.r > 0
    total, breakdown = pol.tree_power_per_token(profile, lw.tree,
                                                act_macs=0.0)
    for path in conv_paths:
        assert breakdown[path] > 0


def test_serving_path_maps_conv_trail():
    assert pol.serving_path(("conv_stem", "s0")) == "conv.s0"
    assert pol.serving_path(("conv_stem", "s1")) == "conv.s1"


# ---------------------------------------------------------------------------
# EncodeEngine: encoder serving end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("allocation", ("uniform", "layerwise"))
def test_encode_engine_serves_ladder_without_recompile(setup, allocation):
    cfg, params, raw = setup
    eng = EncodeEngine(cfg, params, ladder_bits=(2, 4, 6), max_batch=2,
                       backend="ref", allocation=allocation)
    eng.warmup()
    assert eng.compilations_after_warmup == 1
    reqs = [EncodeRequest(uid=i, item=np.asarray(raw[i % 2]),
                          power_budget_bits=b)
            for i, b in enumerate((2, 4, 6, 6))]
    out = eng.encode(reqs)
    eng.assert_no_recompile()
    assert [r.uid for r in out] == [0, 1, 2, 3]
    assert [r.rung_bits for r in out] == [2, 4, 6, 6]
    t = costs.encoder_tokens(cfg)
    for r in out:
        assert r.encoded.shape == (t, cfg.d_model)
        assert r.metadata["est_bitflips_per_token"] > 0
    # higher budget -> at least as much power per item
    flips = [r.metadata["est_bitflips_per_token"] for r in out[:3]]
    assert flips[0] < flips[1] < flips[2]


def test_encode_engine_ledger_itemizes_conv_roles(setup):
    cfg, params, raw = setup
    eng = EncodeEngine(cfg, params, ladder_bits=(2, 6), max_batch=1,
                       backend="ref", allocation="layerwise")
    out = eng.encode([EncodeRequest(uid=0, item=np.asarray(raw[0]),
                                    power_budget_bits=6)])
    breakdown = out[0].metadata["per_module_gbitflips_per_token"]
    conv_roles = {k for k in breakdown if k.startswith("conv.")}
    assert conv_roles == {f"conv.s{i}" for i in range(len(cfg.conv_stem))}
    assert all(breakdown[k] > 0 for k in conv_roles)


def test_encode_engine_outputs_differ_across_rungs(setup):
    """The dial is real: a 2-bit encode differs from a 6-bit encode of the
    same item, and each equals a direct MD.encode through that rung's
    variant (the engine adds batching, not numerics)."""
    cfg, params, raw = setup
    eng = EncodeEngine(cfg, params, ladder_bits=(2, 6), max_batch=2,
                       backend="ref")
    item = np.asarray(raw[0])
    out = eng.encode([EncodeRequest(uid=0, item=item, power_budget_bits=2),
                      EncodeRequest(uid=1, item=item, power_budget_bits=6)])
    assert not np.array_equal(out[0].encoded, out[1].encoded)
    cfg_b = dataclasses.replace(eng.cfg)
    for resp in out:
        direct = MD.encode(eng.variants[resp.rung_bits], cfg_b,
                           jnp.asarray(np.stack([item, item])))
        np.testing.assert_array_equal(resp.encoded, np.asarray(direct[0]))


def test_encode_engine_rejects_wrong_item_shape(setup):
    cfg, params, _ = setup
    eng = EncodeEngine(cfg, params, ladder_bits=(4,), max_batch=1,
                       backend="ref")
    bad = np.zeros((3, 3, 3), np.float32)
    with pytest.raises(ValueError, match="item shape"):
        eng.encode([EncodeRequest(uid=0, item=bad)])


def test_encode_engine_rejects_lm_only_config():
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="no encode path"):
        EncodeEngine(cfg, params, ladder_bits=(4,))


def test_encoder_forward_matches_training_float_path(setup):
    """4-D raw input through ``forward`` routes through the stem and
    agrees with explicitly stemmed 3-D input — train/serve stay one
    code path."""
    cfg, params, raw = setup
    toks = jnp.zeros((2, 4), jnp.int32)
    kw4 = {"image_embeds": raw} if cfg.family == "vlm" \
        else {"enc_inputs": raw}
    emb = MD.apply_conv_stem(params, cfg, raw)
    kw3 = {"image_embeds": emb} if cfg.family == "vlm" \
        else {"enc_inputs": emb}
    out4 = MD.forward(params, cfg, toks, **kw4)
    out3 = MD.forward(params, cfg, toks, **kw3)
    np.testing.assert_array_equal(np.asarray(out4.logits),
                                  np.asarray(out3.logits))
