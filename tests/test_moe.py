"""MoE: router properties + dense-scan vs capacity-dispatch equivalence."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import mlp as M


def _moe_cfg():
    cfg = configs.reduced(configs.get_config("mixtral-8x7b"))
    return cfg


@given(st.integers(0, 1000), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_router_topk_properties(seed, k):
    rng = np.random.default_rng(seed)
    e = 8
    logits = jnp.asarray(rng.standard_normal((3, 5, e)), jnp.float32)
    gates, mask = M.router_topk(logits, k)
    # exactly k experts selected per token; gates sum to 1 over selected
    assert int(mask.sum(-1).min()) == k and int(mask.sum(-1).max()) == k
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert float(gates.min()) >= 0
    # the selected experts are the k largest logits
    sel_logits = jnp.where(mask, logits, -jnp.inf)
    thresh = jnp.min(jnp.where(mask, logits, jnp.inf), axis=-1)
    assert bool((jnp.where(~mask, logits, -jnp.inf)
                 <= thresh[..., None] + 1e-6).all())


def test_moe_scan_forward_uses_gates():
    cfg = _moe_cfg()
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 8, cfg.d_model)), jnp.float32)
    y, aux = M.apply_moe(x, p, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0.5  # load-balance loss near E * 1/E * 1 = 1


def test_moe_aux_loss_balanced_router_is_topk():
    """With uniform router probabilities the Switch-style aux loss
    E * sum_e f_e p_e equals top_k (f sums to k, p uniform)."""
    cfg = _moe_cfg()
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, 64, cfg.d_model))
    p = {**p, "router": {"w": jnp.zeros_like(p["router"]["w"])}}
    _, aux = M.apply_moe(x, p, cfg)
    assert float(aux) == pytest.approx(cfg.moe.top_k, rel=0.05)


def test_capacity_dispatch_matches_scan_multidev():
    """The §Perf capacity path must match the dense scan wherever no token
    is dropped (generous capacity_factor)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                      "src")}
    code = textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs.base import MoEConfig
        from repro.models import mlp as M
        from repro.dist.moe_ep import apply_moe_capacity

        cfg = configs.reduced(configs.get_config("mixtral-8x7b"))
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0))
        p = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (8, 16, cfg.d_model)), jnp.float32)
        y_scan, aux_scan = M.apply_moe(x, p, cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh:
            y_cap, aux_cap = jax.jit(
                lambda x_, p_: apply_moe_capacity(x_, p_, cfg, mesh))(x, p)
        err = float(jnp.abs(y_scan - y_cap).max()
                    / (jnp.abs(y_scan).max() + 1e-9))
        # gradients flow through the dispatch path
        g = jax.grad(lambda p_: jnp.sum(
            apply_moe_capacity(x, p_, cfg, mesh)[0] ** 2))(p)
        gn = float(sum(jnp.abs(l).sum()
                       for l in jax.tree_util.tree_leaves(g)))
        print(json.dumps({"err": err, "aux_scan": float(aux_scan),
                          "aux_cap": float(aux_cap), "grad_norm": gn}))
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-4, r
    assert r["aux_cap"] == pytest.approx(r["aux_scan"], rel=0.05)
    assert r["grad_norm"] > 0
