"""Validate the bit-flip simulators against the paper's analytic models.

These are the paper's own calibration experiments (Table 1, Figs. 8-11,
Observations 1 & 2) re-run on our vectorized simulator.
"""
import numpy as np
import pytest

from repro.core import bitflip as bf
from repro.core import power as pw

N = 30_000
RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Table 1 / Eqs. (1)-(4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [3, 4, 6, 8])
@pytest.mark.parametrize("kind", ["serial", "booth"])
def test_mult_signed_matches_half_b_squared(b, kind):
    w = bf.draw_uniform_signed(RNG, b, N)
    x = bf.draw_uniform_signed(RNG, b, N)
    stats = bf.simulate_multiplier(w, x, b, b, kind=kind)
    model = pw.p_mult_signed(b)
    assert stats.total == pytest.approx(model, rel=0.45)
    # inputs alone: 0.5b + 0.5b
    assert stats.input_toggles == pytest.approx(b, rel=0.1)


@pytest.mark.parametrize("b", [3, 4, 6, 8])
def test_mult_unsigned_close_to_signed(b):
    """App. A.3 / Fig. 6(a): unsigned multiplier power ~= signed (ratio ~0.9)."""
    ws, xs = (bf.draw_uniform_signed(RNG, b, N) for _ in range(2))
    wu, xu = (bf.draw_uniform_unsigned(RNG, b, N) for _ in range(2))
    s = bf.simulate_multiplier(ws, xs, b, b).internal_toggles
    u = bf.simulate_multiplier(wu, xu, b, b).internal_toggles
    assert 0.5 < u / s <= 1.1


@pytest.mark.parametrize("b", [2, 4, 6, 8])
def test_accumulator_signed_observation1(b):
    """Obs. 1: signed products toggle ~0.5B accumulator-input bits."""
    w = bf.draw_uniform_signed(RNG, b, N)
    x = bf.draw_uniform_signed(RNG, b, N)
    acc = bf.simulate_accumulator(w * x, acc_bits=32)
    if b >= 4:
        assert acc.input_toggles == pytest.approx(16.0, rel=0.15)
    else:
        # at b=2 many products are exactly zero, so the sign-extension bits
        # toggle less than the idealized 0.5B — but still dominate
        assert acc.input_toggles > 10.0
    # sum + FF toggles ~ 0.5*b_acc + 0.5*b_acc = 2b
    assert acc.sum_toggles + acc.ff_toggles == pytest.approx(2 * b, rel=0.5)


@pytest.mark.parametrize("b", [2, 3, 4, 6, 8])
def test_accumulator_unsigned(b):
    """Eq. (4): unsigned accumulation costs ~3b, input toggles drop to ~b."""
    w = bf.draw_uniform_unsigned(RNG, b, N)
    x = bf.draw_uniform_unsigned(RNG, b, N)
    acc = bf.simulate_accumulator(w * x, acc_bits=32)
    assert acc.input_toggles <= b * 1.25
    if b >= 3:
        # with half-range operands (App. A.4) the effective width is b-1,
        # so the measured cost tracks 3*(b-1); Eq. (4)'s 3b is the
        # full-range, conservative version of the same model
        assert acc.total == pytest.approx(pw.p_acc_unsigned(b - 1), rel=0.35)
    assert acc.total <= pw.p_acc_unsigned(b) * 1.05
    # and always well below the signed cost (Obs. 1; saving shrinks as b grows)
    assert acc.total < pw.p_acc_signed(b, 32) * 0.8


# ---------------------------------------------------------------------------
# Observation 2 / Eq. (7): mixed widths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["serial", "booth"])
def test_mixed_width_signed_dominated_by_max(kind):
    b_x = 8
    base = None
    for b_w in [8, 6, 4, 2]:
        w = bf.draw_uniform_signed(RNG, b_w, N)
        x = bf.draw_uniform_signed(RNG, b_x, N)
        tot = bf.simulate_multiplier(w, x, b_w, b_x, kind=kind).internal_toggles
        if base is None:
            base = tot
        # signed: power stays within ~20% of the b_w = b_x case (Fig. 10)
        assert tot >= 0.6 * base


def test_mixed_width_unsigned_saves_power():
    """Fig. 10/11 (left): with unsigned operands, shrinking b_w does save."""
    b_x = 8
    x = bf.draw_uniform_unsigned(RNG, b_x, N)
    w8 = bf.draw_uniform_unsigned(RNG, 8, N)
    w2 = bf.draw_uniform_unsigned(RNG, 2, N)
    t8 = bf.simulate_multiplier(w8, x, 8, b_x, kind="serial").internal_toggles
    t2 = bf.simulate_multiplier(w2, x, 2, b_x, kind="serial").internal_toggles
    assert t2 < 0.8 * t8


# ---------------------------------------------------------------------------
# PANN power model, Eq. (13)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bx_tilde,r_target", [(4, 2.0), (6, 1.5), (8, 3.0)])
def test_pann_stream_matches_eq13(bx_tilde, r_target):
    d = 20_000
    rng = np.random.default_rng(1)
    # draw integer weights with mean r_target (Poisson keeps them >= 0)
    w_q = rng.poisson(r_target, size=d)
    x_q = rng.integers(0, 1 << (bx_tilde - 1), size=d, dtype=np.int64)
    per_elem, r_emp = bf.simulate_pann_stream(w_q, x_q, acc_bits=32)
    model = pw.p_pann(r_emp, bx_tilde)
    assert per_elem == pytest.approx(model, rel=0.5)
    # PANN beats the unsigned MAC model once R is in the paper's regime
    assert per_elem < pw.p_mac_unsigned(8)


# ---------------------------------------------------------------------------
# Closed-form sanity (pure model level)
# ---------------------------------------------------------------------------

def test_unsigned_power_save_matches_paper_table6():
    # Table 6 last row: 32-bit accumulator saves {58, 44, 33, 25, 19}% at 2-6 bits
    expected = {2: 0.58, 3: 0.44, 4: 0.33, 5: 0.25, 6: 0.19}
    for b, frac in expected.items():
        assert pw.unsigned_power_save(b, 32) == pytest.approx(frac, abs=0.02)


def test_required_acc_bits_table6():
    # Table 6: ResNet largest layer fan-in 3*3*512 -> B = {17,19,21,23,25}
    for b, want in zip([2, 3, 4, 5, 6], [17, 19, 21, 23, 25]):
        assert pw.required_acc_bits(b, b, 9 * 512) == want


def test_pann_budget_inversion():
    for p in [18.0, 41.0, 99.0]:
        for bx in [2, 4, 6, 8]:
            r = pw.pann_r_for_budget(p, bx)
            assert pw.p_pann(r, bx) == pytest.approx(p)


def test_p_mult_mixed_edge_cases():
    """Eq. (7) with b_w != b_x: dominated by max (Obs. 2), symmetric in its
    arguments, and exactly Eq. (1) when the widths agree."""
    # equal widths collapse to the signed model: 0.5 b^2 + 0.5 (b + b)
    for b in (2, 4, 8):
        assert pw.p_mult_mixed(b, b) == pytest.approx(pw.p_mult_signed(b))
    # symmetry and the max-domination structure
    assert pw.p_mult_mixed(2, 8) == pytest.approx(pw.p_mult_mixed(8, 2))
    assert pw.p_mult_mixed(2, 8) == pytest.approx(0.5 * 64 + 0.5 * 10)
    # shrinking only the narrow operand saves only the linear term
    assert pw.p_mult_mixed(8, 8) - pw.p_mult_mixed(2, 8) \
        == pytest.approx(0.5 * (8 - 2))
    # extreme asymmetry: 1-bit weights against a wide activation
    assert pw.p_mult_mixed(1, 8) == pytest.approx(0.5 * 64 + 4.5)
    # and the mixed MAC uses the max width in its Eq.-2 accumulator term
    assert pw.p_mac_mixed_signed(2, 8, 32) == \
        pytest.approx(pw.p_mult_mixed(2, 8) + pw.p_acc_signed(8, 32))


def test_required_acc_bits_edge_cases():
    """Eq. (20) B = b_x + b_w + 1 + floor(log2(k^2 C_in)) off the Table-6
    grid: b_w != b_x, tiny C_in, and k > 1 convolution fan-ins."""
    # mixed widths contribute additively
    assert pw.required_acc_bits(2, 8, 1024) == 2 + 8 + 1 + 10
    assert pw.required_acc_bits(8, 2, 1024) == pw.required_acc_bits(2, 8,
                                                                    1024)
    # tiny C_in: fan_in 1 leaves just the b_x + b_w + 1 product width;
    # fan_in 0 is guarded (a degenerate module, not a crash)
    assert pw.required_acc_bits(4, 4, 1) == 9
    assert pw.required_acc_bits(4, 4, 0) == 9
    # k > 1 convs: fan_in = k^2 C_in, floor'd log2 (75 -> 6, not 6.23)
    assert pw.required_acc_bits(4, 4, 5 * 5 * 3) == 4 + 4 + 1 + 6
    assert pw.required_acc_bits(3, 5, 3 * 3 * 512) == 3 + 5 + 1 + 12
    # non-power-of-two boundary: floor(log2(2^k - 1)) == k - 1
    assert pw.required_acc_bits(4, 4, 1023) == 9 + 9
    assert pw.required_acc_bits(4, 4, 1024) == 9 + 10


def test_mac_power_reference_values():
    # Paper Sec. 3 example: b=4, B=32 -> P_mult + P_acc = 36, of which 16 = 44.4%
    assert pw.p_mac_signed(4, 32) == pytest.approx(36.0)
    assert 16.0 / pw.p_mac_signed(4, 32) == pytest.approx(0.444, abs=1e-3)
    # Fig. 3 caption: unsigned MAC = 0.5 b^2 + 4b
    for b in range(2, 9):
        assert pw.p_mac_unsigned(b) == pytest.approx(0.5 * b * b + 4 * b)
