"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pann as pann_core
from repro.core import quant as quant_core
from repro.core.unsigned import unsigned_split
from repro.kernels import ops, ref
from repro.kernels.pann_matmul import pann_matmul as pann_matmul_raw
from repro.kernels.quantize_act import quantize_act as quantize_act_raw
from repro.kernels.unsigned_matmul import unsigned_matmul as unsigned_raw

RNG = np.random.default_rng(0)


def _mk_planes(k, n, n_planes, lo=-12, hi=13):
    w_q = jnp.asarray(RNG.integers(lo, hi, (k, n)), jnp.float32)
    pos, neg = unsigned_split(w_q)
    pp = pann_core.bitplane_decompose(pos, n_planes)
    pn = pann_core.bitplane_decompose(neg, n_planes)
    return w_q, pp, pn


# ---------------------------------------------------------------------------
# pann_matmul kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (128, 256, 128),
                                   (256, 512, 256)])
@pytest.mark.parametrize("mode", ["fused", "planes"])
def test_pann_matmul_matches_oracle(m, k, n, mode):
    n_planes = 4
    _, pp, pn = _mk_planes(k, n, n_planes)
    x_q = jnp.asarray(RNG.integers(0, 128, (m, k)), jnp.int8)
    s_x = jnp.asarray(RNG.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    gamma = jnp.asarray(RNG.uniform(0.001, 0.01, (n,)), jnp.float32)
    got = pann_matmul_raw(x_q, pp, pn, s_x, gamma, mode=mode,
                          bm=128, bn=128, bk=128, interpret=True)
    want = ref.pann_matmul_ref(x_q, pp, pn, s_x, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_pann_matmul_modes_bit_identical():
    _, pp, pn = _mk_planes(128, 128, 3)
    x_q = jnp.asarray(RNG.integers(0, 64, (128, 128)), jnp.int8)
    s_x = jnp.ones((128, 1), jnp.float32)
    gamma = jnp.ones((128,), jnp.float32)
    a = pann_matmul_raw(x_q, pp, pn, s_x, gamma, mode="fused", interpret=True)
    b = pann_matmul_raw(x_q, pp, pn, s_x, gamma, mode="planes", interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n_planes", [1, 2, 3, 5, 6])
def test_pann_matmul_plane_count_sweep(n_planes):
    hi = (1 << n_planes)
    w_q, pp, pn = _mk_planes(128, 128, n_planes, lo=-(hi - 1), hi=hi)
    x_q = jnp.asarray(RNG.integers(0, 100, (128, 128)), jnp.int8)
    s_x = jnp.asarray(RNG.uniform(0.01, 1.0, (128, 1)), jnp.float32)
    gamma = jnp.full((128,), 0.5, jnp.float32)
    got = pann_matmul_raw(x_q, pp, pn, s_x, gamma, interpret=True)
    want = ref.pann_matmul_ref(x_q, pp, pn, s_x, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# quantize_act kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 6, 8])
@pytest.mark.parametrize("m,k", [(128, 256), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_act_matches_oracle(bits, m, k, dtype):
    x = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    q, s = quantize_act_raw(x, bits=bits, bm=128, interpret=True)
    qr, sr = ref.quantize_act_ref(x, bits=bits)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    else:
        # bf16 inputs can land exactly on .5 rounding boundaries where the
        # interpret-mode and jit division differ by 1 ulp -> code off by 1
        diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
        assert diff.max() <= 1 and (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    assert int(q.max()) <= (1 << (bits - 1)) - 1 and int(q.min()) >= 0


# ---------------------------------------------------------------------------
# unsigned_matmul kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (128, 384, 256)])
def test_unsigned_matmul_matches_oracle(m, k, n):
    x_q = jnp.asarray(RNG.integers(0, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(RNG.integers(-127, 128, (k, n)), jnp.int8)
    s_x = jnp.asarray(RNG.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    s_w = jnp.asarray(RNG.uniform(0.001, 0.01, (n,)), jnp.float32)
    got = unsigned_raw(x_q, w_q, s_x, s_w, bm=128, bn=128, bk=128,
                       interpret=True)
    want = ref.unsigned_matmul_ref(x_q, w_q, s_x, s_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# ops.py wrappers (padding paths + end-to-end PANN linear)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(100, 200, 72), (13, 130, 7), (128, 64, 64)])
def test_ops_unsigned_matmul_ragged(m, k, n):
    x_q = jnp.asarray(RNG.integers(0, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(RNG.integers(-127, 128, (k, n)), jnp.int8)
    s_x = jnp.asarray(RNG.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    s_w = jnp.asarray(RNG.uniform(0.001, 0.01, (n,)), jnp.float32)
    got = ops.unsigned_matmul(x_q, w_q, s_x, s_w, interpret=True)
    want = ref.unsigned_matmul_ref(x_q, w_q, s_x, s_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("m,k,n", [(64, 96, 80), (200, 256, 120)])
def test_ops_pann_matmul_end_to_end(m, k, n):
    """Fused-prologue path == the affine jnp oracle (dispatch conventions:
    per-tensor include_zero (s, z), int32 zcol in the accumulator)."""
    w = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    x = jnp.abs(jnp.asarray(RNG.standard_normal((m, k)), jnp.float32))
    r = 2.0
    packed = ops.pann_pack_weights(w, r, axis=0)
    got = ops.pann_matmul(x, packed, act_bits=8, interpret=True)

    # oracle: integer-exact affine reference, the identical (s, z) op
    # sequence the fused kernel's in-VMEM encode uses
    n_lvl = jnp.float32(min((1 << 8) - 1, 127))
    lo, hi = quant_core.act_range_bounds(x, include_zero=True)
    s, z = quant_core.affine_scale_zp(lo, hi, n_lvl)
    q = quant_core.affine_encode(x, s, z, n_lvl).astype(jnp.int32)
    w_q, gamma = pann_core.pann_quantize(w, r, axis=0)
    wq32 = w_q.astype(jnp.int32)
    zcol = z.astype(jnp.int32) * jnp.sum(wq32, axis=0)
    want = ((jnp.matmul(q, wq32) - zcol[None, :]).astype(jnp.float32)
            * s * gamma.reshape(1, -1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and it approximates the fp32 product
    rel = float(jnp.linalg.norm(got - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.15


def test_ops_quantize_act_leading_dims():
    x = jnp.asarray(RNG.standard_normal((4, 32, 96)), jnp.float32)
    q, s = ops.quantize_act(x, bits=6, interpret=True)
    assert q.shape == (4, 32, 96) and s.shape == (4, 32, 1)
    qr, sr = ref.quantize_act_ref(x.reshape(-1, 96), bits=6)
    np.testing.assert_array_equal(np.asarray(q).reshape(-1, 96),
                                  np.asarray(qr))
