"""The mmap-able serving artifact (DESIGN.md §11, docs/artifact.md):

  * zero-copy rung VIEWS over one max-budget weight store
    (``models.serving.build_weight_store`` / ``materialize_view``) —
    per-module, per-backend bit-equality between serving a view and
    serving its materialized copy, and through a full decode step;
  * the truncation-consistent scheme itself: a rung's effective codes are
    exactly the top planes of the max-R codes (property-based, vendored
    hypothesis stub);
  * the on-disk schema (``serve_engine.artifact``): manifest + blob
    round-trip bit-identically through one ``np.memmap`` with no
    Python-side copy, and corruption / version skew is REJECTED, never
    half-loaded.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import pann as pann_core
from repro.core import planner
from repro.kernels import dispatch
from repro.models import model as MD
from repro.models import serving
from repro.serve_engine import (ArtifactError, ServeEngine, load_artifact,
                                write_artifact)
from repro.serve_engine import artifact as art_mod

BACKENDS = ("ref", "fused:force", "packed:force")


@pytest.fixture(scope="module")
def setup():
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def specs(setup):
    cfg, _ = setup
    return {b: (p.r, p.b_x_tilde) for b, p in
            ((b, planner.plan_with_theory(planner.budget_from_bits(b),
                                          float(cfg.d_model)))
             for b in (2, 4, 6))}


@pytest.fixture(scope="module")
def ws(setup, specs):
    cfg, params = setup
    return serving.build_weight_store(
        params, cfg, specs, pack_planes=True,
        cache_bits={b: 4 for b in specs})


def _leaves(tree):
    return jax.tree_util.tree_leaves_with_path(tree)


# ---------------------------------------------------------------------------
# Views: zero-copy sharing + bit-equality vs materialization
# ---------------------------------------------------------------------------

def test_views_reference_store_leaves_by_identity(ws):
    """The zero-copy claim at the object level: every big leaf in a view
    IS the store's leaf — same array, same device buffer."""
    big = {"w_q", "w_planes_pos", "w_planes_neg", "w_scale", "b"}
    store_ids = {id(leaf) for _, leaf in _leaves(ws.store)}
    shared = 0
    for view in ws.views.values():
        for path, leaf in _leaves(view):
            if getattr(path[-1], "key", "") in big:
                assert id(leaf) in store_ids, path
                shared += 1
    assert shared > 0


def test_views_share_one_pytree_structure(ws):
    assert len({jax.tree_util.tree_structure(v)
                for v in ws.views.values()}) == 1


def test_narrow_rung_actually_shifts(ws):
    """The cross-rung tests below are vacuous unless at least one rung
    drops planes."""
    shifts = {rung: {float(np.asarray(leaf).reshape(-1)[0])
                     for path, leaf in _leaves(view)
                     if getattr(path[-1], "key", "") == "plane_shift"}
              for rung, view in ws.views.items()}
    assert max(max(s) for s in shifts.values() if s) > 0
    assert shifts[max(shifts)] == {0.0}     # top rung served exactly


@pytest.mark.parametrize("backend", BACKENDS)
def test_view_matches_materialized_per_module(setup, backend):
    """serving_linear over a plane-shifted VIEW == the same rung
    MATERIALIZED (codes re-quantized to the truncated values, planes
    re-packed, no plane_shift leaf) — per backend, bit-identical fp32."""
    cfg, _ = setup
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
    store = serving.build_weight_store({"wq": {"w": w}}, cfg,
                                       {2: (2.0, 8), 6: (16.0, 8)},
                                       pack_planes=True)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    for rung, view in store.views.items():
        shift = float(np.asarray(view["wq"]["plane_shift"]).reshape(-1)[0])
        if rung == min(store.views):
            assert shift > 0            # the narrow rung must drop planes
        mat = serving.materialize_view(view)
        assert "plane_shift" not in mat["wq"]
        y_view = dispatch.serving_linear(x, view["wq"], backend)
        y_mat = dispatch.serving_linear(x, mat["wq"], backend)
        np.testing.assert_array_equal(np.asarray(y_view), np.asarray(y_mat))


@pytest.mark.parametrize("backend", ("ref", "packed:force"))
def test_full_decode_step_view_vs_materialized(setup, ws, backend):
    """The whole reduced llama3 decode step — every projection plus the
    4-bit quantized KV cache — is bit-identical serving a rung view vs
    that view materialized."""
    cfg, _ = setup
    cfg_q = dataclasses.replace(cfg, kernel_backend=backend, cache_bits=4)
    tok = jnp.zeros((1, 1), jnp.int32)
    for rung in (min(ws.views), max(ws.views)):
        view = ws.views[rung]
        mat = serving.materialize_view(view)
        lv, _ = MD.decode_step(
            view, cfg_q, MD.init_decode_state(view, cfg_q, 1, 4), tok)
        lm, _ = MD.decode_step(
            mat, cfg_q, MD.init_decode_state(mat, cfg_q, 1, 4), tok)
        np.testing.assert_array_equal(np.asarray(lv), np.asarray(lm))


# ---------------------------------------------------------------------------
# Truncation consistency (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(st.integers(0, 6), st.integers(0, 10_000))
def test_rung_codes_are_top_planes_of_max_codes(shift, seed):
    """The scheme's defining identity: the integer weights a shift-s view
    realizes (``masked_codes``) equal the reconstruction from ONLY the top
    planes (p >= s) of the max-R plane stacks, per sign."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(-127, 128, (16, 8)), jnp.int32)
    planes_p = pann_core.bitplane_decompose(jnp.maximum(codes, 0), 7)
    planes_n = pann_core.bitplane_decompose(jnp.maximum(-codes, 0), 7)
    top = sum(((planes_p[p].astype(jnp.int32)
                - planes_n[p].astype(jnp.int32)) << p)
              for p in range(shift, 7))
    np.testing.assert_array_equal(
        np.asarray(pann_core.masked_codes(codes, shift)), np.asarray(top))


@settings(max_examples=20)
@given(st.floats(0.2, 120.0), st.floats(0.2, 120.0))
def test_view_shift_snaps_within_sqrt2(r_max, r):
    r = min(r, r_max)                   # rungs never exceed the store
    sh = pann_core.view_shift(r_max, r)
    assert 0 <= sh <= 6
    snapped = pann_core.snapped_r(r_max, sh)
    if sh < 6:                          # inside the clip, nearest-pow2 bound
        assert snapped / r < 2 ** 0.5 + 1e-9
    assert pann_core.view_shift(r_max, r_max) == 0
    assert pann_core.snapped_r(r_max, 0) == r_max


def test_view_shift_rejects_nonpositive_budgets():
    with pytest.raises(ValueError):
        pann_core.view_shift(0.0, 1.0)
    with pytest.raises(ValueError):
        pann_core.view_shift(4.0, -1.0)


# ---------------------------------------------------------------------------
# On-disk schema: round trip, zero-copy mmap, rejection
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def written(ws, tmp_path_factory):
    d = tmp_path_factory.mktemp("artifact")
    write_artifact(str(d), ws, meta={"note": "test"})
    return str(d)


def test_round_trip_bit_identical(ws, written):
    loaded = load_artifact(written)
    for orig, back in ((ws.store, loaded.store),
                       *((ws.views[r], loaded.views[r]) for r in ws.views)):
        fo, fb = _leaves(orig), _leaves(back)
        assert len(fo) == len(fb)
        for (po, lo), (pb, lb) in zip(fo, fb):
            assert po == pb
            assert np.asarray(lo).dtype == np.asarray(lb).dtype
            assert np.asarray(lo).shape == np.asarray(lb).shape
            np.testing.assert_array_equal(np.asarray(lo), np.asarray(lb))


def test_loaded_leaves_are_views_over_one_mmap(written):
    loaded = load_artifact(written)
    bases = set()
    for _, leaf in _leaves((loaded.store, loaded.views)):
        base = leaf
        while getattr(base, "base", None) is not None:
            base = base.base
        bases.add(id(base))
    assert len(bases) == 1              # every leaf windows ONE buffer
    # and ref leaves resolve to the SAME object as the store's, not a copy
    for view in loaded.views.values():
        for path, leaf in _leaves(view):
            if getattr(path[-1], "key", "") == "w_q":
                store_node = loaded.store
                for p in path[:-1]:
                    store_node = store_node[getattr(p, "key", getattr(
                        p, "idx", None))]
                assert leaf is store_node["w_q"]


def test_meta_round_trip(written):
    assert art_mod.read_meta(written)["note"] == "test"


def _copy_artifact(src, dst):
    os.makedirs(dst, exist_ok=True)
    for name in (art_mod.MANIFEST, art_mod.BLOB):
        with open(os.path.join(src, name), "rb") as f:
            data = f.read()
        with open(os.path.join(dst, name), "wb") as f:
            f.write(data)
    return dst


def test_rejects_version_skew(written, tmp_path):
    d = _copy_artifact(written, str(tmp_path / "skew"))
    mpath = os.path.join(d, art_mod.MANIFEST)
    with open(mpath) as f:
        man = json.load(f)
    man["version"] = art_mod.ARTIFACT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(man, f)
    with pytest.raises(ArtifactError, match="version"):
        load_artifact(d)


def test_rejects_wrong_magic(written, tmp_path):
    d = _copy_artifact(written, str(tmp_path / "magic"))
    mpath = os.path.join(d, art_mod.MANIFEST)
    with open(mpath) as f:
        man = json.load(f)
    man["magic"] = "not-a-weight-store"
    with open(mpath, "w") as f:
        json.dump(man, f)
    with pytest.raises(ArtifactError, match="magic"):
        load_artifact(d)


def test_rejects_truncated_blob(written, tmp_path):
    d = _copy_artifact(written, str(tmp_path / "trunc"))
    bpath = os.path.join(d, art_mod.BLOB)
    with open(bpath, "rb") as f:
        data = f.read()
    with open(bpath, "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(ArtifactError):
        load_artifact(d)


def test_rejects_missing_manifest(tmp_path):
    with pytest.raises(ArtifactError):
        load_artifact(str(tmp_path))


# ---------------------------------------------------------------------------
# Engine integration: one store behind the ladder
# ---------------------------------------------------------------------------

def test_engine_views_only_legacy_retired(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, ladder_bits=(2, 6), max_batch=1,
                      max_len=12)
    assert eng.artifact_format == "views"
    assert eng.weight_store is not None
    # the per-rung "legacy" materialization is retired: the name now gets
    # a helpful error pointing at the views format + the parity bound
    with pytest.raises(ValueError, match="retired"):
        ServeEngine(cfg, params, ladder_bits=(2, 6), max_batch=1,
                    max_len=12, artifact_format="legacy")
    with pytest.raises(ValueError, match="artifact_format"):
        ServeEngine(cfg, params, ladder_bits=(2, 6), max_batch=1,
                    max_len=12, artifact_format="mmap")


def test_engine_serves_loaded_artifact_bit_identically(setup, ws, written):
    """ROADMAP item 5 end-to-end: ``ServeEngine(weight_store=
    load_artifact(dir))`` serves WITHOUT re-quantizing, and its decode
    stream is bit-identical to an engine built over the in-memory store."""
    cfg, params = setup
    cfg_q = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    prompt = np.arange(5, dtype=np.int32)
    schedule = [(2, 3), (6, 3)]

    def run(store):
        eng = ServeEngine(cfg_q, weight_store=store, ladder_bits=(2, 6),
                          max_batch=1, max_len=16, cache_bits=4)
        eng.warmup()
        out = eng.decode_stream(prompt, schedule)
        eng.assert_no_recompile()
        return out

    mem = run(ws)
    loaded = run(load_artifact(written))
    assert mem["tokens"] == loaded["tokens"]
    assert mem["segments"] == loaded["segments"]


def test_engine_views_no_recompile_mixed_weight_cache_ladder(setup):
    """The §11 acceptance claim: with views, a mixed weight-rung x
    cache-rung ladder still decodes through ONE compiled step, and the
    views really do share the store's code arrays on device."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, ladder_bits=(2, 4, 6), max_batch=2,
                      max_len=20, cache_bits="auto")
    eng.warmup()
    assert eng.compilations_after_warmup == 1
    assert len(set(eng._cache_bits_by_rung.values())) > 1
    from repro.serve_engine import Request
    reqs = [Request(uid=i, prompt=np.arange(6, dtype=np.int32),
                    max_new_tokens=3, power_budget_bits=b)
            for i, b in enumerate((2, 4, 6))]
    eng.generate(reqs)
    eng.assert_no_recompile()
    ids = [{id(leaf) for path, leaf in _leaves(v)
            if getattr(path[-1], "key", "") == "w_q"}
           for v in eng.variants.values()]
    assert ids[0] == ids[1] == ids[2]   # one code tensor per module, shared
