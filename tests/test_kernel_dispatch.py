"""Kernel-dispatch backends (repro.kernels.dispatch): ref / fused / packed
bit-exactness per module role and rung, the off-TPU fallback policy, and the
one-compiled-decode-step-per-backend invariant through the serve engine."""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import policy as pol
from repro.kernels import dispatch
from repro.models import layers as L
from repro.models import model as MD
from repro.models import serving
from repro.serve_engine import Request, ServeEngine

RNG = np.random.default_rng(0)
PALLAS = ("fused:force", "packed:force")   # interpret mode on CPU


def _cfg(arch="llama3-8b"):
    cfg = configs.reduced(configs.get_config(arch))
    return dataclasses.replace(cfg, quant=QuantConfig(mode="none"))


def _leaf(k, n, r=3.0, act_bits=6, bias=False):
    """One projection's serving artifact via the real quantizer walk."""
    node = {"w": jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)}
    if bias:
        node["b"] = jnp.asarray(RNG.standard_normal((n,)), jnp.float32)
    qp = serving.quantize_params_for_serving(
        {"wq": node}, _cfg(), r=r, act_bits=act_bits, pack_planes=True)
    return qp["wq"]


@pytest.mark.parametrize("k,n,act_bits,bias", [
    (64, 48, 6, False),    # n not a tile multiple
    (72, 64, 8, True),     # b~x = 8 runs at the int8 half-range ceiling
    (60, 40, 3, False),    # K % 8 != 0: pack_planes pads K
    (129, 257, None, True),  # no act_n leaf; everything ragged
])
def test_backends_bit_identical(k, n, act_bits, bias):
    leaf = _leaf(k, n, act_bits=act_bits, bias=bias)
    x = jnp.asarray(RNG.standard_normal((3, 5, k)), jnp.float32)
    y_ref = jax.jit(lambda x, p: dispatch.serving_linear(x, p, "ref"))(
        x, leaf)
    for spec in PALLAS:
        y = jax.jit(lambda x, p: dispatch.serving_linear(x, p, spec))(
            x, leaf)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref),
                                      err_msg=spec)


def _quantized_modules(qp):
    """{role: per-layer artifact dict} over the whole quantized param tree."""
    found = {}

    def walk(node, trail=()):
        if isinstance(node, dict):
            if "w_q" in node:
                sd = node["w_q"].ndim - 2      # scan-stacked leading dims
                found.setdefault(
                    pol.serving_path(trail),
                    {kk: v[(0,) * sd] if sd else v for kk, v in node.items()})
                return
            for kk, v in node.items():
                walk(v, trail + (kk,))
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v, trail)

    walk(qp)
    return found


@pytest.mark.parametrize("arch,expect", [
    ("llama3-8b", {"attn.wq", "attn.wo", "mlp.w_gate", "mlp.w_down",
                   "lm_head"}),
    ("zamba2-1.2b", {"ssm.in_proj", "ssm.out_proj", "attn.wq", "mlp.w_up"}),
    ("rwkv6-1.6b", {"rwkv.tm.wr", "rwkv.tm.wk", "rwkv.tm.decay_a",
                    "rwkv.tm.wo", "rwkv.cm.wk", "rwkv.cm.wv"}),
])
def test_every_module_role_bit_identical(arch, expect):
    cfg = _cfg(arch)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    qp = serving.quantize_params_for_serving(
        params, cfg, r=3.0, act_bits=6, pack_planes=True,
        plane_count=serving.LADDER_PLANE_COUNT)
    modules = _quantized_modules(qp)
    assert expect <= set(modules), sorted(modules)
    for role, leaf in sorted(modules.items()):
        k = leaf["w_q"].shape[0]
        x = jnp.asarray(RNG.standard_normal((2, k)), jnp.float32)
        y_ref = dispatch.serving_linear(x, leaf, "ref")
        for spec in PALLAS:
            y = dispatch.serving_linear(x, leaf, spec)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref),
                                          err_msg=f"{arch}:{role}:{spec}")


def test_dispatch_tracks_float_dequant():
    """Backend-vs-backend equality can't catch a shared sign/zcol/bias bug;
    the integer dataflow must also approximate the float dequant product."""
    leaf = _leaf(128, 96, r=8.0, act_bits=8, bias=True)
    x = jnp.asarray(RNG.standard_normal((4, 128)), jnp.float32)
    y = np.asarray(dispatch.serving_linear(x, leaf, "ref"))
    w = leaf["w_q"].astype(jnp.float32) * leaf["w_scale"]
    y_fp = np.asarray(x @ w + leaf["b"])
    denom = np.abs(y_fp).mean() + 1e-9
    assert np.abs(y - y_fp).mean() / denom < 0.05


def test_zero_point_bounded_for_nonspanning_activations():
    """Regression: activations that do not span zero (post-ReLU-ish values
    near 100) must NOT overflow the zero point — the calibration range is
    extended to include 0, bounding z to [0, n]. Before the fix zcol
    wrapped int32 and the ref backend returned garbage/zeros."""
    leaf = _leaf(64, 32, r=8.0, act_bits=8, bias=False)
    x = jnp.asarray(100.0 + 1e-6 * RNG.standard_normal((4, 64)), jnp.float32)
    y = np.asarray(dispatch.serving_linear(x, leaf, "ref"))
    w = leaf["w_q"].astype(jnp.float32) * leaf["w_scale"]
    y_fp = np.asarray(x @ w)
    denom = np.abs(y_fp).mean() + 1e-9
    assert np.abs(y - y_fp).mean() / denom < 0.05
    for spec in PALLAS:   # and the backends still agree bitwise
        np.testing.assert_array_equal(
            np.asarray(dispatch.serving_linear(x, leaf, spec)), y)


def test_colsum_leaf_matches_recomputation():
    """w_colsum is precomputed in the artifact; a hand-built leaf without
    it must fall back to recomputing and produce identical outputs."""
    leaf = _leaf(48, 24, act_bits=6)
    assert leaf["w_colsum"].dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(leaf["w_colsum"]),
        np.asarray(jnp.sum(leaf["w_q"].astype(jnp.int32), axis=0)))
    stripped = {kk: v for kk, v in leaf.items() if kk != "w_colsum"}
    x = jnp.asarray(RNG.standard_normal((3, 48)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(dispatch.serving_linear(x, leaf, "ref")),
        np.asarray(dispatch.serving_linear(x, stripped, "ref")))


def test_fallback_off_tpu_is_ref():
    leaf = _leaf(64, 32)
    assert dispatch.resolve_backend("fused", leaf) == ("ref", False)
    assert dispatch.resolve_backend("fused:force", leaf) == ("fused", True)
    x = jnp.asarray(RNG.standard_normal((4, 64)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(dispatch.serving_linear(x, leaf, "fused")),
        np.asarray(dispatch.serving_linear(x, leaf, "ref")))


def test_parse_backend_rejects_typos():
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.parse_backend("fast")
    with pytest.raises(ValueError, match="unknown backend option"):
        dispatch.parse_backend("fused:interpret")


def test_packed_without_planes_is_a_build_error():
    node = {"w": jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)}
    leaf = serving.quantize_params_for_serving({"wq": node}, _cfg(),
                                               r=2.0)["wq"]
    x = jnp.asarray(RNG.standard_normal((2, 32)), jnp.float32)
    with pytest.raises(ValueError, match="pack_planes=True"):
        dispatch.serving_linear(x, leaf, "packed:force")


def test_variant_cache_pins_plane_count_across_rungs():
    cfg = _cfg()
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="plane_count"):
        serving.build_variant_cache(params, cfg, {2: 1.1, 4: 3.7},
                                    pack_planes=True)


def test_legacy_backend_none_is_unchanged():
    """backend=None must stay bit-exact with the pre-dispatch float path."""
    leaf = _leaf(48, 32, act_bits=None, bias=True)
    x = jnp.asarray(RNG.standard_normal((3, 48)), jnp.float32)
    y = L.apply_linear(x, leaf, None, backend=None)
    w = (leaf["w_q"].astype(jnp.float32) * leaf["w_scale"]).astype(x.dtype)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x @ w + leaf["b"]))


@pytest.mark.parametrize("allocation", ["uniform", "layerwise"])
def test_ladder_bitwise_across_backends_no_recompile(allocation):
    """The acceptance gate: every rung of a uniform AND a layerwise ladder
    decodes bit-identically (fp32 logits) through all three backends, each
    with exactly one compiled decode step surviving mixed-rung traffic."""
    cfg = _cfg()
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    logits, engines = {}, {}
    for spec in ("ref",) + PALLAS:
        eng = ServeEngine(cfg, params, ladder_bits=(2, 4), max_batch=2,
                          max_len=6, allocation=allocation, backend=spec)
        eng.warmup()
        state = eng._init_state(2)
        tok = jnp.zeros((2, 1), jnp.int32)
        per_rung = []
        for bits in (2, 4, 2):       # revisit rung 2: pointer-swap switching
            lg, state = eng._step(eng.variants[bits], state, tok)
            per_rung.append(np.asarray(lg))
        logits[spec] = np.stack(per_rung)
        engines[spec] = eng
    for spec in PALLAS:
        np.testing.assert_array_equal(logits[spec], logits["ref"],
                                      err_msg=f"{allocation}:{spec}")
    reqs = [Request(uid=i,
                    prompt=np.asarray([1, 2], np.int32),
                    max_new_tokens=2, power_budget_bits=[2, 4][i % 2])
            for i in range(4)]
    for spec, eng in engines.items():
        toks = [r.tokens for r in eng.generate(reqs)]
        eng.assert_no_recompile()
        assert eng.describe()["backend"] == spec
        if spec != "ref":
            ref_toks = [r.tokens for r in engines["ref"].generate(reqs)]
            assert toks == ref_toks, spec


def test_kernel_bench_check_baseline_logic():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import kernel_bench
    base = {"invariants": {
        "shape": {"m": 1}, "hbm_bytes_per_weight": {"int8_codes": 1.0},
        "parity": {"a": {"exact": True, "max_abs_diff": 0.0}}}}
    good = {"invariants": {
        "shape": {"m": 1}, "hbm_bytes_per_weight": {"int8_codes": 1.0},
        "parity": {"a": {"exact": True, "max_abs_diff": 0.0}}}}
    import json
    import tempfile
    with tempfile.NamedTemporaryFile("w", suffix=".json",
                                     delete=False) as f:
        json.dump(base, f)
        path = f.name
    assert kernel_bench.check_baseline(good, path) == []
    bad = json.loads(json.dumps(good))
    bad["invariants"]["parity"]["a"] = {"exact": False,
                                       "max_abs_diff": 0.25}
    assert any("parity broken" in m
               for m in kernel_bench.check_baseline(bad, path))
    drift = json.loads(json.dumps(good))
    drift["invariants"]["shape"] = {"m": 2}
    assert any("drifted" in m
               for m in kernel_bench.check_baseline(drift, path))
    os.unlink(path)
