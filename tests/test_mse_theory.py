"""Validate the §5.3 quantization-error theory against Monte-Carlo simulation
(the paper's Fig. 4 / Fig. 16 experiments)."""
import numpy as np
import pytest

from repro.core import mse as m
from repro.core import power as pw


RNG = np.random.default_rng(0)


@pytest.mark.parametrize("bx,bw", [(4, 4), (6, 4), (8, 8), (3, 3)])
def test_mse_ruq_matches_monte_carlo(bx, bw):
    d = 512
    theory = m.mse_ruq(d, bx, bw)
    mc = m.mc_mse_ruq(RNG, d, bx, bw, n=4096)
    assert mc == pytest.approx(theory, rel=0.25)


@pytest.mark.parametrize("bx,r", [(4, 2.0), (6, 1.5), (8, 4.0)])
def test_mse_pann_matches_monte_carlo(bx, r):
    d = 512
    theory = m.mse_pann(d, bx, r)
    mc = m.mc_mse_pann(RNG, d, bx, r, n=4096)
    assert mc == pytest.approx(theory, rel=0.3)


def test_fig4_pann_beats_ruq_at_low_bits():
    """Fig. 4: the MSE ratio RUQ/PANN exceeds 1 at low bit widths."""
    for b in [2, 3, 4]:
        assert m.mse_ratio_at_budget(b) > 1.0
    # and RUQ becomes relatively better at high bit widths
    assert m.mse_ratio_at_budget(8) < m.mse_ratio_at_budget(2)


def test_optimal_bx_increases_with_power():
    """Fig. 16: the optimal b~x grows with the power budget."""
    budgets = [pw.p_mac_unsigned(b) for b in (2, 4, 8)]
    bxs = [m.optimal_bx_tilde(p)[0] for p in budgets]
    assert bxs == sorted(bxs)
    assert bxs[-1] > bxs[0]


def test_eq19_equals_eq18_after_substitution():
    d, p = 128.0, 24.0
    for bx in range(2, 9):
        r = pw.pann_r_for_budget(p, bx)
        if r <= 0:
            continue
        assert m.mse_pann_at_budget(d, p, bx) == pytest.approx(
            m.mse_pann(d, bx, r))


def test_gaussian_setting_qualitative():
    """Fig. 4 right: in the Gaussian setting PANN also wins at low budgets."""
    d = 256
    b = 3
    budget = pw.p_mac_unsigned(b)
    bx, _ = m.optimal_bx_tilde(budget, d)
    r = pw.pann_r_for_budget(budget, bx)
    ruq = m.mc_mse_ruq(RNG, d, b, b, n=4096, dist="gauss")
    pann = m.mc_mse_pann(RNG, d, bx, r, n=4096, dist="gauss")
    assert pann < ruq
