"""End-to-end behaviour tests: training converges, PANN beats RUQ at low
power (the paper's core claim), serving works, checkpoint-resume is exact."""
import sys

import pytest

sys.path.insert(0, ".")  # for benchmarks.common

from benchmarks.common import eval_accuracy, train_small_lm  # noqa: E402
from repro.configs.base import QuantConfig  # noqa: E402
from repro.core import planner  # noqa: E402


@pytest.fixture(scope="module")
def trained_lm():
    return train_small_lm(steps=150, seed=0)


def test_training_learns_structure(trained_lm):
    acc = eval_accuracy(trained_lm, QuantConfig(mode="none"))
    # structured stream: 85% of transitions are the deterministic bigram
    assert acc > 0.6, acc


def test_pann_beats_ruq_at_2bit_budget(trained_lm):
    """The paper's central experimental claim (Table 2, bottom rows): at the
    power budget of a 2-bit MAC, regular quantization collapses while PANN
    stays near full precision."""
    fp = eval_accuracy(trained_lm, QuantConfig(mode="none"))
    ruq = eval_accuracy(trained_lm, QuantConfig(mode="ruq_unsigned",
                                                weight_bits=2, act_bits=2))
    budget = planner.budget_from_bits(2)
    plan = planner.plan_with_eval(
        budget, lambda b, r: eval_accuracy(
            trained_lm, QuantConfig(mode="pann", r=r, act_bits_tilde=b)))
    assert plan.score > ruq + 0.2, (plan.score, ruq)
    assert plan.score > fp - 0.1, (plan.score, fp)
    # and the planned config uses more activation bits + few additions,
    # as the theory predicts for low budgets (Fig. 16)
    assert plan.b_x_tilde >= 3


def test_power_accuracy_tradeoff_is_traversable(trained_lm):
    """Fig. 1 / Fig. 3: accuracy improves monotonically-ish with budget
    without any architecture change (same weights, different (b~x, R))."""
    accs = []
    for bits in [2, 4, 8]:
        plan = planner.plan_with_theory(planner.budget_from_bits(bits))
        accs.append(eval_accuracy(
            trained_lm, QuantConfig(mode="pann", r=plan.r,
                                    act_bits_tilde=plan.b_x_tilde)))
    assert accs[-1] >= accs[0] - 0.02


def test_train_cli_end_to_end(tmp_path):
    from repro.launch import train
    summary = train.main([
        "--arch", "llama3-8b", "--reduced", "--steps", "30",
        "--batch", "8", "--seq", "32", "--lr", "2e-3",
        "--ckpt_dir", str(tmp_path / "ck"), "--ckpt_every", "10"])
    assert summary["last_loss"] < summary["first_loss"]


def test_train_resume_is_exact(tmp_path):
    """Kill at step 20, resume from checkpoint, final state must equal the
    uninterrupted run (deterministic data + saved optimizer state)."""
    from repro.launch import train
    args = ["--arch", "llama3-8b", "--reduced", "--batch", "4",
            "--seq", "32", "--lr", "1e-3", "--ckpt_every", "10",
            "--total_steps", "20"]
    full = train.main(args + ["--steps", "20",
                              "--ckpt_dir", str(tmp_path / "a")])
    # interrupted: run 10 steps, then "restart" the process and continue
    train.main(args + ["--steps", "10", "--ckpt_dir", str(tmp_path / "b")])
    resumed = train.main(args + ["--steps", "20",
                                 "--ckpt_dir", str(tmp_path / "b")])
    assert resumed["last_loss"] == pytest.approx(full["last_loss"], rel=1e-5)


def test_serve_cli_all_families():
    from repro.launch import serve
    for arch in ["gemma2-9b", "seamless-m4t-medium",
                 "llama-3.2-vision-90b", "zamba2-1.2b"]:
        s = serve.main(["--arch", arch, "--reduced", "--batch", "2",
                        "--prompt_len", "8", "--gen", "4",
                        "--quant", "pann", "--power_bits", "4"])
        assert s["generated"] == 4, arch


def test_qat_improves_over_ptq_at_2bit():
    """Paper §6: using PANN during training beats post-training conversion
    at very low budgets."""
    budget = planner.budget_from_bits(2)
    plan = planner.plan_with_theory(budget)
    qc = QuantConfig(mode="pann", r=plan.r, act_bits_tilde=plan.b_x_tilde,
                     qat=True)
    tl_qat = train_small_lm(steps=150, qat_quant=qc, seed=0)
    qat_acc = eval_accuracy(tl_qat, qc)
    tl_fp = train_small_lm(steps=150, seed=0)
    ptq_acc = eval_accuracy(tl_fp, qc)
    assert qat_acc >= ptq_acc - 0.02, (qat_acc, ptq_acc)
