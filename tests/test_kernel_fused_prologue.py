"""Fused act-quant prologue + block autotuner (kernels/pann_matmul act
entry points, kernels/autotune, the hoisted act_s/act_z artifact leaves):
bit-exactness vs the ref oracle across dynamic and export-frozen calibrated
ranges, odd shapes through the padding path, cache semantics, and the
no-recompile invariant with the autotuner active."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import policy as pol
from repro.kernels import autotune, dispatch, ops, ref
from repro.models import serving
from repro.serve_engine import Request, ServeEngine

RNG = np.random.default_rng(7)
PALLAS = ("fused:force", "packed:force")


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Redirect the persistent autotune cache to a throwaway file."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def _cfg():
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    return dataclasses.replace(cfg, quant=QuantConfig(mode="none"))


def _leaf(k, n, act_bits=6, calib_range=None):
    node = {"w": jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)}
    calib = None
    if calib_range is not None:
        calib = {pol.serving_path(("wq",)): calib_range}
    qp = serving.quantize_params_for_serving(
        {"wq": node}, _cfg(), r=3.0, act_bits=act_bits, pack_planes=True,
        calib=calib)
    return qp["wq"]


# ---------------------------------------------------------------------------
# parity: dynamic AND export-frozen calibrated ranges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("calib_range", [None, (-1.5, 2.25), (0.5, 4.0)])
def test_fused_prologue_bit_identical(calib_range):
    """Pallas backends (fp activations in, codes encoded in VMEM) must match
    the ref oracle bit-for-bit, for the dynamic per-batch range and for
    frozen calibration — including a non-zero-spanning range whose zero
    extension bounds z."""
    leaf = _leaf(72, 56, act_bits=8, calib_range=calib_range)
    x = jnp.asarray(RNG.standard_normal((2, 3, 72)), jnp.float32)
    y_ref = jax.jit(lambda x, p: dispatch.serving_linear(x, p, "ref"))(
        x, leaf)
    for spec in PALLAS:
        y = jax.jit(lambda x, p: dispatch.serving_linear(x, p, spec))(
            x, leaf)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref),
                                      err_msg=f"{spec}:{calib_range}")


def test_hoisted_calibration_scalars_bit_exact():
    """The build-time-hoisted act_s/act_z leaves and the serve-time
    derivation from act_lo/act_hi are the same f32 op sequence — stripping
    the hoist must not change a single bit on any backend."""
    leaf = _leaf(64, 48, act_bits=8, calib_range=(-2.0, 3.0))
    assert "act_s" in leaf and "act_z" in leaf
    stripped = {k: v for k, v in leaf.items() if k not in ("act_s", "act_z")}
    x = jnp.asarray(RNG.standard_normal((4, 64)), jnp.float32)
    for spec in ("ref",) + PALLAS:
        np.testing.assert_array_equal(
            np.asarray(dispatch.serving_linear(x, leaf, spec)),
            np.asarray(dispatch.serving_linear(x, stripped, spec)),
            err_msg=spec)


def test_unseen_calibration_stays_dynamic():
    """lo > hi marks a role the training run never observed: the artifact
    carries no frozen leaves and the backends fall back to the dynamic
    range, still bit-identically."""
    leaf = _leaf(64, 32, act_bits=6, calib_range=(1.0, -1.0))
    assert "act_lo" not in leaf and "act_s" not in leaf
    x = jnp.asarray(RNG.standard_normal((3, 64)), jnp.float32)
    y_ref = dispatch.serving_linear(x, leaf, "ref")
    for spec in PALLAS:
        np.testing.assert_array_equal(
            np.asarray(dispatch.serving_linear(x, leaf, spec)),
            np.asarray(y_ref), err_msg=spec)


# ---------------------------------------------------------------------------
# odd shapes through the pad-to-block path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (5, 60, 40),      # K % 8 != 0 (pack_planes pads), ragged M and N
    (1, 129, 257),    # decode row count 1, everything prime-ish
    (7, 72, 48),      # M not a multiple of any MXU-aligned bm
])
def test_odd_shapes_bit_identical(m, k, n):
    leaf = _leaf(k, n, act_bits=6)
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    y_ref = dispatch.serving_linear(x, leaf, "ref")
    for spec in PALLAS:
        np.testing.assert_array_equal(
            np.asarray(dispatch.serving_linear(x, leaf, spec)),
            np.asarray(y_ref), err_msg=spec)


def test_cached_blocks_force_ragged_m_padding(tmp_cache):
    """Plant cache entries whose bm does NOT divide M, so serving_linear
    runs the fused-prologue kernels through the pad-rows path (padded fp32
    zeros encode to the code z against zero plane rows — an exact no-op)."""
    k, n, m = 64, 48, 6
    leaf = _leaf(k, n, act_bits=8)
    n_planes = leaf["w_planes_pos"].shape[-3]
    k_full = leaf["w_planes_pos"].shape[-2] * 8
    autotune.record(m, k, n, n_planes, "fused", (4, 48, 64))
    autotune.record(m, k_full, n, n_planes, "packed", (4, 48, 64))
    assert autotune.blocks_for(m, k, n, n_planes, "fused") == (4, 48, 64)
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    y_ref = dispatch.serving_linear(x, leaf, "ref")
    for spec in PALLAS:
        np.testing.assert_array_equal(
            np.asarray(dispatch.serving_linear(x, leaf, spec)),
            np.asarray(y_ref), err_msg=spec)


def test_quantize_act_ragged_and_platform_default():
    """Ragged M pads up and slices back (bit-identical to the oracle), and
    interpret=None resolves by platform instead of the old unconditional
    interpret=True."""
    x = jnp.abs(jnp.asarray(RNG.standard_normal((13, 40)), jnp.float32))
    q, s = ops.quantize_act(x, bits=8)          # interpret resolved inside
    q_ref, s_ref = ref.quantize_act_ref(x, 8)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))


# ---------------------------------------------------------------------------
# autotune cache semantics
# ---------------------------------------------------------------------------

def test_heuristic_respects_vmem_budget():
    for packed in (False, True):
        for (m, n, k) in [(4, 4096, 8192), (256, 512, 512), (1, 64, 48)]:
            bm, bn, bk = autotune.heuristic_blocks(m, n, k, packed=packed)
            assert autotune.vmem_bytes(bm, bn, bk, k, packed) \
                <= 8 * 2 ** 20, (m, n, k, packed)
            if packed and k >= 8:
                assert bk % 8 == 0


def test_candidate_grid_fits_budget_and_contains_heuristic():
    cands = autotune.candidate_blocks(64, 256, 1024, 7)
    assert autotune.heuristic_blocks(64, 256, 1024, 7) in cands
    for bm, bn, bk in cands:
        assert autotune.vmem_bytes(bm, bn, bk, 1024, False) <= 8 * 2 ** 20


def test_record_persists_and_survives_process_cache_drop(tmp_cache):
    assert autotune.blocks_for(8, 64, 32, 7, "fused") == \
        autotune.heuristic_blocks(8, 32, 64, 7)
    autotune.record(8, 64, 32, 7, "fused", (8, 32, 64))
    autotune.clear_memory_cache()               # force a disk re-read
    assert autotune.blocks_for(8, 64, 32, 7, "fused") == (8, 32, 64)
    on_disk = json.loads(tmp_cache.read_text())
    assert on_disk["version"] == autotune.CACHE_VERSION
    key = autotune.cache_key(8, 64, 32, 7, "fused")
    assert on_disk["blocks"][key] == {"blocks": [8, 32, 64], "depth": 2,
                                      "order": "mnk"}


def test_corrupt_or_foreign_cache_is_ignored(tmp_cache):
    tmp_cache.write_text("{ not json")
    assert autotune.blocks_for(8, 64, 32, 7, "fused") == \
        autotune.heuristic_blocks(8, 32, 64, 7)
    autotune.clear_memory_cache()
    tmp_cache.write_text(json.dumps(
        {"version": 999, "blocks": {autotune.cache_key(
            8, 64, 32, 7, "fused"): [1, 1, 1]}}))
    assert autotune.blocks_for(8, 64, 32, 7, "fused") == \
        autotune.heuristic_blocks(8, 32, 64, 7)


def test_tune_off_tpu_records_heuristic_and_short_circuits(tmp_cache):
    calls = []
    best = autotune.tune(4, 128, 64, 7, "fused",
                         runner=lambda b: calls.append(b) or 1.0)
    assert best == autotune.heuristic_params(4, 64, 128, 7)
    assert best.blocks == autotune.heuristic_blocks(4, 64, 128, 7)
    assert calls == []          # off-TPU: never timed, emulator noise
    # cached entry short-circuits without consulting the runner either
    assert autotune.tune(4, 128, 64, 7, "fused",
                         runner=lambda b: 1 / 0) == best


def test_tune_projection_fills_cache_for_real_artifacts(tmp_cache):
    leaf = _leaf(64, 48, act_bits=8)
    n_planes = leaf["w_planes_pos"].shape[-3]
    k_full = leaf["w_planes_pos"].shape[-2] * 8
    dispatch.tune_projection(4, leaf, "packed:force")
    assert autotune.cache_key(4, k_full, 48, n_planes, "packed") in \
        json.loads(tmp_cache.read_text())["blocks"]
    dispatch.tune_projection(4, leaf, "ref")    # ref: nothing to tune
    assert len(json.loads(tmp_cache.read_text())["blocks"]) == 1


# ---------------------------------------------------------------------------
# the engine invariant with the autotuner active
# ---------------------------------------------------------------------------

def test_engine_autotune_no_recompile(tmp_cache):
    """ServeEngine(autotune=True) tunes strictly before warmup; blocks_for
    is pure at trace time, so mixed-rung traffic still decodes through ONE
    compiled step — and the tuner actually populated the cache."""
    cfg = _cfg()
    from repro.models import model as MD
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, ladder_bits=(2, 4), max_batch=2,
                      max_len=6, backend="packed:force", autotune=True)
    eng.warmup()
    assert tmp_cache.exists()
    assert len(json.loads(tmp_cache.read_text())["blocks"]) > 0
    reqs = [Request(uid=i, prompt=np.asarray([1, 2], np.int32),
                    max_new_tokens=2, power_budget_bits=[2, 4][i % 2])
            for i in range(4)]
    eng.generate(reqs)
    eng.assert_no_recompile()
