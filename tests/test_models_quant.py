"""PANN / RUQ quantization through full model forward + QAT gradients."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import model as MD

QUANT_ARCHS = ["llama3-8b", "mixtral-8x7b", "rwkv6-1.6b", "zamba2-1.2b"]


def _setup(arch, quant):
    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, quant=quant)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    return cfg, params, tokens


@pytest.mark.parametrize("arch", QUANT_ARCHS)
@pytest.mark.parametrize("mode", ["ruq", "ruq_unsigned", "pann"])
def test_quantized_forward_finite_and_close(arch, mode):
    qc = QuantConfig(mode=mode, weight_bits=8, act_bits=8, r=4.0,
                     act_bits_tilde=8)
    cfg, params, tokens = _setup(arch, qc)
    out_q = jax.jit(lambda p, t: MD.forward(p, cfg, t, remat=False))(
        params, tokens)
    cfg_fp = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    out_fp = jax.jit(lambda p, t: MD.forward(p, cfg_fp, t, remat=False))(
        params, tokens)
    assert bool(jnp.isfinite(out_q.logits).all())
    # 8-bit / R=4 quantization should track the fp logits reasonably
    denom = float(jnp.abs(out_fp.logits).mean()) + 1e-6
    err = float(jnp.abs(out_q.logits - out_fp.logits).mean()) / denom
    assert err < 0.6, f"{arch}/{mode}: rel err {err}"


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b"])
def test_pann_qat_grads(arch):
    qc = QuantConfig(mode="pann", r=2.0, act_bits_tilde=6, qat=True)
    cfg, params, tokens = _setup(arch, qc)
    labels = tokens
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: MD.lm_loss(p, cfg, tokens, labels)))(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0


def test_pann_ruq_unsigned_equals_ruq():
    """ruq and ruq_unsigned are the same numbers (Eq. 5-6 exactness)."""
    a = QuantConfig(mode="ruq", weight_bits=6, act_bits=6)
    b = QuantConfig(mode="ruq_unsigned", weight_bits=6, act_bits=6)
    cfg_a, params, tokens = _setup("llama3-8b", a)
    cfg_b = dataclasses.replace(cfg_a, quant=b)
    oa = MD.forward(params, cfg_a, tokens, remat=False)
    ob = MD.forward(params, cfg_b, tokens, remat=False)
    np.testing.assert_array_equal(np.asarray(oa.logits),
                                  np.asarray(ob.logits))


def test_lower_power_more_error():
    """Lower PANN budgets (smaller R) give larger logit error — the
    power-accuracy trade-off is monotone end to end."""
    errs = []
    for r in [8.0, 1.0, 0.25]:
        qc = QuantConfig(mode="pann", r=r, act_bits_tilde=8)
        cfg, params, tokens = _setup("llama3-8b", qc)
        cfg_fp = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
        oq = MD.forward(params, cfg, tokens, remat=False)
        ofp = MD.forward(params, cfg_fp, tokens, remat=False)
        errs.append(float(jnp.abs(oq.logits - ofp.logits).mean()))
    assert errs[0] < errs[1] < errs[2], errs
