"""Power-aware training: budget annealing, EMA calibration, checkpoint
round-trip of quant/calibration state, and the train→serve export loop.

The heavyweight piece is a module-scoped fixture that runs a real (tiny)
``launch/train.py`` invocation across two budget knots; the tests then
assert the properties the ISSUE demands:

  * resuming a mid-anneal checkpoint continues the loss trajectory
    BIT-exactly and replans the allocator identically,
  * the exported serving artifact reproduces the training-time eval loss,
  * calibration state is checkpointed and EMA-updated deterministically.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import QuantConfig
from repro.core import anneal
from repro.core import calibrate as CAL
from repro.launch import export as EX
from repro.launch import train as TR

ARCH = "llama3-8b"
SCHEDULE = "0:fp,2:8,5:6"
STEPS = 8
BASE_ARGS = ["--arch", ARCH, "--reduced", "--batch", "2", "--seq", "16",
             "--quant", "pann", "--train_quant", "qat",
             "--budget_schedule", SCHEDULE, "--allocation", "layerwise",
             "--lr", "1e-2", "--log_every", "100"]


def _train(ckpt_dir, steps, extra=()):
    return TR.main(BASE_ARGS + ["--ckpt_dir", str(ckpt_dir),
                                "--steps", str(steps),
                                "--total_steps", str(STEPS),
                                "--ckpt_every", "4", *extra])


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    ckpt_dir = tmp_path_factory.mktemp("ck_full")
    summary = _train(ckpt_dir, STEPS)
    return str(ckpt_dir), summary


# ---------------------------------------------------------------------------
# Budget schedule / annealer
# ---------------------------------------------------------------------------

def test_schedule_parse_and_segments():
    s = anneal.BudgetSchedule.parse("0:fp,4:8,12:6")
    assert s.bits_at(0) == 0 and s.bits_at(3) == 0
    assert s.bits_at(4) == 8 and s.bits_at(11) == 8
    assert s.bits_at(12) == 6 and s.bits_at(999) == 6
    assert s.segments(0, 18) == ((0, 4, 0), (4, 12, 8), (12, 18, 6))
    # resume mid-segment: same budgets, clipped spans
    assert s.segments(6, 18) == ((6, 12, 8), (12, 18, 6))
    assert s.segments(5, 5) == ()
    assert s.knot_steps() == (4, 12)


@pytest.mark.parametrize("bad", ["", "4", "4:8,2:6", "x:8", "3:-1", "3:8.5"])
def test_schedule_parse_rejects(bad):
    with pytest.raises(ValueError):
        anneal.BudgetSchedule.parse(bad)


def test_annealer_replan_is_deterministic():
    cfg = configs.reduced(configs.get_config(ARCH))
    mk = lambda: anneal.BudgetAnnealer(
        anneal.BudgetSchedule.parse(SCHEDULE), cfg, allocation="layerwise")
    a, b = mk(), mk()
    for bits in (8, 6):
        ta, tb = a.tree_for(bits), b.tree_for(bits)
        assert ta == tb                       # frozen dataclass equality
        assert a.gbitflips_per_token(bits) == b.gbitflips_per_token(bits)
    # fp segment strips quantization from the forward
    cfg_fp, plan, bits = a.config_at(cfg, 0)
    assert bits == 0 and plan is None
    assert cfg_fp.policy is None and cfg_fp.quant.mode == "none"
    cfg_q, plan, bits = a.config_at(cfg, 7)
    assert bits == 6 and cfg_q.policy is plan.tree


# ---------------------------------------------------------------------------
# Tri-state --train_quant validation
# ---------------------------------------------------------------------------

def _args(**kw):
    ns = dict(quant="none", train_quant="", budget_schedule="")
    ns.update(kw)
    import types
    return types.SimpleNamespace(**ns)


def test_train_quant_tri_state():
    assert TR.resolve_train_quant(_args()) == "none"
    assert TR.resolve_train_quant(_args(quant="pann")) == "qat"   # legacy
    assert TR.resolve_train_quant(_args(quant="pann",
                                        train_quant="ptq")) == "ptq"
    with pytest.raises(ValueError):   # qat needs a scheme
        TR.resolve_train_quant(_args(train_quant="qat"))
    with pytest.raises(ValueError):   # scheme + none is ambiguous
        TR.resolve_train_quant(_args(quant="pann", train_quant="none"))
    with pytest.raises(ValueError):   # schedule needs qat
        TR.resolve_train_quant(_args(quant="pann", train_quant="ptq",
                                     budget_schedule="0:8"))
    with pytest.raises(ValueError):   # schedule plans PANN points
        TR.resolve_train_quant(_args(quant="ruq", train_quant="qat",
                                     budget_schedule="0:8"))


# ---------------------------------------------------------------------------
# EMA calibration collection
# ---------------------------------------------------------------------------

def test_calib_ema_semantics():
    cfg = configs.reduced(configs.get_config(ARCH))
    calib = CAL.init_calib(cfg)
    assert "attn.wq" in calib and "lm_head" in calib
    assert "ssm.conv" not in calib
    assert not bool(CAL.seen(calib["attn.wq"]))

    obs = CAL.unseen_like(calib)
    obs["attn.wq"] = jnp.asarray([-1.0, 2.0], jnp.float32)
    # first observation is adopted outright
    c1 = CAL.ema_update(calib, obs, decay=0.9)
    np.testing.assert_allclose(np.asarray(c1["attn.wq"]), [-1.0, 2.0])
    # unseen observation leaves the range untouched
    np.testing.assert_array_equal(np.asarray(c1["mlp.w_up"]),
                                  np.asarray(calib["mlp.w_up"]))
    # subsequent observations blend with the decay
    obs2 = dict(obs, **{"attn.wq": jnp.asarray([-3.0, 1.0], jnp.float32)})
    c2 = CAL.ema_update(c1, obs2, decay=0.9)
    np.testing.assert_allclose(np.asarray(c2["attn.wq"]),
                               [0.9 * -1.0 + 0.1 * -3.0,
                                0.9 * 2.0 + 0.1 * 1.0], rtol=1e-6)
    # merge takes the envelope
    merged = CAL.merge(obs, {"attn.wq": jnp.asarray([-0.5, 3.0])})
    np.testing.assert_allclose(np.asarray(merged["attn.wq"]), [-1.0, 3.0])


def test_serving_freezes_calibrated_ranges():
    from repro.launch import steps as ST
    from repro.models import serving
    import jax

    cfg = configs.reduced(configs.get_config(
        ARCH, quant=QuantConfig(mode="pann", r=2.0, qat=True)))
    key = jax.random.PRNGKey(0)
    state = ST.make_train_state(key, cfg, TR.TrainConfig(), calibrate=True)
    calib = dict(state.calib)
    calib["attn.wq"] = jnp.asarray([-1.5, 1.5], jnp.float32)  # seen
    v = serving.quantize_params_for_serving(state.params, cfg, r=2.0,
                                            act_bits=6, calib=calib)
    wq_leaf = v["decoder"]["groups"]["layers"][0]["attn"]["wq"]
    assert "act_lo" in wq_leaf and "act_hi" in wq_leaf
    assert float(wq_leaf["act_lo"].reshape(-1)[0]) == -1.5
    # unseen role stays dynamic (no frozen-range leaves)
    wo_leaf = v["decoder"]["groups"]["layers"][0]["attn"]["wo"]
    assert "act_lo" not in wo_leaf and "act_n" in wo_leaf
    with pytest.raises(ValueError):   # range freeze needs a bit width
        serving.quantize_params_for_serving(state.params, cfg, r=2.0,
                                            calib=calib)


def test_moe_qat_calibration_suspends_expert_scan():
    """Expert projections run inside an inner lax.scan: observing them into
    the layer-stack tap would leak inner-trace values. The suspend guard
    keeps MoE QAT trainable — router calibrated, expert roles dynamic."""
    import jax
    from functools import partial
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.launch import steps as ST

    cfg = configs.reduced(configs.get_config(
        "mixtral-8x7b", quant=QuantConfig(mode="pann", r=2.0, qat=True)))
    tcfg = TrainConfig(total_steps=4)
    state = ST.make_train_state(jax.random.PRNGKey(0), cfg, tcfg,
                                calibrate=True)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    fn = jax.jit(partial(ST.train_step, cfg=cfg, tcfg=tcfg,
                         par=ParallelConfig(remat="none")))
    state, metrics = fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    seen = {k for k, v in state.calib.items()
            if float(v[0]) <= float(v[1])}
    assert "moe.router" in seen and "attn.wq" in seen
    assert not seen & {"moe.w_gate", "moe.w_up", "moe.w_down"}


def test_frozen_range_convention_is_shared():
    """A calibrated range that does not span zero is zero-extended the same
    way by the QAT fake-quant path and the kernel backends — the export
    gate must validate numerics deployment actually serves."""
    from repro.core import quant as Q
    x = jnp.asarray(np.linspace(0.4, 3.1, 64, dtype=np.float32))
    rng_lo, rng_hi = 0.5, 3.0
    q, s, z = Q.affine_from_range(x, 63.0, rng_lo, rng_hi)
    # zero-extension: lo pulled to 0 -> z == 0, scale covers [0, hi]
    assert float(z) == 0.0
    np.testing.assert_allclose(float(s), 3.0 / 63.0, rtol=1e-6)
    # the unseen sentinel still falls back to the UNextended dynamic range
    qd, sd, zd = Q.affine_from_range(x, 63.0, np.inf, -np.inf)
    qref, sref, zref = Q.affine_quant_levels(x, 63.0)
    np.testing.assert_array_equal(np.asarray(qd), np.asarray(qref))
    assert float(sd) == float(sref) and float(zd) == float(zref)


def test_dispatch_backends_honor_frozen_ranges():
    """The integer serving backends quantize against export-frozen ranges
    (act_lo/act_hi leaves) — and stay bit-identical to each other."""
    from repro.kernels import dispatch
    from repro.models import serving

    rng = np.random.default_rng(0)
    cfg = configs.reduced(configs.get_config(ARCH))
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    tree = {"wq": {"w": w}}
    kw = dict(r=2.0, act_bits=6, pack_planes=True)
    leaf_dyn = serving.quantize_params_for_serving(tree, cfg, **kw)["wq"]
    leaf_cal = serving.quantize_params_for_serving(
        tree, cfg, calib={"wq": np.asarray([-8.0, 8.0], np.float32)},
        **kw)["wq"]
    y_dyn = np.asarray(dispatch.serving_linear(x, leaf_dyn, "ref"))
    y_cal = np.asarray(dispatch.serving_linear(x, leaf_cal, "ref"))
    # a deliberately wide frozen range coarsens the quantizer vs the
    # batch's own extremes — outputs must differ (the range is honored)
    assert not np.allclose(y_dyn, y_cal)
    # cross-backend bit-exactness holds for calibrated artifacts too
    y_fused = np.asarray(dispatch.serving_linear(x, leaf_cal,
                                                 "fused:force"))
    y_packed = np.asarray(dispatch.serving_linear(x, leaf_cal,
                                                  "packed:force"))
    np.testing.assert_array_equal(y_cal, y_fused)
    np.testing.assert_array_equal(y_cal, y_packed)


def test_restore_fallback_is_scoped_to_calib(tmp_path):
    from repro.ckpt import checkpoint as ck
    old = {"params": {"w": np.ones((2, 2), np.float32)}}
    ck.save(str(tmp_path), 1, old)
    tmpl = {"params": {"w": np.zeros((2, 2), np.float32)},
            "calib": {"attn.wq": np.asarray(CAL.UNSEEN, np.float32)}}
    out = ck.restore(str(tmp_path), 1, tmpl, strict=("calib/",))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), 1.0)
    assert not bool(CAL.seen(out["calib"]["attn.wq"]))
    with pytest.raises(KeyError):      # default stays strict
        ck.restore(str(tmp_path), 1, tmpl)
    # a missing PARAM leaf never silently falls back under the scoped mode
    tmpl2 = {"params": {"w": np.zeros((2, 2), np.float32),
                        "extra": np.zeros((2,), np.float32)}}
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), 1, tmpl2, strict=("calib/",))


# ---------------------------------------------------------------------------
# The end-to-end properties (shared trained run)
# ---------------------------------------------------------------------------

def test_calibration_state_checkpointed(trained):
    ckpt_dir, _ = trained
    arrays = np.load(os.path.join(ckpt_dir, f"step_{STEPS:08d}",
                                  "arrays.npz"))
    calib_keys = [k for k in arrays.files if k.startswith("calib/")]
    assert "calib/attn.wq" in calib_keys
    lo, hi = arrays["calib/attn.wq"]
    assert np.isfinite([lo, hi]).all() and lo < hi


def test_mid_anneal_resume_bit_exact(trained, tmp_path):
    full_dir, full = trained
    ckpt_dir = tmp_path / "ck_resume"
    first = _train(ckpt_dir, 4)           # stops mid-anneal (8b segment)
    resumed = _train(ckpt_dir, STEPS)     # restarts from the step-4 ckpt
    assert first["losses"] == full["losses"][:4]
    # BIT-exact continuation: same losses, same final eval loss
    assert resumed["losses"] == full["losses"][4:]
    assert resumed["eval_loss"] == full["eval_loss"]
    # the resumed run replanned the allocator identically
    with open(os.path.join(full_dir, f"step_{STEPS:08d}",
                           "meta.json")) as f:
        meta_full = json.load(f)
    resumed_plans = {p["step"]: p for p in resumed["plans"]}
    for p in full["plans"]:
        if p["step"] >= 4 and p["step"] in resumed_plans:
            assert resumed_plans[p["step"]]["gbitflips_per_token"] == \
                p["gbitflips_per_token"]
    assert meta_full["eval_loss"] == resumed["eval_loss"]


def test_export_round_trip(trained, tmp_path):
    ckpt_dir, summary = trained
    out = str(tmp_path / "artifact")
    res = EX.main(["--ckpt_dir", ckpt_dir, "--out", out])
    assert res["bits"] == 6                       # the schedule's last knot
    assert res["loss_train_eval"] == summary["eval_loss"]
    assert res["rel_diff"] <= 1e-3                # fp32 round-trip
    # the artifact landed in checkpoint layout and restores as a tree
    from repro.ckpt import checkpoint as ck
    step = ck.latest_step(out)
    assert step == STEPS
    meta = ck.read_meta(out, step)
    assert meta["bits"] == 6 and "train_args" in meta


def test_export_rejects_fp_schedule_tail(tmp_path):
    ckpt_dir = tmp_path / "ck_fp"
    TR.main(["--arch", ARCH, "--reduced", "--batch", "2", "--seq", "16",
             "--quant", "pann", "--train_quant", "qat",
             "--budget_schedule", "0:fp", "--lr", "1e-2",
             "--log_every", "100", "--ckpt_dir", str(ckpt_dir),
             "--steps", "2", "--ckpt_every", "2"])
    with pytest.raises(SystemExit):
        EX.main(["--ckpt_dir", str(ckpt_dir)])


def test_ptq_trains_fp_but_exports_quantized(tmp_path):
    ckpt_dir = tmp_path / "ck_ptq"
    summary = TR.main(["--arch", ARCH, "--reduced", "--batch", "2",
                       "--seq", "16", "--quant", "pann",
                       "--train_quant", "ptq", "--steps", "3",
                       "--lr", "1e-2", "--log_every", "100",
                       "--ckpt_dir", str(ckpt_dir), "--ckpt_every", "3"])
    # no calibration collection for fp training
    arrays = np.load(os.path.join(str(ckpt_dir), "step_00000003",
                                  "arrays.npz"))
    assert not [k for k in arrays.files if k.startswith("calib/")]
    res = EX.main(["--ckpt_dir", str(ckpt_dir)])
    # PTQ pays a quantization gap; it is reported, not gated
    assert res["train_quant"] == "ptq"
    assert np.isfinite(res["loss_serve_eval"])
    assert summary["eval_loss"] == pytest.approx(res["loss_train_eval"])
