"""Packed bit-plane PANN kernel vs oracle + pack/unpack roundtrip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pann as pann_core
from repro.core.unsigned import unsigned_split
from repro.kernels import ref
from repro.kernels.pann_matmul_packed import (pack_planes, pann_matmul_packed,
                                              unpack_planes)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("k,n,p", [(128, 128, 3), (256, 128, 5), (64, 64, 1)])
def test_pack_unpack_roundtrip(k, n, p):
    planes = jnp.asarray(RNG.integers(0, 2, (p, k, n)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.shape == (p, k // 8, n) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_planes(packed, k)),
                                  np.asarray(planes))


@pytest.mark.parametrize("m,k,n,n_planes", [(128, 128, 128, 3),
                                            (128, 256, 128, 4),
                                            (256, 128, 256, 2)])
def test_packed_matmul_matches_oracle(m, k, n, n_planes):
    hi = 1 << n_planes
    w_q = jnp.asarray(RNG.integers(-(hi - 1), hi, (k, n)), jnp.float32)
    pos, neg = unsigned_split(w_q)
    pp = pann_core.bitplane_decompose(pos, n_planes)
    pn = pann_core.bitplane_decompose(neg, n_planes)
    x_q = jnp.asarray(RNG.integers(0, 128, (m, k)), jnp.int8)
    s_x = jnp.asarray(RNG.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    gamma = jnp.asarray(RNG.uniform(0.001, 0.01, (n,)), jnp.float32)
    got = pann_matmul_packed(x_q, pack_planes(pp), pack_planes(pn),
                             s_x, gamma, interpret=True)
    want = ref.pann_matmul_ref(x_q, pp, pn, s_x, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_packed_storage_is_8x_smaller():
    planes = jnp.asarray(RNG.integers(0, 2, (4, 512, 256)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.size * packed.dtype.itemsize \
        == planes.size * planes.dtype.itemsize // 8


# ---------------------------------------------------------------------------
# pack/unpack round-trip edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 7, 13, 8, 9, 127])
def test_roundtrip_k_not_divisible_by_8(k):
    planes = jnp.asarray(RNG.integers(0, 2, (3, k, 5)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.shape == (3, (k + 7) // 8, 5)
    np.testing.assert_array_equal(np.asarray(unpack_planes(packed, k)),
                                  np.asarray(planes))
    # the padded tail bits must be zero, or matmuls against padded x rows
    # would pick up phantom weights
    full = unpack_planes(packed, packed.shape[-2] * 8)
    np.testing.assert_array_equal(np.asarray(full[:, k:, :]), 0)


def test_roundtrip_single_plane_b_r_1():
    planes = jnp.asarray(RNG.integers(0, 2, (1, 16, 4)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.shape == (1, 2, 4)
    np.testing.assert_array_equal(np.asarray(unpack_planes(packed, 16)),
                                  np.asarray(planes))


def test_roundtrip_empty_planes_all_zero_codes():
    planes = jnp.zeros((4, 24, 6), jnp.int8)
    packed = pack_planes(planes)
    assert int(jnp.sum(packed)) == 0
    np.testing.assert_array_equal(np.asarray(unpack_planes(packed, 24)), 0)


def test_roundtrip_stacked_leading_dims():
    """Serving artifacts stack planes behind scan dims: (L, P, K, N)."""
    planes = jnp.asarray(RNG.integers(0, 2, (2, 3, 11, 4)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.shape == (2, 3, 2, 4)
    np.testing.assert_array_equal(np.asarray(unpack_planes(packed, 11)),
                                  np.asarray(planes))


def test_dtype_invariants():
    planes = jnp.asarray(RNG.integers(0, 2, (2, 9, 3)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.dtype == jnp.uint8
    un = unpack_planes(packed, 9)
    assert un.dtype == jnp.int8
    assert set(np.unique(np.asarray(un))) <= {0, 1}
    # float-typed {0,1} planes pack identically (quantizer output dtype)
    packed_f = pack_planes(planes.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(packed_f), np.asarray(packed))
