"""Packed bit-plane PANN kernel vs oracle + pack/unpack roundtrip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pann as pann_core
from repro.core.unsigned import unsigned_split
from repro.kernels import ref
from repro.kernels.pann_matmul_packed import (pack_planes, pann_matmul_packed,
                                              unpack_planes)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("k,n,p", [(128, 128, 3), (256, 128, 5), (64, 64, 1)])
def test_pack_unpack_roundtrip(k, n, p):
    planes = jnp.asarray(RNG.integers(0, 2, (p, k, n)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.shape == (p, k // 8, n) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_planes(packed, k)),
                                  np.asarray(planes))


@pytest.mark.parametrize("m,k,n,n_planes", [(128, 128, 128, 3),
                                            (128, 256, 128, 4),
                                            (256, 128, 256, 2)])
def test_packed_matmul_matches_oracle(m, k, n, n_planes):
    hi = 1 << n_planes
    w_q = jnp.asarray(RNG.integers(-(hi - 1), hi, (k, n)), jnp.float32)
    pos, neg = unsigned_split(w_q)
    pp = pann_core.bitplane_decompose(pos, n_planes)
    pn = pann_core.bitplane_decompose(neg, n_planes)
    x_q = jnp.asarray(RNG.integers(0, 128, (m, k)), jnp.int8)
    s_x = jnp.asarray(RNG.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    gamma = jnp.asarray(RNG.uniform(0.001, 0.01, (n,)), jnp.float32)
    got = pann_matmul_packed(x_q, pack_planes(pp), pack_planes(pn),
                             s_x, gamma, interpret=True)
    want = ref.pann_matmul_ref(x_q, pp, pn, s_x, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_packed_storage_is_8x_smaller():
    planes = jnp.asarray(RNG.integers(0, 2, (4, 512, 256)), jnp.int8)
    packed = pack_planes(planes)
    assert packed.size * packed.dtype.itemsize \
        == planes.size * planes.dtype.itemsize // 8
