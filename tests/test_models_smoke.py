"""Per-architecture smoke tests: reduced config, one forward + one train-grad
step + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as MD

ARCHS = list(configs.ARCH_NAMES)


def _inputs(cfg, batch=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_inputs"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        kwargs["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return tokens, labels, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.reduced(configs.get_config(arch))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    tokens, labels, kwargs = _inputs(cfg)
    out = jax.jit(lambda p, t: MD.forward(p, cfg, t, remat=False, **kwargs)
                  )(params, tokens)
    assert out.logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(out.logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = configs.reduced(configs.get_config(arch))
    params = MD.init_params(jax.random.PRNGKey(1), cfg)
    tokens, labels, kwargs = _inputs(cfg, seed=1)

    loss_fn = lambda p: MD.lm_loss(p, cfg, tokens, labels, **kwargs)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    gnorm = sum(float(jnp.sum(g ** 2)) for g in flat) ** 0.5
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.reduced(configs.get_config(arch))
    params = MD.init_params(jax.random.PRNGKey(2), cfg)
    tokens, _, kwargs = _inputs(cfg, seed=2)
    state = MD.init_decode_state(params, cfg, batch=2, max_len=32, **kwargs)
    step = jax.jit(lambda p, s, t: MD.decode_step(p, cfg, s, t))
    logits, state = step(params, state, tokens[:, :1])
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    logits2, state = step(params, state, tokens[:, 1:2])
    assert int(state.position) == 2
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-1.2b", "rwkv6-1.6b",
                                  "mixtral-8x7b", "gemma2-9b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward (prefill) logits."""
    cfg = configs.reduced(configs.get_config(arch))
    params = MD.init_params(jax.random.PRNGKey(3), cfg)
    tokens, _, kwargs = _inputs(cfg, batch=1, seq=8, seed=3)
    fwd = MD.forward(params, cfg, tokens, remat=False, **kwargs)
    state = MD.init_decode_state(params, cfg, batch=1, max_len=8, **kwargs)
    step = jax.jit(lambda p, s, t: MD.decode_step(p, cfg, s, t))
    outs = []
    for t in range(8):
        lg, state = step(params, state, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd.logits),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned numbers."""
    spec = {
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    }
    for name, (nl, d, h, kv, ff, v) in spec.items():
        c = configs.get_config(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (nl, d, h, kv, ff, v), name
    assert configs.get_config("qwen1.5-4b").qkv_bias
    assert configs.get_config("dbrx-132b").moe.num_experts == 16
    assert configs.get_config("dbrx-132b").moe.top_k == 4
    assert configs.get_config("mixtral-8x7b").moe.num_experts == 8
    assert configs.get_config("mixtral-8x7b").sliding_window == 4096
    assert configs.get_config("zamba2-1.2b").ssm_state == 64
    assert configs.get_config("gemma2-9b").local_global_period == 2
    assert configs.get_config("llama-3.2-vision-90b").cross_attn_period == 5
    assert configs.get_config("seamless-m4t-medium").encoder_layers == 12
