"""Multi-device distribution tests — run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag must never be
set in THIS process: smoke tests see 1 device, per the assignment)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def run_py(body: str) -> dict:
    """Run a snippet in a fresh 8-device process; it must print one JSON."""
    code = textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], env=ENV,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_compressed_allreduce_error_feedback():
    r = run_py("""
        import jax, jax.numpy as jnp, json
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum_mean

        mesh = jax.make_mesh((8,), ("data",))
        g_global = jnp.asarray(np.random.default_rng(0)
                               .standard_normal((8, 64)), jnp.float32)

        def step(g, err):
            return compressed_psum_mean(g, err, "data")

        f = jax.shard_map(step, mesh=mesh,
                          in_specs=(P("data", None), P("data", None)),
                          out_specs=(P(None, None), P("data", None)),
                          check_vma=False)
        err = jnp.zeros((8, 64))
        out, errd = f({"w": g_global}, {"w": err})
        mean, err = out["w"], errd["w"]
        true_mean = g_global.mean(0)
        rel = float(jnp.abs(mean[0] - true_mean).max()
                    / jnp.abs(true_mean).max())
        # int8 wire: one-step error bounded; feedback carries the residual
        total_err = float(jnp.abs(err).sum())
        # second round with error feedback on the SAME grads must reduce
        # the accumulated bias
        out2, _ = f({"w": g_global}, {"w": err})
        mean2 = out2["w"]
        bias1 = float(jnp.abs(mean[0] - true_mean).mean())
        # error feedback telescopes: the running average of compressed means
        # converges on the true mean even though each round is quantized
        avg_bias = float(jnp.abs((mean[0] + mean2[0]) / 2 - true_mean).mean())
        print(json.dumps({"rel": rel, "bias1": bias1, "avg_bias": avg_bias,
                          "err_nonzero": total_err > 0}))
    """)
    assert r["rel"] < 0.05
    assert r["err_nonzero"]
    assert r["avg_bias"] <= r["bias1"]  # feedback cancels quantization bias


def test_gpipe_matches_sequential():
    r = run_py("""
        import jax, jax.numpy as jnp, json, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.pipeline import pipeline_stack

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        n_groups, d = 8, 16
        ws = jnp.asarray(rng.standard_normal((n_groups, d, d)) * 0.2,
                         jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 4, d)), jnp.float32)

        def block(stage_ws, h):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, h, stage_ws)
            return out

        def seq(ws, h):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, h, ws)
            return out

        with mesh:
            y_pipe = jax.jit(lambda w, h: pipeline_stack(
                block, w, h, mesh=mesh, axis="pod", n_micro=4))(ws, x)
        y_seq = seq(ws, x)
        err = float(jnp.abs(y_pipe - y_seq).max())
        print(json.dumps({"err": err}))
    """)
    assert r["err"] < 1e-5


def test_gpipe_is_differentiable():
    r = run_py("""
        import jax, jax.numpy as jnp, json, numpy as np
        from repro.dist.pipeline import pipeline_stack

        mesh = jax.make_mesh((2,), ("pod",))
        rng = np.random.default_rng(1)
        ws = jnp.asarray(rng.standard_normal((4, 8, 8)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((4, 2, 8)), jnp.float32)

        def block(stage_ws, h):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, h, stage_ws)
            return out

        def loss_pipe(w):
            with mesh:
                y = pipeline_stack(block, w, x, mesh=mesh, axis="pod",
                                   n_micro=2)
            return jnp.sum(y ** 2)

        def loss_seq(w):
            def body(c, ww):
                return jnp.tanh(c @ ww), None
            out, _ = jax.lax.scan(body, x, w)
            return jnp.sum(out ** 2)

        g1 = jax.jit(jax.grad(loss_pipe))(ws)
        g2 = jax.grad(loss_seq)(ws)
        err = float(jnp.abs(g1 - g2).max() / (jnp.abs(g2).max() + 1e-9))
        print(json.dumps({"err": err}))
    """)
    assert r["err"] < 1e-4


def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a 4x2 mesh must produce the same loss
    trajectory as single-device execution (same seed, same data)."""
    body_tpl = """
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P
        from functools import partial
        from repro import configs
        from repro.configs.base import TrainConfig, ParallelConfig
        from repro.data.pipeline import SyntheticLM
        from repro.dist import sharding as SH
        from repro.launch import steps as ST
        from repro.optim.optimizers import AdamWState

        MESH = %s
        cfg = configs.reduced(configs.get_config("llama3-8b"))
        tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        par = ParallelConfig(remat="none")
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=8, seed=0)
        mesh = jax.make_mesh(MESH, ("data", "model"))
        with mesh:
            state = ST.make_train_state(jax.random.PRNGKey(0), cfg, tcfg)
            pspecs = SH.param_specs(jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                state.params), mesh, par)
            sspec = ST.TrainState(params=pspecs,
                                  opt=AdamWState(mu=pspecs, nu=pspecs,
                                                 count=P()), step=P())
            ssh = SH.to_named(sspec, mesh)
            state = jax.tree_util.tree_map(jax.device_put, state, ssh)
            fn = jax.jit(partial(ST.train_step, cfg=cfg, tcfg=tcfg, par=par),
                         in_shardings=(ssh, None), out_shardings=(ssh, None))
            losses = []
            for step in range(4):
                batch = {k: jnp.asarray(v)
                         for k, v in data.global_batch_arrays(step).items()}
                state, m = fn(state, batch)
                losses.append(float(m["loss"]))
        print(json.dumps({"losses": losses}))
    """
    multi = run_py(body_tpl % "(4, 2)")
    single = run_py(body_tpl % "(1, 1)")
    # equality across meshes is the correctness property here; convergence
    # over hundreds of steps is covered by the end-to-end system test
    for a, b in zip(multi["losses"], single["losses"]):
        assert a == pytest.approx(b, rel=2e-3), (multi, single)


def test_elastic_remesh_restore():
    """Save under a (4,2) mesh, restore under (2,4) and (8,1) — elastic
    rescaling across checkpoint boundaries."""
    r = run_py("""
        import jax, jax.numpy as jnp, json, tempfile, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ck

        d = tempfile.mkdtemp()
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = {"w": NamedSharding(mesh_a, P("data", "model"))}
        tree_a = jax.tree_util.tree_map(jax.device_put, tree, sh_a)
        ck.save(d, 1, tree_a)

        results = []
        for shape in [(2, 4), (8, 1)]:
            mesh_b = jax.make_mesh(shape, ("data", "model"))
            sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
            out = ck.restore(d, 1, tree, sh_b)
            results.append(bool((np.asarray(out["w"]) ==
                                 np.asarray(tree["w"])).all()))
            results.append(out["w"].sharding.mesh.shape["data"] == shape[0])
        print(json.dumps({"ok": all(results)}))
    """)
    assert r["ok"]
