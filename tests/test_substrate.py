"""Data pipeline, optimizers, checkpointing, fault tolerance (single-device)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticLM, frontend_stub
from repro.dist.fault import StepMonitor, Supervisor
from repro.optim import optimizers as OPT


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_replay():
    d = SyntheticLM(vocab_size=64, seq_len=32, global_batch=8, seed=3)
    a = d.global_batch_arrays(step=7)
    b = d.global_batch_arrays(step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.global_batch_arrays(step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_shards_partition_global_batch():
    d = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8, seed=0)
    shards = [d.host_local_batch(step=1, shard=i, num_shards=4)
              for i in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # different shards draw different streams
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticLM(vocab_size=64, seq_len=16, global_batch=2, seed=1)
    b = d.global_batch_arrays(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()  # masked final position


def test_data_is_learnable_structure():
    """The bigram-cycle structure must be present (next = 5*cur+1 mod V)."""
    d = SyntheticLM(vocab_size=64, seq_len=256, global_batch=4, seed=0,
                    structure=0.9)
    b = d.global_batch_arrays(0)["tokens"]
    follows = (b[:, 1:] == (5 * b[:, :-1] + 1) % 64).mean()
    assert follows > 0.7


def test_frontend_stub_shapes():
    from repro import configs
    cfg = configs.reduced(configs.get_config("seamless-m4t-medium"))
    fe = frontend_stub(cfg, batch=3, step=0)
    assert fe.shape == (3, cfg.encoder_seq_len, cfg.d_model)
    cfg = configs.reduced(configs.get_config("llama-3.2-vision-90b"))
    fe = frontend_stub(cfg, batch=2, step=0)
    assert fe.shape == (2, cfg.num_image_tokens, cfg.d_model)
    assert frontend_stub(configs.reduced(configs.get_config("llama3-8b")),
                         2, 0) is None


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_params():
    return {"a": jnp.asarray([2.0, -3.0]), "b": jnp.asarray([[1.0, 2.0]])}


@pytest.mark.parametrize("name", ["adamw", "sgdm"])
def test_optimizer_descends_quadratic(name):
    tcfg = TrainConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                       total_steps=200, grad_clip=10.0)
    opt = OPT.make_optimizer(name, tcfg)
    params = _quad_params()
    state = opt.init(params)
    loss_fn = lambda p: sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))
    l0 = float(loss_fn(params))
    for _ in range(100):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 0.1 * l0


def test_grad_clip_bounds_norm():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    n2 = jnp.sqrt(sum(jnp.sum(x ** 2)
                      for x in jax.tree_util.tree_leaves(clipped)))
    assert float(n2) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    sched = OPT.cosine_warmup_schedule(tcfg)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(sched(jnp.asarray(100))) < 2e-4


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"x": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"y": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros((2,)), jnp.full((1,), 7.0)]}
    ck.save(str(tmp_path), 5, tree, meta={"note": "t"})
    assert ck.latest_step(str(tmp_path)) == 5
    out = ck.restore(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.read_meta(str(tmp_path), 5)["note"] == "t"


def test_checkpoint_keep_k(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in [1, 2, 3, 4, 5]:
        ck.save(str(tmp_path), s, tree, keep=2)
    assert ck.all_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_partial_write_ignored(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    ck.save(str(tmp_path), 1, tree)
    # simulate a torn write: directory without COMMITTED
    os.makedirs(tmp_path / "step_00000002")
    assert ck.latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, {"x": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_detector_flags_slow_step():
    mon = StepMonitor(warmup=2, threshold=2.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.5) is True
    assert mon.summary()["stragglers"] == 1


def test_supervisor_restarts_after_injected_crash(tmp_path):
    """The run crashes at step 7; the supervisor restores from the step-5
    checkpoint and completes — no step is lost or repeated in the result."""
    crashed = {"done": False}

    def init_fn():
        return {"value": jnp.zeros(()), "steps_seen": []}

    def resume_fn(step):
        st = ck.restore(str(tmp_path), step, {"value": jnp.zeros(())})
        return {"value": st["value"], "steps_seen": []}

    def step_fn(state, step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        return {"value": state["value"] + 1.0,
                "steps_seen": state["steps_seen"] + [step]}

    def save_fn(state, step):
        ck.save(str(tmp_path), step, {"value": state["value"]})

    sup = Supervisor(str(tmp_path), ckpt_every=5)
    final = sup.run(total_steps=10, init_fn=init_fn, resume_fn=resume_fn,
                    step_fn=step_fn, save_fn=save_fn)
    assert sup.restarts == 1
    assert float(final["value"]) == 10.0       # 5 from ckpt + steps 5..9
    assert final["steps_seen"] == [5, 6, 7, 8, 9]
    assert ck.latest_step(str(tmp_path)) == 10
