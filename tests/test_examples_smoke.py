"""Examples can't rot silently: run their main() in-process and rely on the
shape assertions each example carries (plus a few checks here)."""
import importlib.util
import os

_EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")


def _load(name):
    path = os.path.join(_EXAMPLES, f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"example_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_power_planner_example_ladder_shape():
    mod = _load("power_planner")
    out = mod.main(["--arch", "llama3-8b", "--ladder", "2,4,6"])
    assert len(out["rows"]) == 6
    assert [r["bits"] for r in out["ladder"]] == [2, 4, 6]
    # the traversal: per-token price must rise monotonically with the rung
    prices = [r["gbitflips_per_token"] for r in out["ladder"]]
    assert prices == sorted(prices) and prices[0] > 0


def test_layerwise_allocator_example():
    mod = _load("layerwise_allocator")
    out = mod.main(["--arch", "llama3-8b", "--ladder", "2,4,6"])
    assert out["ladder_bits"] == [2, 4, 6]
    assert len(out["plans"]) == 3
    for lw in out["plans"]:
        # the example's contract: budget parity + score dominance per rung
        assert lw.score >= lw.uniform_score
        assert abs(lw.total_power - lw.power_budget * out["total_macs"]) \
            <= 0.01 * lw.total_power


def test_serve_lm_example_ladder_serving():
    mod = _load("serve_lm")
    summary = mod.main(["--arch", "llama3-8b", "--gen", "8"])
    assert summary["mode"] == "ladder"
    assert summary["generated"] == 6 * 8
    served = {r["rung_bits"] for r in summary["requests"]}
    assert served == {2, 4, 6}
