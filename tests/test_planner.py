"""Algorithm-1 planner edge cases: budgets too small for any bit width, and
the PannPlan.describe round-trip of the chosen (b~x, R)."""
import re

import pytest

from repro.core import planner
from repro.core import power as pw


def test_candidate_bit_widths_empty_below_minimum_budget():
    """A power budget below the cheapest (2-bit) PANN configuration leaves
    no candidate: p_pann(R -> 0, b=2) is the floor."""
    floor = pw.p_pann(0.05, 2)
    assert planner.candidate_bit_widths(floor * 0.5) == []
    # just above the floor, at least the smallest width qualifies
    assert 2 in planner.candidate_bit_widths(pw.p_pann(1.0, 2))


def test_candidate_bit_widths_monotone_in_budget():
    """Raising the budget never removes a candidate."""
    budgets = [planner.budget_from_bits(b) for b in (2, 4, 8)]
    cands = [set(planner.candidate_bit_widths(p)) for p in budgets]
    assert cands[0] <= cands[1] <= cands[2]
    assert cands[-1], "an 8-bit-MAC budget must admit some bit width"


def test_planners_raise_on_impossible_budget():
    with pytest.raises(ValueError, match="too small"):
        planner.plan_with_theory(0.01)
    with pytest.raises(ValueError, match="too small"):
        planner.plan_with_eval(0.01, lambda b, r: 1.0)


def test_plan_with_eval_empty_range_raises():
    p = planner.budget_from_bits(4)
    with pytest.raises(ValueError):
        planner.plan_with_eval(p, lambda b, r: 1.0, b_range=())


def test_describe_roundtrip_of_chosen_parameters():
    plan = planner.plan_with_theory(planner.budget_from_bits(4))
    text = plan.describe()
    m = re.search(r"b~x=(\d+), R=([0-9.]+)", text)
    assert m, text
    assert int(m.group(1)) == plan.b_x_tilde
    assert float(m.group(2)) == pytest.approx(plan.r, abs=5e-3)
    # the described budget matches too
    mb = re.search(r"P=([0-9.]+)", text)
    assert float(mb.group(1)) == pytest.approx(plan.power_budget, abs=0.05)
    # and the chosen pair actually meets the budget (Eq. 13 inversion)
    assert pw.p_pann(plan.r, plan.b_x_tilde) == \
        pytest.approx(plan.power_budget, rel=1e-6)


def test_plan_with_eval_picks_argmax():
    p = planner.budget_from_bits(4)
    cands = planner.candidate_bit_widths(p)
    best = cands[len(cands) // 2]
    plan = planner.plan_with_eval(p, lambda b, r: 1.0 - abs(b - best))
    assert plan.b_x_tilde == best
    assert plan.score == pytest.approx(1.0)
    assert len(plan.candidates) == len(cands)
