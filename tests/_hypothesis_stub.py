"""Minimal deterministic stand-in for `hypothesis`, used only when the real
package is not installed (this container ships no hypothesis and nothing may
be pip-installed). It implements exactly the surface the test-suite uses:

    from hypothesis import given, settings, strategies as st
    st.integers / st.floats / st.sampled_from / st.lists

`given` draws `max_examples` pseudo-random examples from a fixed seed (plus
the boundary example first), so runs are reproducible. Shrinking, databases,
deadlines etc. are not implemented — `settings` only reads `max_examples`.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random, boundary: bool = False):
        return self._draw(rng, boundary)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng, b: min_value if b
                     else rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng, b: min_value if b
                     else rng.uniform(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng, b: seq[0] if b else rng.choice(seq))


def booleans() -> _Strategy:
    return _Strategy(lambda rng, b: False if b else rng.random() < 0.5)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng, boundary):
        size = min_size if boundary else rng.randint(min_size, max_size)
        return [elements.example(rng, boundary) for _ in range(size)]
    return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        max_examples = getattr(fn, "_stub_max_examples",
                               _DEFAULT_MAX_EXAMPLES)

        def runner():
            rng = random.Random(0)
            for i in range(max_examples):
                args = [s.example(rng, boundary=(i == 0))
                        for s in strategies]
                try:
                    fn(*args)
                except Exception as err:
                    raise AssertionError(
                        f"falsifying example (stub hypothesis): "
                        f"{fn.__name__}{tuple(args)!r}") from err

        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the wrapped function's strategy parameters.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco


def install() -> None:
    """Register stub `hypothesis` / `hypothesis.strategies` in sys.modules."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "lists", "booleans"):
        setattr(strat, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
