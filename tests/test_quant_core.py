"""Quantizer + unsigned-split + PANN core properties (unit + property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pann, planner, quant
from repro.core import power as pw
from repro.core.unsigned import is_unsigned_exact, unsigned_matmul, unsigned_split

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# RUQ
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,signed", [(2, True), (4, True), (8, True),
                                         (2, False), (4, False), (8, False)])
def test_ruq_codes_in_range(bits, signed):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    if not signed:
        x = jnp.abs(x)
    q, s = quant.ruq(x, bits, signed)
    qr = quant.qrange(bits, signed)
    assert float(q.min()) >= qr.qmin and float(q.max()) <= qr.qmax
    assert jnp.all(q == jnp.round(q))


def test_ruq_error_bounded_by_half_step():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-1, 1, (128,)), jnp.float32)
    q, s = quant.ruq(x, 6, signed=True)
    err = jnp.abs(x - q * s)
    assert float(err.max()) <= float(s.max()) * 0.5 + 1e-6


def test_fake_quant_gradient_is_identity_in_range():
    x = jnp.linspace(-0.5, 0.5, 11)
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, 4, signed=True)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g))


def test_clip_calibration_beats_absmax_on_heavy_tails():
    rng = np.random.default_rng(2)
    x = rng.standard_t(df=2, size=8192).astype(np.float32)  # heavy tails
    xj = jnp.asarray(x)
    bits = 4
    clip = quant.calibrate_clip(xj, bits, signed=True)
    qc, sc = quant.clip_quant(xj, bits, signed=True, clip=clip)
    qa, sa = quant.ruq(xj, bits, signed=True)
    mse_clip = float(jnp.mean((xj - qc * sc) ** 2))
    mse_abs = float(jnp.mean((xj - qa * sa) ** 2))
    assert mse_clip < mse_abs


def test_lsq_forward_and_grads():
    x = jnp.asarray(np.random.default_rng(3).standard_normal(256), jnp.float32)
    step = quant.lsq_init_step(x, 4, signed=True)
    y = quant.lsq_quant(x, step, -8, 7)
    assert y.shape == x.shape
    dx, dstep = jax.grad(
        lambda xx, ss: jnp.sum(quant.lsq_quant(xx, ss, -8, 7) ** 2),
        argnums=(0, 1))(x, step)
    assert jnp.isfinite(dstep)
    assert jnp.all(jnp.isfinite(dx))
    # gradient flows only inside the clipping range
    big = jnp.full((4,), 100.0)
    dbig = jax.grad(lambda xx: jnp.sum(quant.lsq_quant(xx, step, -8, 7)))(big)
    np.testing.assert_allclose(np.asarray(dbig), 0.0)


# ---------------------------------------------------------------------------
# Unsigned split (Sec. 4) — exactness
# ---------------------------------------------------------------------------

@given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_unsigned_split_exact(d_in, d_out, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(np.abs(rng.standard_normal((3, d_in))), jnp.float32)
    assert is_unsigned_exact(x, w)
    wp, wn = unsigned_split(w)
    assert float(wp.min()) >= 0 and float(wn.min()) >= 0
    np.testing.assert_allclose(np.asarray(wp - wn), np.asarray(w), rtol=1e-6)


def test_unsigned_matmul_with_bias():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    x = jnp.abs(jnp.asarray(rng.standard_normal((4, 32)), jnp.float32))
    np.testing.assert_allclose(np.asarray(unsigned_matmul(x, w, b)),
                               np.asarray(x @ w + b), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# PANN quantization (Eq. 12) properties
# ---------------------------------------------------------------------------

@given(st.sampled_from([0.5, 1.0, 2.0, 4.0]), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_pann_addition_budget_respected(r, seed):
    """The realized addition factor ||w_q||_1 / d tracks the budget R."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    w_q, gamma = pann.pann_quantize(w, r, axis=0)
    realized = pann.additions_per_element(w_q, axis=0)
    # rounding keeps the per-channel addition factor within ~15% + abs slack
    np.testing.assert_allclose(np.asarray(realized), r, rtol=0.15, atol=0.3)


def test_pann_gamma_formula():
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    r = 2.0
    gamma = pann.pann_gamma(w, r, axis=0)
    want = np.abs(np.asarray(w)).sum(0, keepdims=True) / (r * w.shape[0])
    np.testing.assert_allclose(np.asarray(gamma), want, rtol=1e-6)


def test_pann_quantization_error_bounded():
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.standard_normal((512, 4)), jnp.float32)
    w_q, gamma = pann.pann_quantize(w, 2.0, axis=0)
    err = jnp.abs(w - w_q * gamma)
    assert float((err <= 0.5 * gamma + 1e-7).all())


def test_bitplane_decomposition_roundtrip():
    rng = np.random.default_rng(13)
    w = jnp.asarray(rng.integers(0, 30, (16, 8)), jnp.float32)
    planes = pann.bitplane_decompose(w)
    recon = sum((2 ** k) * planes[k].astype(jnp.float32)
                for k in range(planes.shape[0]))
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(w))


def test_bitplane_matmul_matches_dense():
    rng = np.random.default_rng(14)
    w = jnp.asarray(rng.integers(-12, 13, (32, 8)), jnp.float32)
    x = jnp.asarray(rng.integers(0, 15, (4, 32)), jnp.float32)
    pos, neg = unsigned_split(w)
    n = pann.weight_storage_bits(w)
    y = pann.bitplane_matmul(x, pann.bitplane_decompose(pos, n),
                             pann.bitplane_decompose(neg, n))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w))


def test_pann_linear_qat_vs_ptq_paths_agree():
    rng = np.random.default_rng(15)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    x = jnp.abs(jnp.asarray(rng.standard_normal((8, 64)), jnp.float32))
    y_qat = pann.pann_linear(x, w, None, r=2.0, act_bits=6, qat=True)
    y_ptq = pann.pann_linear(x, w, None, r=2.0, act_bits=6, qat=False)
    np.testing.assert_allclose(np.asarray(y_qat), np.asarray(y_ptq),
                               rtol=2e-4, atol=2e-4)


def test_pann_bitplane_linear_matches_reference():
    rng = np.random.default_rng(16)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    x = jnp.abs(jnp.asarray(rng.standard_normal((8, 64)), jnp.float32))
    pwts = pann.pann_prepare(w, r=2.0, axis=0)
    y_ref = pann.pann_matmul_reference(x, pwts, act_bits=6)
    y_bp = pann.pann_bitplane_linear(x, pwts, act_bits=6)
    np.testing.assert_allclose(np.asarray(y_bp), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_pann_qat_weight_gradients_flow():
    rng = np.random.default_rng(17)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    x = jnp.abs(jnp.asarray(rng.standard_normal((4, 32)), jnp.float32))
    g = jax.grad(lambda ww: jnp.sum(
        pann.pann_linear(x, ww, None, r=2.0, act_bits=6, qat=True) ** 2))(w)
    assert float(jnp.abs(g).sum()) > 0
    assert jnp.all(jnp.isfinite(g))


# ---------------------------------------------------------------------------
# Planner (Algorithm 1)
# ---------------------------------------------------------------------------

def test_planner_picks_argmax_of_eval():
    budget = planner.budget_from_bits(4)  # 24 bit flips

    def fake_eval(b, r):
        return -abs(b - 5)  # pretend b~x = 5 is best

    plan = planner.plan_with_eval(budget, fake_eval)
    assert plan.b_x_tilde == 5
    assert plan.r == pytest.approx(pw.pann_r_for_budget(budget, 5))


def test_planner_theory_prefers_more_bits_at_higher_power():
    lo = planner.plan_with_theory(planner.budget_from_bits(2))
    hi = planner.plan_with_theory(planner.budget_from_bits(8))
    assert hi.b_x_tilde >= lo.b_x_tilde


def test_equal_power_curve_is_consistent():
    for bits in [2, 4, 8]:
        p = planner.budget_from_bits(bits)
        for b, r in planner.equal_power_curve(bits):
            assert pw.p_pann(r, b) == pytest.approx(p)


def test_planner_rejects_tiny_budget():
    with pytest.raises(ValueError):
        planner.plan_with_eval(0.5, lambda b, r: 0.0)
