"""Serving-time PANN weight quantization (models/serving.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import QuantConfig
from repro.models import model as MD
from repro.models.serving import quantize_params_for_serving


def _setup(arch="llama3-8b"):
    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none", r=4.0))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    return cfg, params, tokens


def test_quantized_params_are_int8():
    cfg, params, _ = _setup()
    qp = quantize_params_for_serving(params, cfg, r=4.0)
    leaves = jax.tree_util.tree_flatten_with_path(qp)[0]
    n_int8 = sum(1 for p, l in leaves
                 if p and getattr(p[-1], "key", "") == "w_q")
    assert n_int8 > 0
    for path, leaf in leaves:
        if path and getattr(path[-1], "key", "") == "w_q":
            assert leaf.dtype == jnp.int8
            assert int(jnp.abs(leaf.astype(jnp.int32)).max()) <= 127


def test_quantized_forward_tracks_fp():
    cfg, params, tokens = _setup()
    qp = quantize_params_for_serving(params, cfg, r=8.0)
    out_fp = MD.forward(params, cfg, tokens, remat=False)
    out_q = MD.forward(qp, cfg, tokens, remat=False)
    assert bool(jnp.isfinite(out_q.logits).all())
    denom = float(jnp.abs(out_fp.logits).mean()) + 1e-9
    err = float(jnp.abs(out_q.logits - out_fp.logits).mean()) / denom
    assert err < 0.35, err


def test_quantized_decode_works():
    cfg, params, tokens = _setup("zamba2-1.2b")
    qp = quantize_params_for_serving(params, cfg, r=4.0)
    state = MD.init_decode_state(qp, cfg, batch=2, max_len=8)
    step = jax.jit(lambda p, s, t: MD.decode_step(p, cfg, s, t))
    logits, state = step(qp, state, tokens[:, :1])
    assert bool(jnp.isfinite(logits).all())


def test_weight_bytes_shrink():
    """The point of the exercise: serving weight bytes drop ~4x vs f32."""
    cfg, params, _ = _setup()
    qp = quantize_params_for_serving(params, cfg, r=4.0)

    def proj_bytes(tree):
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            name = getattr(path[-1], "key", "") if path else ""
            if name in ("w", "w_q"):
                total += leaf.size * leaf.dtype.itemsize
        return total

    before = proj_bytes(params)
    after = proj_bytes(qp)
    assert after < 0.3 * before, (before, after)


def test_higher_r_better_fidelity():
    cfg, params, tokens = _setup()
    out_fp = MD.forward(params, cfg, tokens, remat=False)
    errs = []
    for r in [1.0, 4.0, 16.0]:
        qp = quantize_params_for_serving(params, cfg, r=r)
        out_q = MD.forward(qp, cfg, tokens, remat=False)
        errs.append(float(jnp.abs(out_q.logits - out_fp.logits).mean()))
    assert errs[0] > errs[1] > errs[2], errs
