"""Differential parity suite for the quantized KV cache (docs/kv_cache.md).

The contract under test: ``kernels.pann_attention.decode_attention`` (Pallas,
interpret mode off-TPU) is BIT-IDENTICAL in fp32 to the jnp int32 oracle
``kernels.ref.decode_attention_ref`` — across dynamic and calibrated
(constant-row) quantizer ranges, ragged sequence lengths, GQA head counts,
sliding windows, softcapping, and every cache bit width the ladder can
produce (fewer-bit rungs write zero high planes into the same 7-plane
layout, which is what makes mid-stream rung switches aval-stable).

Plus property-based round-trip tests for the cache codec itself via the
vendored hypothesis stub (tests/_hypothesis_stub.py; the real package wins
when installed).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant
from repro.kernels import dispatch
from repro.kernels import pann_attention as pa
from repro.kernels import ref
from repro.models import attention as ATT


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _mk_cache_side(rng, b, s, kh, hd, bits, frozen=False):
    """Random packed planes + per-position quantizer rows for one side.

    ``frozen=True`` broadcasts ONE (s, z) across every position — the
    calibrated-range layout ``models.serving`` hoists; otherwise every
    position carries its own (dynamic) row.
    """
    cap = min((1 << bits) - 1, 127)
    codes = rng.integers(0, cap + 1, (b, s, kh, hd))
    planes = ref.pack_cache_codes(jnp.asarray(codes, jnp.int32))
    planes = jnp.moveaxis(planes, 0, 1)          # (B, P, S, K, hd//8)
    if frozen:
        s_row = np.full((b, s), rng.uniform(0.01, 0.1), np.float32)
        z_row = np.full((b, s), float(rng.integers(0, cap + 1)), np.float32)
    else:
        s_row = rng.uniform(0.01, 0.1, (b, s)).astype(np.float32)
        z_row = rng.integers(0, cap + 1, (b, s)).astype(np.float32)
    return planes, jnp.asarray(s_row), jnp.asarray(z_row), codes


def _mk_inputs(seed, b, s, kh, g, hd, bits, frozen=False):
    rng = np.random.default_rng(seed)
    kp, ks, kz, _ = _mk_cache_side(rng, b, s, kh, hd, bits, frozen)
    vp, vs, vz, _ = _mk_cache_side(rng, b, s, kh, hd, bits, frozen)
    qq = jnp.asarray(rng.integers(0, 128, (b, kh, g, hd)), jnp.int32)
    q_z = jnp.int32(rng.integers(0, 128))
    q_scale = jnp.float32(rng.uniform(0.001, 0.05) * hd ** -0.5)
    return qq, q_z, q_scale, kp, ks, kz, vp, vs, vz


# ---------------------------------------------------------------------------
# ref vs Pallas kernel: bit-identical fp32
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,kh,g,hd", [
    (1, 8, 1, 4, 8),       # MQA: one KV head, 4 query groups
    (2, 16, 2, 2, 16),     # GQA 2x2
    (2, 12, 4, 1, 8),      # MHA: group size 1
])
@pytest.mark.parametrize("bits", [2, 4, 7])
def test_kernel_matches_ref_bit_identical(b, s, kh, g, hd, bits):
    args = _mk_inputs(0, b, s, kh, g, hd, bits)
    for pos in (0, s // 2, s - 1):
        want = ref.decode_attention_ref(*args, jnp.int32(pos))
        got = pa.decode_attention(*args, jnp.int32(pos), interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("window,softcap", [(None, 0.0), (5, 0.0),
                                            (None, 30.0), (3, 20.0)])
def test_kernel_matches_ref_window_softcap(window, softcap):
    args = _mk_inputs(1, 2, 16, 2, 2, 8, 4)
    for pos in (2, 9, 15):
        want = ref.decode_attention_ref(*args, jnp.int32(pos),
                                        window=window, softcap=softcap)
        got = pa.decode_attention(*args, jnp.int32(pos), window=window,
                                  softcap=softcap, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_matches_ref_calibrated_rows():
    """Frozen (calibrated) ranges broadcast one (s, z) per side — the
    serving hoist — and must stay bit-identical like dynamic rows."""
    args = _mk_inputs(2, 2, 12, 2, 2, 16, 4, frozen=True)
    want = ref.decode_attention_ref(*args, jnp.int32(7))
    got = pa.decode_attention(*args, jnp.int32(7), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ref_ragged_positions_match_per_batch_kernel_calls():
    """The oracle takes per-batch pos (ragged lanes); the kernel pins one
    scalar. Slicing each batch row out and running the kernel at its own
    pos must reproduce the ragged oracle exactly."""
    b, s, kh, g, hd = 3, 16, 2, 2, 8
    args = _mk_inputs(3, b, s, kh, g, hd, 4)
    pos = jnp.asarray([3, 15, 9], jnp.int32)
    want = ref.decode_attention_ref(*args, pos)
    for i in range(b):
        row = [a[i:i + 1] if getattr(a, "ndim", 0) > 0 else a for a in args]
        got = pa.decode_attention(*row, pos[i], interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(want[i:i + 1]))


def test_rung_switch_zero_high_planes_parity():
    """A rung switch changes only the CODE WIDTH: a 3-bit rung's codes in
    the pinned 7-plane layout leave the high planes zero. Parity must hold
    on exactly that layout (same avals, different values) — the aval
    stability that lets one compiled step straddle a mid-stream switch."""
    lo = _mk_inputs(4, 2, 16, 2, 2, 8, 3)
    hi = _mk_inputs(4, 2, 16, 2, 2, 8, 7)
    # 3-bit inputs really do have zero high planes
    assert int(jnp.max(lo[3][:, 3:])) == 0 and int(jnp.max(lo[6][:, 3:])) == 0
    assert int(jnp.max(hi[3][:, 3:])) > 0
    for args in (lo, hi):
        want = ref.decode_attention_ref(*args, jnp.int32(11))
        got = pa.decode_attention(*args, jnp.int32(11), interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dispatch_backend_parity():
    """kernels.dispatch.decode_attention (the serving entry: quantizes q,
    seals the scalars) must agree bit-for-bit between the jnp ref backend
    and the forced Pallas kernel."""
    rng = np.random.default_rng(5)
    b, s, kh, g, hd = 2, 12, 2, 2, 8
    kp, ks, kz, _ = _mk_cache_side(rng, b, s, kh, hd, 4)
    vp, vs, vz, _ = _mk_cache_side(rng, b, s, kh, hd, 4)
    kv = ATT.QuantKVCache(k_planes=kp, v_planes=vp, k_s=ks, k_z=kz,
                          v_s=vs, v_z=vz, length=jnp.int32(s - 1))
    q = jnp.asarray(rng.standard_normal((b, kh * g, hd)), jnp.float32)
    a = dispatch.decode_attention(q, kv, "ref", num_kv_heads=kh)
    bq = dispatch.decode_attention(q, kv, "fused:force", num_kv_heads=kh)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bq))


def test_incremental_writes_match_batch_pack():
    """models.attention's masked select-write, applied token by token, must
    leave the exact planes/rows a one-shot pack of the same codes produces
    — so a decode stream's cache state is re-derivable from the prefix
    (what makes the rung-switch replay bit-exact)."""
    rng = np.random.default_rng(6)
    b, t, kh, hd, bits = 2, 5, 2, 8, 4
    n_lvl = jnp.float32((1 << bits) - 1)
    xs = rng.standard_normal((b, t, kh, hd)).astype(np.float32)
    planes = jnp.zeros((b, ref.CACHE_PLANES, t, kh, hd // 8), jnp.uint8)
    s_row = jnp.zeros((b, t), jnp.float32)
    z_row = jnp.zeros((b, t), jnp.float32)
    codes_all = []
    for i in range(t):
        new = jnp.asarray(xs[:, i:i + 1])
        s, z = ATT._cache_rows(new, None, None, n_lvl)
        planes, s_row, z_row = ATT._cache_write(
            planes, s_row, z_row, new, s, z, n_lvl, jnp.int32(i))
        codes_all.append(quant.affine_encode(
            new, s[:, None, None, None], z[:, None, None, None], n_lvl))
    codes = jnp.concatenate(codes_all, axis=1).astype(jnp.int32)
    direct = jnp.moveaxis(ref.pack_cache_codes(codes), 0, 1)
    np.testing.assert_array_equal(np.asarray(planes), np.asarray(direct))


# ---------------------------------------------------------------------------
# property-based codec round trips (vendored hypothesis stub)
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(st.integers(1, 7), st.integers(1, 4), st.integers(1, 6),
       st.integers(0, 10_000))
def test_codec_round_trip(bits, lead, d8, seed):
    """unpack(pack(codes)) == codes for every plane count and shape."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, (lead, 3, d8 * 8))
    packed = ref.pack_cache_codes(jnp.asarray(codes, jnp.int32),
                                  n_planes=bits)
    assert packed.shape == (bits, lead, 3, d8)
    back = ref.unpack_cache_codes(packed)
    np.testing.assert_array_equal(np.asarray(back), codes)


@settings(max_examples=20)
@given(st.integers(2, 7), st.floats(0.1, 8.0), st.integers(0, 10_000))
def test_affine_cache_round_trip_error_bound(bits, spread, seed):
    """Encoding a tensor through the cache codec (affine encode -> pack ->
    unpack -> dequant) reconstructs within half a step everywhere inside
    the range — the codec itself is lossless on the codes."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-spread, spread, (2, 3, 16)), jnp.float32)
    n_lvl = jnp.float32((1 << bits) - 1)
    lo, hi = quant.act_range_bounds(x, include_zero=True)
    s, z = quant.affine_scale_zp(lo, hi, n_lvl)
    codes = quant.affine_encode(x, s, z, n_lvl).astype(jnp.int32)
    back = ref.unpack_cache_codes(ref.pack_cache_codes(codes,
                                                       n_planes=bits))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
    deq = (back.astype(jnp.float32) - z) * s
    err = float(jnp.max(jnp.abs(deq - x)))
    assert err <= 0.5 * float(s) * (1 + 1e-5), (err, float(s))
