"""Paper Table 6 + Fig. 12(a): required accumulator widths and the unsigned
power saving at both reduced-B and 32-bit accumulators."""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json
from repro.core import power as pw


def run() -> dict:
    t0 = time.perf_counter()
    rows = []
    fan_in = 9 * 512    # the paper's ResNet largest layer (3x3x512)
    for b in [2, 3, 4, 5, 6]:
        breq = pw.required_acc_bits(b, b, fan_in)
        rows.append({
            "bits": b,
            "required_acc_bits": breq,
            "save_reduced_acc": round(pw.unsigned_power_save(b, breq), 3),
            "save_32b_acc": round(pw.unsigned_power_save(b, 32), 3),
        })
    save_json("table6_accumulator.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    emit("table6_accumulator", us,
         " ".join(f"{r['bits']}b:B={r['required_acc_bits']}"
                  f" save32={r['save_32b_acc']:.0%}" for r in rows[:3]))
    return rows


if __name__ == "__main__":
    run()
