"""CI train-smoke: a reduced power-aware QAT run, end to end through export.

Runs ``launch/train.py --reduced`` with a layer-wise budget-annealing
schedule, then exports the checkpoint (``launch/export.py``) and records:

  * the loss trajectory (gated with tolerance — CPU BLAS variation across
    runners moves losses at the 1e-5 level, real regressions at 1e-1),
  * the planned Gbit-flips/token at every schedule knot (gated EXACTLY:
    the allocator is deterministic Python float math, identical on every
    platform — any drift is a planner/profile change),
  * the export round-trip gap (gated: the serving artifact must reproduce
    the training-time eval loss),
  * wall-clock timings (informational only, like kernel_bench).

``--check`` gates against benchmarks/baselines/train_bench.json; refresh
the baseline by copying benchmarks/results/train_bench.json over it when
training semantics legitimately change.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

from benchmarks.common import save_json  # noqa: E402
from repro.launch import export as EX  # noqa: E402
from repro.launch import train as TR  # noqa: E402

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "train_bench.json")

# the smoke configuration: tiny, but crosses two budget knots (fp -> 8b ->
# 6b) so replanning, calibration, and the re-jit path all execute
SMOKE = dict(arch="llama3-8b", steps=18, batch=4, seq=64, lr=1e-2,
             schedule="0:fp,4:8,12:6", allocation="layerwise")

LOSS_RTOL = 0.05
LOSS_ATOL = 0.02


def run(check: bool = False) -> dict:
    ckpt_dir = tempfile.mkdtemp(prefix="train_bench_ck_")
    argv = ["--arch", SMOKE["arch"], "--reduced",
            "--steps", str(SMOKE["steps"]),
            "--batch", str(SMOKE["batch"]), "--seq", str(SMOKE["seq"]),
            "--lr", str(SMOKE["lr"]),
            "--quant", "pann", "--train_quant", "qat",
            "--budget_schedule", SMOKE["schedule"],
            "--allocation", SMOKE["allocation"],
            "--ckpt_dir", ckpt_dir, "--ckpt_every", "1000",
            "--log_every", "6"]
    t0 = time.perf_counter()
    summary = TR.main(argv)
    train_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    export = EX.main(["--ckpt_dir", ckpt_dir])
    export_s = time.perf_counter() - t0

    out = {
        "config": SMOKE,
        "losses": summary["losses"],
        "eval_loss": summary["eval_loss"],
        "plans": summary["plans"],
        "export": {"bits": export["bits"],
                   "rel_diff": export["rel_diff"],
                   "loss_serve_eval": export["loss_serve_eval"]},
        "timings_s": {"train": round(train_s, 2),
                      "export": round(export_s, 2),
                      "mean_step": summary["mean_step_s"]},
    }
    path = save_json("train_bench.json", out)
    print(f"[train_bench] wrote {path}")
    if check:
        failures = check_baseline(out)
        if failures:
            for f in failures:
                print(f"[train_bench] REGRESSION: {f}")
            raise SystemExit(1)
        print("[train_bench] baseline check passed")
    return out


def check_baseline(result: dict, baseline_path: str = BASELINE) -> list[str]:
    """Gate the loss trajectory (tolerance), the planned Gbit-flips
    (exact), and the export round-trip; timings stay advisory."""
    failures = []
    with open(baseline_path) as f:
        base = json.load(f)

    if result["config"] != base["config"]:
        failures.append(f"smoke config drifted: {result['config']} != "
                        f"{base['config']} — refresh {baseline_path}")

    # planned power: deterministic allocator output, bit-for-bit portable
    if len(result["plans"]) != len(base["plans"]):
        failures.append(f"schedule knot count changed: {result['plans']} "
                        f"vs {base['plans']}")
    else:
        for got, want in zip(result["plans"], base["plans"]):
            same = (got["step"] == want["step"]
                    and got["bits"] == want["bits"]
                    and np.isclose(got["gbitflips_per_token"],
                                   want["gbitflips_per_token"],
                                   rtol=1e-9, atol=0.0))
            if not same:
                failures.append(
                    f"planned budget drifted at step {want['step']}: "
                    f"{got} != {want} — allocator/profile change; refresh "
                    f"the baseline if intended")

    # loss trajectory: tolerant of BLAS-level noise, loud on real drift
    got_l, want_l = result["losses"], base["losses"]
    if len(got_l) != len(want_l):
        failures.append(f"trajectory length {len(got_l)} != {len(want_l)}")
    elif not np.allclose(got_l, want_l, rtol=LOSS_RTOL, atol=LOSS_ATOL):
        worst = int(np.argmax(np.abs(np.array(got_l) - np.array(want_l))))
        failures.append(
            f"loss trajectory drifted (worst at step {worst}: "
            f"{got_l[worst]:.4f} vs {want_l[worst]:.4f}, "
            f"tol rtol={LOSS_RTOL}/atol={LOSS_ATOL})")
    if not np.isclose(result["eval_loss"], base["eval_loss"],
                      rtol=LOSS_RTOL, atol=LOSS_ATOL):
        failures.append(f"eval loss drifted: {result['eval_loss']:.4f} vs "
                        f"{base['eval_loss']:.4f}")

    # the QAT run must still learn, and the export must still round-trip
    if not result["losses"][-1] < result["losses"][0]:
        failures.append("loss did not decrease over the smoke run")
    if result["export"]["rel_diff"] > 1e-3:
        failures.append(
            f"export round-trip gap {result['export']['rel_diff']:.2e} "
            f"> 1e-3: serving artifact no longer reproduces training")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="gate against the committed baseline")
    args = ap.parse_args(argv)
    return run(check=args.check)


if __name__ == "__main__":
    main()
