"""Paper Table 4 / 11-12 analogue: multiplier-free comparison by addition
factor — PANN at R in {1, 1.5, 2} across weight/act bit widths (QAT)."""
from __future__ import annotations

import time

from benchmarks.common import emit, eval_accuracy, save_json, train_small_lm
from repro.configs.base import QuantConfig


def run(steps: int = 200) -> dict:
    t0 = time.perf_counter()
    rows = []
    for r in [1.0, 1.5, 2.0]:
        row = {"addition_factor": r}
        for bits in [6, 4, 3]:
            qc = QuantConfig(mode="pann", r=r, act_bits_tilde=bits, qat=True)
            tl = train_small_lm(steps=steps, qat_quant=qc)
            row[f"acc_{bits}b"] = round(eval_accuracy(tl, qc), 4)
        rows.append(row)
    save_json("table4_addition_factor.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    emit("table4_addition_factor", us,
         " ".join(f"R={r['addition_factor']}:{r['acc_4b']:.3f}@4b"
                  for r in rows))
    return rows


if __name__ == "__main__":
    run()
