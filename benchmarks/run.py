"""Benchmark harness: the full suite, and the ONE CI gate entry point.

Two modes:

  * ``python benchmarks/run.py`` — the full nightly suite: one module per
    paper table/figure plus every end-to-end benchmark (kernels, roofline,
    serving traversal, artifact parity, training smoke, fleet sim).
    Prints ``name,us_per_call,derived`` CSV lines and writes JSON
    artifacts to benchmarks/results/. ``--fast`` shortens the trained
    benchmarks; ``--only a,b`` selects jobs.

  * ``python benchmarks/run.py --ci-gates`` — the deduplicated CI gate
    runner: every baseline-gated ``--check`` benchmark as a subprocess
    (each with PYTHONPATH=src:. so the workflows carry no per-step env
    boilerplate), one PASS/FAIL summary table at the end, nonzero exit if
    any gate failed. ``--gates`` selects a subset (train-smoke CI runs
    ``--ci-gates --gates train_bench``); the default set is everything
    the tier-1 workflow gates.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every baseline-gated benchmark, by name: argv after the interpreter.
# Order matters: cheap structural gates first, trained/simulated ones last.
GATES: dict[str, list[str]] = {
    "kernel_bench": ["benchmarks/kernel_bench.py", "--check"],
    "roofline": ["benchmarks/roofline.py", "--check"],
    "serve_traversal": ["benchmarks/serve_traversal.py", "--reduced",
                        "--check"],
    "serve_traversal_layerwise": ["benchmarks/serve_traversal.py",
                                  "--reduced", "--check",
                                  "--allocation", "layerwise"],
    "table14_footprint": ["benchmarks/table14_footprint.py", "--reduced",
                          "--check"],
    "artifact_parity": ["benchmarks/artifact_parity.py", "--check"],
    "encoder_bench": ["benchmarks/encoder_bench.py", "--check"],
    "fleet_sim": ["benchmarks/fleet_sim.py", "--reduced", "--check"],
    "train_bench": ["benchmarks/train_bench.py", "--check"],
}

# what `--ci-gates` runs by default == what the tier-1 workflow gates on
# every PR. train_bench rides in its own CI job (it trains a model), so it
# is selectable but not default.
DEFAULT_CI_GATES = ("kernel_bench", "roofline", "serve_traversal",
                    "serve_traversal_layerwise", "table14_footprint",
                    "artifact_parity", "encoder_bench", "fleet_sim")


def run_ci_gates(names, fleet_scale: int = 1) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", ".", env.get("PYTHONPATH")) if p)
    rows = []
    for name in names:
        argv = list(GATES[name])
        if name == "fleet_sim" and fleet_scale != 1:
            argv += ["--scale", str(fleet_scale)]
        print(f"\n=== gate: {name}: {' '.join(argv)}", flush=True)
        t0 = time.monotonic()
        rc = subprocess.run([sys.executable] + argv, cwd=REPO,
                            env=env).returncode
        rows.append((name, rc, time.monotonic() - t0))
    width = max(len(n) for n, _, _ in rows)
    print("\n=== CI gate summary")
    print(f"{'gate'.ljust(width)}  result  seconds")
    for name, rc, dt in rows:
        status = "PASS" if rc == 0 else f"FAIL({rc})"
        print(f"{name.ljust(width)}  {status:6}  {dt:7.1f}")
    failed = [n for n, rc, _ in rows if rc != 0]
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


def run_full_suite(args) -> int:
    steps = 80 if args.fast else 250
    qat_steps = 60 if args.fast else 200

    from benchmarks import (arch_power, artifact_parity, encoder_bench,
                            fig3_equal_power, fig4_mse_ratio, fleet_sim,
                            kernel_bench, roofline, serve_traversal,
                            table1_bitflips, table2_ptq, table3_qat,
                            table4_addition_factor, table6_accumulator,
                            table14_footprint, train_bench)

    # the full suite runs EVERYTHING the repo benchmarks — paper tables,
    # kernels, and each end-to-end driver (main(argv) where the module's
    # CLI owns its defaults), so the nightly CSV covers every subsystem
    jobs = [
        ("table1_bitflips", table1_bitflips.run, {}),
        ("fig3_equal_power", fig3_equal_power.run, {}),
        ("fig4_mse_ratio", fig4_mse_ratio.run, {}),
        ("table6_accumulator", table6_accumulator.run, {}),
        ("arch_power", arch_power.run, {}),
        ("kernel_bench", kernel_bench.run, {}),
        ("artifact_parity", artifact_parity.main, {"argv": []}),
        ("table2_ptq", table2_ptq.run, {"steps": steps}),
        ("table3_qat", table3_qat.run, {"steps": qat_steps}),
        ("table4_addition_factor", table4_addition_factor.run,
         {"steps": qat_steps}),
        ("table14_footprint", table14_footprint.run,
         {"steps": max(qat_steps, 100)}),
        ("roofline", roofline.run, {}),
        ("serve_traversal", serve_traversal.main, {"argv": ["--reduced"]}),
        ("serve_traversal_layerwise", serve_traversal.main,
         {"argv": ["--reduced", "--allocation", "layerwise"]}),
        ("encoder_bench", encoder_bench.main, {"argv": []}),
        ("train_bench", train_bench.run, {}),
        ("fleet_sim", fleet_sim.main, {"argv": ["--reduced"]}),
    ]
    if args.only:
        keep = set(args.only.split(","))
        jobs = [j for j in jobs if j[0] in keep]

    print("name,us_per_call,derived")
    failed = []
    for name, fn, kw in jobs:
        try:
            fn(**kw)
        except SystemExit as e:  # a main(argv) that failed its own gate
            if e.code not in (0, None):
                failed.append(name)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps for the accuracy tables")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names (full suite)")
    ap.add_argument("--ci-gates", action="store_true",
                    help="run the baseline-gated --check benchmarks as "
                         "subprocesses with one summary table")
    ap.add_argument("--gates", default=None,
                    help="comma-separated gate names for --ci-gates "
                         f"(default: {','.join(DEFAULT_CI_GATES)}; "
                         f"available: {','.join(GATES)})")
    ap.add_argument("--fleet-scale", type=int, default=1,
                    help="--scale forwarded to the fleet_sim gate")
    args = ap.parse_args()

    if args.ci_gates:
        names = (args.gates.split(",") if args.gates
                 else list(DEFAULT_CI_GATES))
        unknown = [n for n in names if n not in GATES]
        if unknown:
            ap.error(f"unknown gate(s) {unknown}; available: "
                     f"{sorted(GATES)}")
        raise SystemExit(run_ci_gates(names, fleet_scale=args.fleet_scale))
    raise SystemExit(run_full_suite(args))


if __name__ == "__main__":
    main()
