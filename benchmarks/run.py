"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and writes JSON artifacts to
benchmarks/results/.  ``--fast`` shortens the trained-model benchmarks.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps for the accuracy tables")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    steps = 80 if args.fast else 250
    qat_steps = 60 if args.fast else 200

    from benchmarks import (arch_power, fig3_equal_power, fig4_mse_ratio,
                            kernel_bench, roofline, table1_bitflips,
                            table2_ptq, table3_qat, table4_addition_factor,
                            table6_accumulator, table14_footprint)

    jobs = [
        ("table1_bitflips", table1_bitflips.run, {}),
        ("fig3_equal_power", fig3_equal_power.run, {}),
        ("fig4_mse_ratio", fig4_mse_ratio.run, {}),
        ("table6_accumulator", table6_accumulator.run, {}),
        ("arch_power", arch_power.run, {}),
        ("kernel_bench", kernel_bench.run, {}),
        ("table2_ptq", table2_ptq.run, {"steps": steps}),
        ("table3_qat", table3_qat.run, {"steps": qat_steps}),
        ("table4_addition_factor", table4_addition_factor.run,
         {"steps": qat_steps}),
        ("table14_footprint", table14_footprint.run,
         {"steps": max(qat_steps, 100)}),
        ("roofline", roofline.run, {}),
    ]
    if args.only:
        keep = set(args.only.split(","))
        jobs = [j for j in jobs if j[0] in keep]

    print("name,us_per_call,derived")
    failed = []
    for name, fn, kw in jobs:
        try:
            fn(**kw)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
