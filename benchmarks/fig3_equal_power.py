"""Paper Fig. 3: equal-power (b~x, R) curves — the deployment-time knob."""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json
from repro.core import planner


def run() -> dict:
    t0 = time.perf_counter()
    curves = {}
    for bits in [2, 3, 4, 5, 6, 8]:
        p = planner.budget_from_bits(bits)
        curves[str(bits)] = {
            "power_bitflips_per_mac": p,
            "points": [{"b_x_tilde": b, "r": round(r, 3)}
                       for b, r in planner.equal_power_curve(bits)],
        }
    save_json("fig3_equal_power.json", curves)
    us = (time.perf_counter() - t0) * 1e6
    four = curves["4"]["points"]
    emit("fig3_equal_power", us,
         f"4-bit budget {curves['4']['power_bitflips_per_mac']:.0f}: "
         + " ".join(f"(b~x={p['b_x_tilde']} R={p['r']})" for p in four[:3]))
    return curves


if __name__ == "__main__":
    run()
