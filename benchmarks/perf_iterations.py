"""§Perf hillclimb driver: lower+compile the three chosen cells in baseline
and optimized variants, record before/after roofline terms.

Run as its own process (the dryrun import sets the 512-device XLA flag):

    PYTHONPATH=src:. python -m benchmarks.perf_iterations
"""
from repro.launch import dryrun as DR  # noqa: E402  (sets XLA_FLAGS first)

import json  # noqa: E402
import os  # noqa: E402

from benchmarks.roofline import analyze_record  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def cell(tag, arch, shape, **kw):
    rec = DR.run_cell(arch, shape, multi_pod=False, **kw)
    rec["tag"] = tag
    a = analyze_record(rec)
    a["tag"] = tag
    print(f"[perf] {tag}: compute {a['t_compute_s']:.3e}s "
          f"memory {a['t_memory_s']:.3e}s coll {a['t_collective_s']:.3e}s "
          f"useful {a['useful_ratio']:.3f}")
    return {"record": rec, "analysis": a}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    jobs = [
        # Iteration 3: MoE dense-scan -> capacity dispatch (dbrx + mixtral)
        ("dbrx_train_capacity", "dbrx-132b", "train_4k",
         {"extra_parallel": {"moe_impl": "capacity"}}),
        ("mixtral_train_capacity", "mixtral-8x7b", "train_4k",
         {"extra_parallel": {"moe_impl": "capacity"}}),
        # Iteration 4: masked cache update + sequence-parallel decode attn
        ("llama3_decode_seqpar", "llama3-8b", "decode_32k", {}),
        # Iteration 5: PANN int8 serving weights (on top of iter. 4)
        ("llama3_decode_pann_serve", "llama3-8b", "decode_32k",
         {"quant_mode": "pann_serve"}),
        # Iteration 7: fp8 KV cache (+ both above)
        ("llama3_decode_fp8cache", "llama3-8b", "decode_32k",
         {"extra_parallel": {"kv_cache_dtype": "float8_e4m3fn"}}),
        ("llama3_decode_pann_fp8", "llama3-8b", "decode_32k",
         {"quant_mode": "pann_serve",
          "extra_parallel": {"kv_cache_dtype": "float8_e4m3fn"}}),
        # long-context serving: fp8 cache on the gemma2 long_500k cell
        ("gemma2_long500k_fp8", "gemma2-9b", "long_500k",
         {"extra_parallel": {"kv_cache_dtype": "float8_e4m3fn"}}),
    ]
    keep = set(args.only.split(",")) if args.only else None
    out = []
    for tag, arch, shape, kw in jobs:
        if keep and tag not in keep:
            continue
        try:
            out.append(cell(tag, arch, shape, **kw))
        except Exception as e:  # noqa: BLE001
            print(f"[perf][FAIL] {tag}: {e!r}")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "perf_iterations.json")
    if os.path.exists(path) and keep:
        with open(path) as f:
            prev = json.load(f)
        prev = [p for p in prev if p["analysis"]["tag"] not in keep]
        out = prev + out
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[perf] wrote {len(out)} variant records")


if __name__ == "__main__":
    main()
