"""Inject the roofline table + perf-iteration measurements into
EXPERIMENTS.md (replaces the HTML-comment markers)."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR
from benchmarks.roofline import analyze_record, markdown_table

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def main() -> None:
    with open(os.path.join(RESULTS_DIR, "dryrun_both.json")) as f:
        data = json.load(f)
    rows, seen = [], set()
    for r in data["records"]:
        if r.get("mesh") == "multi" and "skipped" not in r:
            continue
        key = (r["arch"], r["shape"])
        if key in seen:     # resume runs re-append skip markers
            continue
        seen.add(key)
        a = analyze_record(r)
        if a:
            rows.append(a)
    table = markdown_table(rows)

    n_multi = sum(1 for r in data["records"] if r.get("mesh") == "multi")
    n_single = sum(1 for r in data["records"]
                   if r.get("mesh") == "single" and "skipped" not in r)
    n_skip = sum(1 for r in data["records"] if "skipped" in r)
    header = (f"Single-pod cells compiled: {n_single}; multi-pod cells "
              f"compiled: {n_multi}; skipped (long_500k on pure full "
              f"attention): {n_skip}; failures: "
              f"{len(data.get('failures', []))}.\n\n")

    with open(EXP) as f:
        text = f.read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", header + table)
    with open(EXP, "w") as f:
        f.write(text)
    print(f"injected roofline table ({len(rows)} rows) into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
