"""Fleet-sim benchmark: multi-host serving under a global power cap.

Boots a ``repro.serve_engine.fleet.Fleet`` (>= 4 rung-sharded decode hosts
plus a prefill host, all serving zero-copy views of ONE mmap artifact) and
drives it with the deterministic synthetic traffic trace: seeded bursty
arrivals with mixed budgets and SLO floors, a mid-run step of the GLOBAL
Gbit-flips/sec cap, and a host kill absorbed by ``dist.fault`` — then
verifies every served wave bit-for-bit against an uninterrupted
single-engine replay.

    PYTHONPATH=src python benchmarks/fleet_sim.py --reduced --check
    PYTHONPATH=src python benchmarks/fleet_sim.py --reduced --scale 4

``--check`` gates against benchmarks/baselines/fleet_sim.json:

  * requests served and realized fleet Gbit-flips (from EnergyLedger
    telemetry aggregated across hosts) must match the baseline EXACTLY —
    both are analytic functions of the seeded trace (greedy decode always
    emits a request's full token quota; prices are closed-form), so any
    drift is a scheduling/accounting change, not noise;
  * cap violations must be ZERO (the per-tick grant makes this structural);
  * the host kill must have been absorbed (>= 1 restart) and every stream
    must replay bit-identically (``verify_streams``);
  * every host keeps ONE compiled decode step across governor replans
    (``assert_no_recompile``).

Wall-clock latency/throughput ride along as informational fields only.
``--scale N`` multiplies the trace length (nightly runs a larger scale and
appends a point to the committed BENCH_fleet.json via ``--trajectory``).
Refresh the baseline by copying benchmarks/results/fleet_sim.json over it
when the fleet legitimately changes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import common  # noqa: E402
from repro import configs  # noqa: E402
from repro.configs.base import QuantConfig  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.serve_engine import artifact as afct  # noqa: E402
from repro.serve_engine.engine import ServeEngine  # noqa: E402
from repro.serve_engine.fleet import (Fleet, FleetConfig,  # noqa: E402
                                      TrafficSpec, make_trace,
                                      verify_streams)

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "fleet_sim.json")
TRAJECTORY = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_fleet.json")

# EXACT-gated result fields: deterministic functions of the seeded trace
# (token COUNTS and analytic prices — platform- and version-independent)
EXACT_FIELDS = ("served", "realized_gbitflips", "decode_tokens",
                "cap_violations", "host_restarts", "migrations",
                "slo_violations")


def run(args) -> dict:
    cfg = configs.get_config(args.arch, quant=QuantConfig(mode="none"))
    if args.reduced:
        cfg = configs.reduced(cfg)
    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)

    fc = FleetConfig(
        n_decode_hosts=args.hosts,
        n_prefill_hosts=1,
        ladder_bits=tuple(int(b) for b in args.ladder.split(",")),
        cap_gbitflips_per_s=args.cap,
        tick_seconds=1.0,
        control_interval=3,
        max_batch=args.batch,
        max_len=args.prompt_len + max(args.gen_long, args.gen_short) + 2,
        drain_tick_factor=16,
    )
    n_ticks = args.base_ticks * args.scale
    spec = TrafficSpec(
        seed=args.seed + 7,
        n_ticks=n_ticks,
        burst_prob=0.7,
        mean_burst=2.0,
        prompt_lens=(args.prompt_len,),
        gen_tokens=(args.gen_short, args.gen_long),
        budget_mix=(2, 4, 6, 6),
        slo_prob=0.3,
        slo_bits=(4,),
        # mid-run GLOBAL cap step (drops the governor's rung ceiling) and a
        # decode-host kill mid-decode (absorbed by dist.fault)
        budget_steps=((n_ticks // 2, args.cap_step),),
        host_kills=((n_ticks // 3, 1),),
    )

    art_dir = args.artifact_dir or tempfile.mkdtemp(prefix="fleet_sim_")
    t0 = time.monotonic()
    fleet = Fleet(cfg, fc, art_dir, params=params)
    build_s = time.monotonic() - t0
    trace = make_trace(spec, cfg.vocab_size, fleet.ladder)

    report = fleet.run(trace)
    fleet.assert_no_recompile()      # one jitted step per host, governed

    # the fleet-scope bit-exactness oracle: every wave (restarted, switched
    # or untouched) must equal ONE uninterrupted engine on the same artifact
    ref = ServeEngine(cfg, weight_store=afct.load_artifact(art_dir),
                      ladder_bits=fc.ladder_bits, max_batch=fc.max_batch,
                      max_len=fc.max_len)
    ref.warmup()
    mismatches = verify_streams(report, ref)

    out = {
        "arch": cfg.name,
        "reduced": bool(args.reduced),
        "platform": jax.devices()[0].platform,
        "scale": args.scale,
        "hosts": report["hosts"],
        "trace": {
            "seed": spec.seed, "n_ticks": spec.n_ticks,
            "requests": trace.n_requests,
            "cap_gbitflips_per_s": args.cap,
            "cap_step": [n_ticks // 2, args.cap_step],
            "host_kill": [n_ticks // 3, 1],
        },
        # EXACT-gated
        "served": report["served"],
        "realized_gbitflips": report["realized_gbitflips"],
        "decode_tokens": report["decode_tokens"],
        "cap_violations": report["cap_violations"],
        "host_restarts": report["host_restarts"],
        "migrations": report["migrations"],
        "slo_violations": report["slo_violations"],
        "verify_mismatches": mismatches,
        # trajectory / context
        "ticks": report["ticks"],
        "rung_token_histogram": report["rung_token_histogram"],
        "governor_replans": len(report["governor"]["replans"]),
        "final_ceiling_bits": report["governor"]["ceiling_bits"],
        "prefill_gbitflips": report["prefill_gbitflips"],
        "decode_gbitflips": report["decode_gbitflips"],
        # informational (wall clock — never gated)
        "wall_s": report["wall_s"],
        "build_s": round(build_s, 3),
        "latency_ticks_p50": report["latency_ticks_p50"],
        "ttft_ticks_p50": report["ttft_ticks_p50"],
        "straggler_steps": report["straggler_steps"],
    }
    common.emit("fleet_sim/run", report["wall_s"] * 1e6,
                f"served={out['served']} "
                f"gflips={out['realized_gbitflips']:.4f} "
                f"restarts={out['host_restarts']}")
    path = common.save_json("fleet_sim.json", out)
    print(f"[fleet_sim] wrote {path}")
    return out


def check_result(result: dict, baseline_path: str = BASELINE) -> list[str]:
    """Hard gates: structural invariants always; EXACT baseline fields at
    the baseline's scale only (a --scale override changes the trace)."""
    failures = []
    if result["hosts"]["decode"] < 4:
        failures.append(f"fleet ran {result['hosts']['decode']} decode "
                        f"hosts; the gate requires >= 4")
    if result["cap_violations"] != 0:
        failures.append(f"{result['cap_violations']} tick(s) exceeded the "
                        f"global power cap (must be 0)")
    if result["host_restarts"] < 1:
        failures.append("the scheduled host kill was not absorbed "
                        "(0 restarts recorded)")
    for m in result["verify_mismatches"]:
        failures.append(f"bit-exactness: {m}")
    with open(baseline_path) as f:
        base = json.load(f)
    if result["scale"] != base["scale"]:
        print(f"[fleet_sim] scale {result['scale']} != baseline scale "
              f"{base['scale']}; EXACT fields not compared")
        return failures
    for key in EXACT_FIELDS:
        if result[key] != base[key]:
            failures.append(f"{key}: {result[key]!r} != baseline "
                            f"{base[key]!r} (EXACT); if intended, refresh "
                            f"{baseline_path}")
    return failures


def _load_trajectory(path: str = TRAJECTORY) -> dict:
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            if isinstance(data.get("points"), list):
                return data
        except (json.JSONDecodeError, OSError):
            pass
    return {"schema": 1,
            "note": "fleet-sim trajectory; appended by "
                    "benchmarks/fleet_sim.py --trajectory in nightly CI. "
                    "served/gbitflips are exact per scale; wall_s and "
                    "latency are advisory.",
            "points": []}


def append_trajectory(result: dict, path: str = TRAJECTORY) -> str:
    traj = _load_trajectory(path)
    traj["points"].append({
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "platform": result["platform"],
        "scale": result["scale"],
        "served": result["served"],
        "realized_gbitflips": result["realized_gbitflips"],
        "ticks": result["ticks"],
        "wall_s": result["wall_s"],
        "latency_ticks_p50": result["latency_ticks_p50"],
        "host_restarts": result["host_restarts"],
        "migrations": result["migrations"],
    })
    with open(path, "w") as f:
        json.dump(traj, f, indent=1)
        f.write("\n")
    print(f"[fleet_sim] trajectory point {len(traj['points'])} -> {path}")
    return path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--hosts", type=int, default=4,
                    help="decode hosts (+1 prefill host)")
    ap.add_argument("--ladder", default="2,4,6")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt_len", type=int, default=6)
    ap.add_argument("--gen_short", type=int, default=6)
    ap.add_argument("--gen_long", type=int, default=10)
    ap.add_argument("--cap", type=float, default=0.25,
                    help="global cap, Gbit-flips/sec")
    ap.add_argument("--cap_step", type=float, default=0.035,
                    help="mid-run global cap step target")
    ap.add_argument("--base_ticks", type=int, default=12)
    ap.add_argument("--scale", type=int, default=1,
                    help="trace length multiplier (nightly runs > 1; "
                         "EXACT baseline fields gate at scale 1 only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--artifact_dir", default=None,
                    help="reuse/persist the serving artifact here")
    ap.add_argument("--check", action="store_true",
                    help="gate against the committed baseline snapshot")
    ap.add_argument("--trajectory", action="store_true",
                    help="append this run to the committed BENCH_fleet.json")
    args = ap.parse_args(argv)

    result = run(args)
    if args.trajectory:
        append_trajectory(result)
    if args.check:
        failures = check_result(result)
        if failures:
            for f in failures:
                print(f"[fleet_sim] REGRESSION: {f}")
            raise SystemExit(1)
        print("[fleet_sim] baseline check passed")
    return result


if __name__ == "__main__":
    main()
