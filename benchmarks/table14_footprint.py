"""Paper Tables 14-15 analogue: PANN runtime memory footprint and latency —
plus the MEASURED weight+cache footprint gate for the quantized KV cache.

Two instruments:

  * ``run(steps)`` — the original trained-weights analysis: per power
    budget, the optimal (b~x, R) plan, the measured addition factor and
    weight-storage bits b_R, and the derived memory/latency factors.
  * ``measure_footprint()`` — byte-counted serving footprint on a real
    (reduced) artifact: the packed bit-plane weight leaves + the quantized
    KV decode cache vs the fp32 weights + fp cache, per ladder budget.
    This is what ``--check`` gates: the committed baseline snapshot
    (benchmarks/baselines/footprint.json) must be matched within tolerance
    AND the 4-bit budget must keep a >= 2x combined weight+cache reduction
    (docs/kv_cache.md; the PR-7 acceptance floor).
  * ``measure_ladder_depth()`` — the LADDER-DEPTH gate (DESIGN.md §11):
    unique weight-store bytes for a 2-rung vs 5-rung ladder under the
    zero-copy 'views' materialization must stay flat (<= 1.10x; deeper
    ladders add only per-rung scalars) while the legacy per-rung
    quantizer shows its near-linear growth. Baseline-free hard invariant,
    also asserted by ``--check``.

Refresh the baseline by copying benchmarks/results/footprint.json over
benchmarks/baselines/footprint.json when the reduced config or the artifact
layout legitimately changes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, save_json, train_small_lm
from repro import configs
from repro.configs.base import QuantConfig
from repro.core import pann as pann_core
from repro.core import planner
from repro.models import model as MD
from repro.models import serving

# combined weight+cache reduction the 4-bit budget must clear (ISSUE 7
# acceptance criterion) — a HARD floor, independent of the baseline
MIN_REDUCTION_AT_4BIT = 2.0

# footprint ratios are deterministic shape math; the tolerance only absorbs
# benign layout drift (e.g. a new tiny artifact leaf), not regressions
REGRESSION_TOLERANCE = 0.05

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "footprint.json")

# deployed-weight leaves the 'packed' decode step actually reads; w_q (the
# unpacked int8 codes) is the non-packed backends' input and is NOT shipped
# alongside the planes, so it does not count toward the packed footprint
_PACKED_WEIGHT_KEYS = {"w_planes_pos", "w_planes_neg", "w_scale",
                       "w_colsum", "act_n", "act_nlvl", "b"}


def run(steps: int = 120) -> dict:
    t0 = time.perf_counter()
    tl = train_small_lm(steps=steps)

    # collect all 2-D projection weights of the trained model
    weights = [l for p, l in
               jax.tree_util.tree_flatten_with_path(tl.params)[0]
               if getattr(p[-1], "key", "") == "w" and l.ndim == 2]

    rows = []
    for bits in [2, 3, 4, 5, 6, 8]:
        budget = planner.budget_from_bits(bits)
        plan = planner.plan_with_theory(budget)
        b_rs, add_factors = [], []
        for w in weights:
            w_q, _ = pann_core.pann_quantize(w, plan.r, axis=0)
            b_rs.append(pann_core.weight_storage_bits(w_q))
            add_factors.append(float(
                pann_core.additions_per_element(w_q).mean()))
        b_r = int(np.max(b_rs))
        rows.append({
            "power_bits": bits,
            "b_x_tilde": plan.b_x_tilde,
            "latency_R": round(plan.r, 2),
            "realized_additions": round(float(np.mean(add_factors)), 2),
            "b_R_weight_bits": b_r,
            "act_mem_factor": round(plan.b_x_tilde / bits, 2),
            "weight_mem_factor": round(b_r / bits, 2),
        })
    save_json("table14_footprint.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    r2 = rows[0]
    emit("table14_footprint", us,
         f"2-bit budget: b~x={r2['b_x_tilde']} R={r2['latency_R']} "
         f"b_R={r2['b_R_weight_bits']} act-mem x{r2['act_mem_factor']}")
    return rows


# ---------------------------------------------------------------------------
# Measured weight+cache serving footprint (the --check gate)
# ---------------------------------------------------------------------------

def _leaf_bytes(tree, keys=None) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if not hasattr(leaf, "dtype"):
            continue
        if keys is None or getattr(path[-1], "key", "") in keys:
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


def measure_footprint(arch: str = "llama3-8b", budgets=(2, 4, 6),
                      batch: int = 2, max_len: int = 64, seed: int = 0
                      ) -> list[dict]:
    """Byte-count the deployed serving state per ladder budget.

    Weights: the packed bit-plane artifact (2 x b_R planes at 8 codes/byte
    + scales/colsum/act leaves) vs the SAME projections in fp32 (4 B/elem;
    w_q's logical shape). Cache: the whole quantized decode state (packed
    7-plane K/V codes + per-position quantizer rows) vs the fp decode
    state — both from ``model.init_decode_state``, so every cached layer of
    the real architecture is counted, not a per-layer estimate.
    """
    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)

    fp_state = MD.init_decode_state(params, cfg, batch, max_len)
    fp_cache_bytes = _leaf_bytes(fp_state)

    rows = []
    for bits in sorted({int(b) for b in budgets}):
        plan = planner.plan_with_theory(planner.budget_from_bits(bits))
        cache_b = min(bits, 7)
        cfg_q = dataclasses.replace(cfg, cache_bits=cache_b)
        art = serving.quantize_params_for_serving(
            params, cfg_q, r=plan.r, act_bits=plan.b_x_tilde,
            pack_planes=True, cache_bits=cache_b)
        w_packed = _leaf_bytes(art, _PACKED_WEIGHT_KEYS)
        # fp32 bytes of the same projections: w_q preserves W's shape
        w_fp = 4 * sum(
            int(np.prod(leaf.shape))
            for path, leaf in jax.tree_util.tree_leaves_with_path(art)
            if getattr(path[-1], "key", "") == "w_q")
        q_state = MD.init_decode_state(art, cfg_q, batch, max_len)
        q_cache_bytes = _leaf_bytes(q_state)
        reduction = (w_fp + fp_cache_bytes) / max(w_packed + q_cache_bytes,
                                                  1)
        rows.append({
            "power_bits": bits,
            "cache_bits": cache_b,
            "weight_bytes_fp": w_fp,
            "weight_bytes_packed": w_packed,
            "cache_bytes_fp": fp_cache_bytes,
            "cache_bytes_quant": q_cache_bytes,
            "weight_reduction": round(w_fp / max(w_packed, 1), 3),
            "cache_reduction": round(fp_cache_bytes
                                     / max(q_cache_bytes, 1), 3),
            "combined_reduction": round(reduction, 3),
        })
    return rows


# ---------------------------------------------------------------------------
# Ladder-depth gate: weight-store HBM must not scale with rung count
# ---------------------------------------------------------------------------

# views: deeper ladders add only per-rung scalars + w_colsum rows
# (manifest-level overhead) on top of ONE shared max-budget store. On the
# reduced CI shapes a colsum row (n f32) is a visible fraction of a k x n
# int8 store, so "flat" is ~1.04x for 2 -> 5 rungs here; at real model
# shapes (k >= 4096) the same overhead is < 1%. The floor guards the
# failure mode that matters — any per-rung copy of a BIG leaf (codes or
# planes) blows straight past 1.10x toward legacy's ~2.5x.
LADDER_FLAT_TOLERANCE = 1.10
# legacy materializes a full artifact per rung; 2 -> 5 rungs must show the
# near-linear growth the views path exists to kill (sub-2.5x only because
# narrow rungs pack fewer planes)
LEGACY_MIN_GROWTH = 1.8


def _unique_leaf_bytes(*trees) -> int:
    """Byte count deduplicated by array identity: zero-copy rung views
    reference the store's big leaves by the SAME object, and counting
    them once per view would report the HBM scaling the artifact was
    built to avoid."""
    seen, total = set(), 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if not hasattr(leaf, "dtype") or id(leaf) in seen:
                continue
            seen.add(id(leaf))
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


def measure_ladder_depth(arch: str = "llama3-8b", shallow=(2, 4),
                         deep=(2, 3, 4, 5, 6), seed: int = 0) -> dict:
    """Unique weight-store bytes for a shallow vs deep ladder, under both
    materializations (DESIGN.md §11): 'views' quantizes once at the
    per-module max budget and serves rungs as zero-copy views; 'legacy'
    runs the per-rung quantizer and pays for every rung."""
    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="none"))
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)

    def plans(bits_list):
        return {int(b): planner.plan_with_theory(planner.budget_from_bits(
            int(b))) for b in bits_list}

    def views_bytes(bits_list):
        specs = {b: (p.r, p.b_x_tilde) for b, p in plans(bits_list).items()}
        ws = serving.build_weight_store(params, cfg, specs,
                                        pack_planes=True)
        return _unique_leaf_bytes(ws.store, *ws.views.values())

    def legacy_bytes(bits_list):
        return sum(
            _unique_leaf_bytes(serving.quantize_params_for_serving(
                params, cfg, r=p.r, act_bits=p.b_x_tilde, pack_planes=True))
            for p in plans(bits_list).values())

    row = {
        "shallow_rungs": sorted(int(b) for b in shallow),
        "deep_rungs": sorted(int(b) for b in deep),
        "views_bytes_shallow": views_bytes(shallow),
        "views_bytes_deep": views_bytes(deep),
        "legacy_bytes_shallow": legacy_bytes(shallow),
        "legacy_bytes_deep": legacy_bytes(deep),
    }
    row["views_growth"] = round(
        row["views_bytes_deep"] / max(row["views_bytes_shallow"], 1), 3)
    row["legacy_growth"] = round(
        row["legacy_bytes_deep"] / max(row["legacy_bytes_shallow"], 1), 3)
    return row


def check_ladder_depth(row: dict) -> list[str]:
    """Hard invariants, deliberately baseline-free: flatness is a property
    of the artifact design, not of any particular committed snapshot."""
    failures = []
    n_sh, n_dp = len(row["shallow_rungs"]), len(row["deep_rungs"])
    if row["views_growth"] > LADDER_FLAT_TOLERANCE:
        failures.append(
            f"views weight store grew {row['views_growth']:.3f}x going "
            f"{n_sh} -> {n_dp} rungs (flat floor {LADDER_FLAT_TOLERANCE}x)"
            f" — rung views are no longer zero-copy over one store")
    if row["legacy_growth"] < LEGACY_MIN_GROWTH:
        failures.append(
            f"legacy per-rung growth {row['legacy_growth']:.2f}x < "
            f"{LEGACY_MIN_GROWTH}x going {n_sh} -> {n_dp} rungs — the "
            f"legacy measurement no longer materializes per rung, so the "
            f"views comparison is vacuous")
    if row["views_bytes_deep"] >= row["legacy_bytes_deep"]:
        failures.append(
            f"deep ladder: views store ({row['views_bytes_deep']} B) is "
            f"not smaller than legacy ({row['legacy_bytes_deep']} B)")
    return failures


def check_footprint(rows: list[dict], baseline_path: str = BASELINE
                    ) -> list[str]:
    """The gate: baseline match within tolerance + the 4-bit hard floor."""
    failures = []
    at4 = next((r for r in rows if r["power_bits"] == 4), None)
    if at4 is None:
        failures.append("no 4-bit budget row measured — the acceptance "
                        "floor is ungated")
    elif at4["combined_reduction"] < MIN_REDUCTION_AT_4BIT:
        failures.append(
            f"4-bit budget: combined weight+cache reduction "
            f"{at4['combined_reduction']:.2f}x < the "
            f"{MIN_REDUCTION_AT_4BIT:.1f}x floor")
    with open(baseline_path) as f:
        base = {r["power_bits"]: r for r in json.load(f)["footprint"]}
    measured = {r["power_bits"]: r for r in rows}
    for bits in sorted(set(base) - set(measured)):
        failures.append(f"budget {bits}b: in the baseline but not measured "
                        f"— refresh {baseline_path}")
    for bits, r in sorted(measured.items()):
        b = base.get(bits)
        if b is None:
            failures.append(f"budget {bits}b: no baseline entry — refresh "
                            f"{baseline_path}")
            continue
        floor = (1.0 - REGRESSION_TOLERANCE) * b["combined_reduction"]
        if r["combined_reduction"] < floor:
            failures.append(
                f"budget {bits}b: combined reduction "
                f"{r['combined_reduction']:.2f}x < {floor:.2f}x "
                f"(baseline {b['combined_reduction']:.2f}x - "
                f"{REGRESSION_TOLERANCE:.0%})")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config, random-init weights, skip the "
                         "trained-weights Table-14 sweep (the CI gate mode)")
    ap.add_argument("--budgets", default="2,4,6")
    ap.add_argument("--steps", type=int, default=120,
                    help="training steps for the trained-weights sweep "
                         "(ignored with --reduced)")
    ap.add_argument("--check", action="store_true",
                    help="gate against the committed baseline snapshot")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    budgets = [int(b) for b in args.budgets.split(",")]
    result = {
        "mode": "reduced" if args.reduced else "full",
        "arch": args.arch,
        "table": None if args.reduced else run(steps=args.steps),
        "footprint": measure_footprint(args.arch, budgets),
        "ladder_depth": measure_ladder_depth(args.arch),
    }
    save_json("footprint.json", result)
    at4 = next((r for r in result["footprint"] if r["power_bits"] == 4),
               result["footprint"][0])
    emit("footprint", (time.perf_counter() - t0) * 1e6,
         f"{at4['power_bits']}-bit budget: weights "
         f"x{at4['weight_reduction']} cache x{at4['cache_reduction']} "
         f"combined x{at4['combined_reduction']}")
    ld = result["ladder_depth"]
    print(f"[footprint] ladder depth {len(ld['shallow_rungs'])} -> "
          f"{len(ld['deep_rungs'])} rungs: views x{ld['views_growth']} "
          f"(flat), legacy x{ld['legacy_growth']}")
    if args.check:
        failures = check_footprint(result["footprint"])
        failures += check_ladder_depth(result["ladder_depth"])
        if failures:
            for f in failures:
                print(f"[footprint] REGRESSION: {f}")
            raise SystemExit(1)
        print("[footprint] baseline check passed")
    return result


if __name__ == "__main__":
    main()
