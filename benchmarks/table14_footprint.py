"""Paper Tables 14-15 analogue: PANN runtime memory footprint and latency.

For each power budget (expressed as a b-bit unsigned MAC): the optimal
(b~x, R) plan, the measured per-neuron addition factor and weight-storage
bits b_R on real (trained) weights, and the derived activation/weight memory
and latency factors relative to the b-bit baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json, train_small_lm
from repro.core import pann as pann_core
from repro.core import planner


def run(steps: int = 120) -> dict:
    t0 = time.perf_counter()
    tl = train_small_lm(steps=steps)

    # collect all 2-D projection weights of the trained model
    weights = [l for p, l in
               jax.tree_util.tree_flatten_with_path(tl.params)[0]
               if getattr(p[-1], "key", "") == "w" and l.ndim == 2]

    rows = []
    for bits in [2, 3, 4, 5, 6, 8]:
        budget = planner.budget_from_bits(bits)
        plan = planner.plan_with_theory(budget)
        b_rs, add_factors = [], []
        for w in weights:
            w_q, _ = pann_core.pann_quantize(w, plan.r, axis=0)
            b_rs.append(pann_core.weight_storage_bits(w_q))
            add_factors.append(float(
                pann_core.additions_per_element(w_q).mean()))
        b_r = int(np.max(b_rs))
        rows.append({
            "power_bits": bits,
            "b_x_tilde": plan.b_x_tilde,
            "latency_R": round(plan.r, 2),
            "realized_additions": round(float(np.mean(add_factors)), 2),
            "b_R_weight_bits": b_r,
            "act_mem_factor": round(plan.b_x_tilde / bits, 2),
            "weight_mem_factor": round(b_r / bits, 2),
        })
    save_json("table14_footprint.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    r2 = rows[0]
    emit("table14_footprint", us,
         f"2-bit budget: b~x={r2['b_x_tilde']} R={r2['latency_R']} "
         f"b_R={r2['b_R_weight_bits']} act-mem x{r2['act_mem_factor']}")
    return rows


if __name__ == "__main__":
    run()
