"""Paper Table 2 (and Tables 7-9) analogue: post-training quantization at
matched power budgets.

Protocol (faithful to the paper, on our stand-in task):
  1. train a small LM in full precision,
  2. baselines: RUQ at b bits (weights AND activations, per the paper),
  3. PANN: remove the multiplier, choose (b~x, R) with Algorithm 1 at the
     SAME power budget (the b-bit unsigned-MAC cost),
  4. report next-token accuracy per power row.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, eval_accuracy, save_json, train_small_lm
from repro.configs.base import QuantConfig
from repro.core import planner
from repro.core import costs


def run(steps: int = 250) -> dict:
    t0 = time.perf_counter()
    tl = train_small_lm(steps=steps)
    fp_acc = eval_accuracy(tl, QuantConfig(mode="none"))
    macs = costs.network_macs(tl.cfg, type("S", (), {
        "seq_len": 64, "global_batch": 16, "kind": "train"})()).total

    rows = []
    for bits in [8, 6, 5, 4, 3, 2]:
        budget = planner.budget_from_bits(bits)
        base = eval_accuracy(tl, QuantConfig(mode="ruq_unsigned",
                                             weight_bits=bits,
                                             act_bits=bits))

        def eval_fn(b_x, r):
            return eval_accuracy(tl, QuantConfig(mode="pann", r=r,
                                                 act_bits_tilde=b_x))

        plan = planner.plan_with_eval(budget, eval_fn)
        rows.append({
            "bits": bits,
            "power_bitflips_per_mac": round(budget, 1),
            "network_giga_bitflips": round(budget * macs / 1e9, 2),
            "baseline_ruq_acc": round(base, 4),
            "pann_acc": round(plan.score, 4),
            "pann_bx_tilde": plan.b_x_tilde,
            "pann_r": round(plan.r, 2),
        })
    out = {"fp_accuracy": round(fp_acc, 4), "rows": rows}
    save_json("table2_ptq.json", out)
    us = (time.perf_counter() - t0) * 1e6
    two = rows[-1]
    emit("table2_ptq", us,
         f"fp {fp_acc:.3f}; 2-bit budget: RUQ {two['baseline_ruq_acc']:.3f} "
         f"vs PANN {two['pann_acc']:.3f}")
    return out


if __name__ == "__main__":
    run()
