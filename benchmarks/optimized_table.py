"""Baseline-vs-optimized roofline comparison across all single-pod cells,
from dryrun_both.json (baseline) and optimized/dryrun_single.json."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR
from benchmarks.roofline import analyze_record

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
MARK = "<!-- OPTIMIZED_TABLE -->"


def load(path):
    with open(os.path.join(RESULTS_DIR, path)) as f:
        data = json.load(f)
    out = {}
    for r in data["records"]:
        if r.get("mesh") != "single" or r.get("skipped"):
            continue
        key = (r["arch"], r["shape"])
        if key not in out:
            out[key] = analyze_record(r)
    return out


def main() -> None:
    base = load("dryrun_both.json")
    opt = load("optimized/dryrun_single.json")
    lines = [
        "| arch | shape | bound term (base → opt) | useful (base → opt) |",
        "|---|---|---|---|",
    ]
    improved = 0
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        tb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        to = max(o["t_compute_s"], o["t_memory_s"], o["t_collective_s"])
        mark = ""
        if to < 0.95 * tb or o["useful_ratio"] > 1.05 * b["useful_ratio"]:
            improved += 1
            mark = " ✓"
        lines.append(
            f"| {key[0]} | {key[1]} | {tb:.3e} → {to:.3e}{mark} | "
            f"{b['useful_ratio']:.2f} → {o['useful_ratio']:.2f} |")
    table = "\n".join(lines)
    print(f"{improved}/{len(opt)} cells improved")
    with open(EXP) as f:
        text = f.read()
    if MARK in text:
        text = text.replace(MARK, table)
        with open(EXP, "w") as f:
            f.write(text)
        print("injected optimized table into EXPERIMENTS.md")
    else:
        print(table)


if __name__ == "__main__":
    main()
