"""Shared benchmark utilities: timing, CSV emission, a small trainable LM."""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# ---------------------------------------------------------------------------
# Per-device-kind peak table — the ONE copy of the hardware constants shared
# by the roofline analysis AND the roofline CI gate (benchmarks/roofline.py).
# Keys follow jax's device_kind strings. Values per chip:
#   peak_flops   bf16 MXU peak (FLOP/s)
#   peak_int8    int8 MXU peak (OP/s) — the serving kernels' compute roof
#   hbm_bw       HBM bandwidth (byte/s)
#   ici_bw       ICI bandwidth per link (byte/s)
# ---------------------------------------------------------------------------

DEVICE_PEAKS = {
    "TPU v4":  {"peak_flops": 275e12, "peak_int8": 275e12,
                "hbm_bw": 1228e9, "ici_bw": 50e9},
    "TPU v5e": {"peak_flops": 197e12, "peak_int8": 394e12,
                "hbm_bw": 819e9, "ici_bw": 50e9},
    "TPU v5p": {"peak_flops": 459e12, "peak_int8": 918e12,
                "hbm_bw": 2765e9, "ici_bw": 100e9},
    "TPU v6e": {"peak_flops": 918e12, "peak_int8": 1836e12,
                "hbm_bw": 1640e9, "ici_bw": 100e9},
    # interpret-mode hosts: placeholder roof so the analysis stays runnable
    # off-TPU (the CI gate never applies timing thresholds on these)
    "cpu":     {"peak_flops": 1e12, "peak_int8": 2e12,
                "hbm_bw": 100e9, "ici_bw": 10e9},
}


def device_peaks(kind: str | None = None) -> dict:
    """Peaks for ``kind`` (default: the host's first device). Unknown kinds
    fall back to TPU v5e — the repo's reference part — with a note so the
    analysis is visibly approximate rather than silently wrong."""
    if kind is None:
        try:
            kind = jax.devices()[0].device_kind
        except Exception:
            kind = "cpu"
    if kind in DEVICE_PEAKS:
        return {"device_kind": kind, **DEVICE_PEAKS[kind]}
    base = "cpu" if kind.lower() in ("cpu", "gpu") else "TPU v5e"
    return {"device_kind": kind, "assumed": base, **DEVICE_PEAKS[base]}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def time_call(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (after warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------------------
# Small trainable LM for the accuracy benchmarks (Tables 2-4 analogues).
# The paper's benchmarks are ImageNet/CIFAR classifiers; our stand-in task is
# next-token classification on the structured synthetic stream — same
# protocol (train fp32 -> PTQ/QAT at matched power -> accuracy).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainedLM:
    cfg: object
    params: dict
    val_tokens: jnp.ndarray
    val_labels: jnp.ndarray


def train_small_lm(steps: int = 250, seed: int = 0, arch: str = "llama3-8b",
                   vocab: int = 64, qat_quant=None, lr: float = 2e-3
                   ) -> TrainedLM:
    from repro import configs
    from repro.configs.base import QuantConfig, TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.models import model as MD
    from repro.optim.optimizers import AdamW

    cfg = configs.reduced(configs.get_config(arch))
    cfg = dataclasses.replace(cfg, vocab_size=vocab, num_layers=2,
                              quant=qat_quant or QuantConfig(mode="none"))
    tcfg = TrainConfig(lr=lr, warmup_steps=10, total_steps=steps,
                       weight_decay=0.0)
    data = SyntheticLM(vocab_size=vocab, seq_len=64, global_batch=16,
                       seed=seed, structure=0.85)
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)
    opt = AdamW(tcfg)
    ostate = opt.init(params)

    @jax.jit
    def step_fn(params, ostate, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: MD.lm_loss(p, cfg, tokens, labels, remat=False))(params)
        params, ostate, _ = opt.update(grads, ostate, params)
        return params, ostate, loss

    for step in range(steps):
        b = data.global_batch_arrays(step)
        params, ostate, loss = step_fn(params, ostate,
                                       jnp.asarray(b["tokens"]),
                                       jnp.asarray(b["labels"]))
    vb = data.global_batch_arrays(10_000)
    return TrainedLM(cfg=cfg, params=params,
                     val_tokens=jnp.asarray(vb["tokens"]),
                     val_labels=jnp.asarray(vb["labels"]))


def eval_accuracy(tl: TrainedLM, quant) -> float:
    """Top-1 next-token accuracy under a QuantConfig."""
    from repro.models import model as MD
    cfg = dataclasses.replace(tl.cfg, quant=quant)
    out = jax.jit(lambda p, t: MD.forward(p, cfg, t, remat=False))(
        tl.params, tl.val_tokens)
    pred = jnp.argmax(out.logits[..., :tl.cfg.vocab_size], axis=-1)
    mask = tl.val_labels >= 0
    return float((jnp.where(mask, pred == tl.val_labels, False)).sum()
                 / mask.sum())
