"""Paper Table 3 / Table 10 analogue: quantization-aware training.

LSQ-style RUQ QAT (STE fake-quant in the train step) vs PANN QAT at the same
power budget, at 2/3/4-bit budgets.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, eval_accuracy, save_json, train_small_lm
from repro.configs.base import QuantConfig
from repro.core import planner


def run(steps: int = 200) -> dict:
    t0 = time.perf_counter()
    rows = []
    for bits in [4, 3, 2]:
        budget = planner.budget_from_bits(bits)
        plan = planner.plan_with_theory(budget)
        qat_ruq = QuantConfig(mode="ruq_unsigned", weight_bits=bits,
                              act_bits=bits, qat=True)
        qat_pann = QuantConfig(mode="pann", r=plan.r,
                               act_bits_tilde=plan.b_x_tilde, qat=True)
        tl_ruq = train_small_lm(steps=steps, qat_quant=qat_ruq)
        tl_pann = train_small_lm(steps=steps, qat_quant=qat_pann)
        rows.append({
            "bits": bits,
            "power_bitflips_per_mac": round(budget, 1),
            "lsq_style_ruq_acc": round(eval_accuracy(tl_ruq, qat_ruq), 4),
            "pann_acc": round(eval_accuracy(tl_pann, qat_pann), 4),
            "pann_bx_tilde": plan.b_x_tilde,
            "pann_r": round(plan.r, 2),
        })
    save_json("table3_qat.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    two = rows[-1]
    emit("table3_qat", us,
         f"2-bit QAT: RUQ {two['lsq_style_ruq_acc']:.3f} vs "
         f"PANN {two['pann_acc']:.3f}")
    return rows


if __name__ == "__main__":
    run()
