"""Paper Table 1 + Figs. 8-11: bit flips per MAC, simulated vs the analytic
model, for signed/unsigned and mixed-width multipliers."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import bitflip as bf
from repro.core import power as pw

N = 30_000


def run() -> dict:
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    rows = []
    for b in range(2, 9):
        ws, xs = (bf.draw_uniform_signed(rng, b, N) for _ in range(2))
        wu, xu = (bf.draw_uniform_unsigned(rng, b, N) for _ in range(2))
        mult_s = bf.simulate_multiplier(ws, xs, b, b, kind="booth")
        mult_u = bf.simulate_multiplier(wu, xu, b, b, kind="booth")
        acc_s = bf.simulate_accumulator(ws * xs, 32)
        acc_u = bf.simulate_accumulator(wu * xu, 32)
        rows.append({
            "b": b,
            "mult_signed_sim": round(mult_s.total, 2),
            "mult_model": pw.p_mult_signed(b),
            "acc_signed_sim": round(acc_s.total, 2),
            "acc_signed_model": pw.p_acc_signed(b, 32),
            "acc_unsigned_sim": round(acc_u.total, 2),
            "acc_unsigned_model": pw.p_acc_unsigned(b),
            "unsigned_ratio_mult": round(mult_u.internal_toggles
                                         / max(mult_s.internal_toggles, 1e-9),
                                         3),
        })
    # Observation 2: mixed widths, b_x = 8
    mixed = []
    x8s = bf.draw_uniform_signed(rng, 8, N)
    x8u = bf.draw_uniform_unsigned(rng, 8, N)
    for b_w in [8, 6, 4, 2]:
        s = bf.simulate_multiplier(bf.draw_uniform_signed(rng, b_w, N), x8s,
                                   b_w, 8).internal_toggles
        u = bf.simulate_multiplier(bf.draw_uniform_unsigned(rng, b_w, N), x8u,
                                   b_w, 8, kind="serial").internal_toggles
        mixed.append({"b_w": b_w, "signed_internal": round(s, 2),
                      "unsigned_internal_serial": round(u, 2),
                      "model_eq7": pw.p_mult_mixed(b_w, 8) - 0.5 * (b_w + 8)})
    out = {"table1": rows, "observation2_mixed": mixed}
    save_json("table1_bitflips.json", out)
    us = (time.perf_counter() - t0) * 1e6
    b4 = rows[2]
    emit("table1_bitflips", us,
         f"b=4 MAC signed sim {b4['mult_signed_sim'] + b4['acc_signed_sim']:.1f}"
         f" vs model {pw.p_mac_signed(4):.0f}")
    return out


if __name__ == "__main__":
    run()
