"""Roofline analysis from the dry-run artifacts — and the roofline CI gate.

Per (arch x shape) on the single-pod mesh:
    compute term    = HLO_FLOPs / (chips x bf16 peak)
    memory term     = HLO_bytes / (chips x HBM bandwidth)
    collective term = collective_bytes / (chips x ICI bandwidth per link)

HLO_FLOPs/bytes are the probe-corrected per-device values x chips (XLA's
cost_analysis counts while-loop bodies once; the dry-run probes fold trip
counts back in — see launch/dryrun.py). MODEL_FLOPS = 6·N·D (train) /
2·N·D (inference) with N the MoE-active parameter count.

Hardware peaks come from the per-device-kind table in ``benchmarks.common``
(DEVICE_PEAKS) — shared with the gate below, no hardcoded v5e constants.

``--check`` runs the ROOFLINE GATE (docs/kernels.md "reading the roofline
gate"): on TPU it times each Pallas serving backend on a prefill-shaped
projection and FAILS if the achieved int8 OP/s drop below the stated
fraction of the device's int8 MXU peak (GATE_THRESHOLDS; re-measured
per-device floors override via $REPRO_ROOFLINE_FLOORS — see
``gate_thresholds`` and docs/kernels.md "Re-measuring the roofline
floors"). Off-TPU the
timing gate skips cleanly — interpret-mode timings measure the emulator —
but the analysis invariants are still asserted so CPU CI catches formula
regressions the moment they land, not on the next TPU run.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

from benchmarks.common import (RESULTS_DIR, DEVICE_PEAKS, device_peaks,
                               emit, save_json, time_call)

# Stated minimum fraction of the device's int8 MXU peak each Pallas backend
# must achieve on the gate's prefill-shaped projection (m=512, k=n=1024).
# fused streams 2P unpacked plane bytes per weight; packed trades HBM bytes
# for VPU unpack work, so its compute-roof floor is lower.
# Measurement procedure behind these numbers: docs/kernels.md
# "Re-measuring the roofline floors". Per-device re-measured floors can be
# applied without editing this file via $REPRO_ROOFLINE_FLOORS
# (gate_thresholds below).
GATE_THRESHOLDS = {"fused": 0.15, "packed": 0.08}
GATE_SHAPE = (512, 1024, 1024)     # (m, k, n): compute-visible, VMEM-safe

FLOORS_ENV = "REPRO_ROOFLINE_FLOORS"


def gate_thresholds() -> dict:
    """The floors the gate actually enforces: GATE_THRESHOLDS overlaid with
    $REPRO_ROOFLINE_FLOORS (a JSON object, e.g. '{"fused": 0.22}') so a
    re-measured device kind can tighten/loosen floors per-deployment
    without a source edit. Keys must name known backends and values must
    be fractions in (0, 1) — anything else fails loudly rather than
    silently gating on garbage."""
    raw = os.environ.get(FLOORS_ENV, "")
    if not raw:
        return dict(GATE_THRESHOLDS)
    try:
        override = json.loads(raw)
    except json.JSONDecodeError as e:
        raise SystemExit(
            f"[roofline-gate] ${FLOORS_ENV} is not valid JSON: {e}\n"
            f"  value: {raw!r}")
    if not isinstance(override, dict):
        raise SystemExit(
            f"[roofline-gate] ${FLOORS_ENV} must be a JSON object "
            f"{{backend: floor}}, got {type(override).__name__}")
    unknown = sorted(set(override) - set(GATE_THRESHOLDS))
    if unknown:
        raise SystemExit(
            f"[roofline-gate] ${FLOORS_ENV} names unknown backend(s) "
            f"{unknown}; known: {sorted(GATE_THRESHOLDS)}")
    for backend, floor in override.items():
        if not isinstance(floor, (int, float)) or isinstance(floor, bool) \
                or not 0.0 < float(floor) < 1.0:
            raise SystemExit(
                f"[roofline-gate] ${FLOORS_ENV}[{backend!r}] must be a "
                f"fraction of int8 peak in (0, 1), got {floor!r}")
    return {**GATE_THRESHOLDS,
            **{b: float(f) for b, f in override.items()}}


def analyze_record(r: dict, peaks: dict | None = None) -> dict | None:
    # dry-run artifacts are produced against the repo's reference part;
    # pass peaks= to re-price them for another device kind
    pk = peaks or device_peaks("TPU v5e")
    if r.get("skipped"):
        return {"arch": r["arch"], "shape": r["shape"],
                "skipped": r["skipped"]}
    n = r["n_devices"]
    # probe extrapolation can go slightly negative on near-zero terms
    flops_dev = max(r.get("flops_per_device_corrected",
                          r.get("flops_per_device", 0.0)), 0.0)
    bytes_dev = max(r.get("bytes_per_device_corrected",
                          r.get("bytes_per_device", 0.0)), 0.0)
    coll_dev = max(r.get("collective_bytes_corrected",
                         (r.get("collective_bytes_per_device") or {})
                         .get("total", 0.0)), 0.0)
    t_compute = flops_dev / pk["peak_flops"]
    t_memory = bytes_dev / pk["hbm_bw"]
    t_coll = coll_dev / pk["ici_bw"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = r.get("model_flops_global", 0.0)
    hlo_global = flops_dev * n
    out = {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "quant": r.get("quant", "none"),
        "device_kind": pk["device_kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        # roofline fraction: the useful fraction of the bound set by the
        # dominant term (what fraction of ideal-compute time the step needs)
        "roofline_fraction": (model_flops / pk["peak_flops"] / n)
        / max(max(terms.values()), 1e-30),
    }
    return out


def run(paths=("dryrun_both.json", "dryrun_single.json")) -> list:
    t0 = time.perf_counter()
    rows, seen = [], set()
    for p in paths:
        full = os.path.join(RESULTS_DIR, p)
        if not os.path.exists(full):
            continue
        with open(full) as f:
            data = json.load(f)
        for r in data.get("records", []):
            if r.get("mesh") == "multi":
                continue
            key = (r["arch"], r["shape"])
            if key in seen:
                continue
            seen.add(key)
            a = analyze_record(r)
            if a:
                rows.append(a)
    save_json("roofline.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    live = [r for r in rows if "skipped" not in r]
    if live:
        worst = min(live, key=lambda r: r["roofline_fraction"])
        emit("roofline", us,
             f"{len(live)} cells; worst fraction "
             f"{worst['roofline_fraction']:.3f} ({worst['arch']} x "
             f"{worst['shape']})")
    else:
        emit("roofline", us, "no dry-run records yet — run launch/dryrun")
    return rows


def markdown_table(rows: list) -> str:
    live = [r for r in rows if "skipped" not in r]
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(live, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    skipped = [r for r in rows if "skipped" in r]
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                     f"{r['skipped']} | — | — |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# The CI gate
# ---------------------------------------------------------------------------

def assert_invariants(rows: list | None = None) -> None:
    """Platform-independent sanity of the roofline math + peaks table —
    asserted on every gate run, TPU or not, so formula regressions fail CPU
    CI immediately."""
    for kind, pk in DEVICE_PEAKS.items():
        assert all(v > 0 for v in pk.values()), (kind, pk)
        assert pk["peak_int8"] >= pk["peak_flops"], (
            f"{kind}: int8 MXU peak below bf16 peak")
    synthetic = {
        "arch": "synthetic", "shape": "s", "mesh": "single", "n_devices": 4,
        "flops_per_device_corrected": 1e12, "bytes_per_device_corrected":
        1e9, "collective_bytes_corrected": 1e8, "model_flops_global": 3e12,
    }
    checks = [analyze_record(synthetic)]
    checks += [r for r in (rows or []) if "skipped" not in r]
    for a in checks:
        terms = (a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        assert all(t >= 0 and math.isfinite(t) for t in terms), a
        assert a["dominant"] in ("compute", "memory", "collective"), a
        assert math.isfinite(a["roofline_fraction"]), a
        assert a["roofline_fraction"] >= 0, a
    # the synthetic record is hand-checkable: compute 1s, memory ~1.22ms,
    # collective 2ms on v5e — compute-dominant with useful fraction 3/4
    a = checks[0]
    assert a["dominant"] == "compute", a
    assert abs(a["useful_ratio"] - 0.75) < 1e-9, a


def _gate_measurements() -> dict:
    """Time each Pallas serving backend on the gate shape; returns
    {backend: {us, achieved_int8_ops, fraction_of_peak}}. TPU only."""
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.kernels import dispatch
    from repro.models.serving import quantize_params_for_serving

    m, k, n = GATE_SHAPE
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    cfg = configs.reduced(configs.get_config("llama3-8b"))
    leaf = quantize_params_for_serving(
        {"wq": {"w": w}}, cfg, r=2.0, act_bits=8, pack_planes=True)["wq"]
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    peaks = device_peaks()
    out = {}
    for backend in GATE_THRESHOLDS:
        us = time_call(lambda b=backend: dispatch.serving_linear(x, leaf, b),
                       iters=5)
        ops_per_call = 2.0 * m * k * n
        achieved = ops_per_call / (us * 1e-6)
        out[backend] = {
            "us": round(us, 1),
            "achieved_int8_ops": achieved,
            "fraction_of_peak": achieved / peaks["peak_int8"],
        }
    return out


def gate(check: bool = True) -> dict:
    """The roofline CI gate. Returns (and saves) the gate record; raises
    SystemExit(1) on a threshold breach when ``check``."""
    from repro.kernels import ops as _kops

    rows = run()
    assert_invariants(rows)
    peaks = device_peaks()
    floors = gate_thresholds()
    record = {"device": peaks, "thresholds": floors,
              "shape": list(GATE_SHAPE)}
    if floors != GATE_THRESHOLDS:
        # make an overridden gate self-describing in the CI artifact
        record["floors_overridden_via"] = FLOORS_ENV
        print(f"[roofline-gate] floors overridden via ${FLOORS_ENV}: "
              f"{floors}")
    failures = []
    if _kops.on_tpu():
        meas = _gate_measurements()
        record["measurements"] = meas
        for backend, rec in meas.items():
            frac = rec["fraction_of_peak"]
            floor = floors[backend]
            line = (f"{backend}: {frac:.3f} of int8 peak "
                    f"(floor {floor:.2f}, {rec['us']:.0f} us)")
            print(f"[roofline-gate] {line}")
            if frac < floor:
                failures.append(line)
    else:
        record["skipped"] = ("no TPU — interpret-mode timings measure the "
                             "emulator; invariants asserted instead")
        print(f"[roofline-gate] {record['skipped']}")
    record["failures"] = failures
    save_json("roofline_gate.json", record)
    if check and failures:
        for f in failures:
            print(f"[roofline-gate] BELOW ROOFLINE FLOOR: {f}")
        raise SystemExit(1)
    if check:
        print("[roofline-gate] passed")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="run as the CI gate: fail when a Pallas backend "
                         "drops below its stated fraction of the int8 MXU "
                         "peak (TPU); off-TPU, assert analysis invariants "
                         "and skip the timing gate cleanly")
    args = ap.parse_args()
    if args.check:
        gate(check=True)
    else:
        print(markdown_table(run()))
