"""Roofline analysis from the dry-run artifacts.

Per (arch x shape) on the single-pod mesh:
    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_bytes / (chips x 50 GB/s ICI per link)

HLO_FLOPs/bytes are the probe-corrected per-device values x chips (XLA's
cost_analysis counts while-loop bodies once; the dry-run probes fold trip
counts back in — see launch/dryrun.py). MODEL_FLOPS = 6·N·D (train) /
2·N·D (inference) with N the MoE-active parameter count.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import RESULTS_DIR, emit, save_json

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def analyze_record(r: dict) -> dict | None:
    if r.get("skipped"):
        return {"arch": r["arch"], "shape": r["shape"],
                "skipped": r["skipped"]}
    n = r["n_devices"]
    # probe extrapolation can go slightly negative on near-zero terms
    flops_dev = max(r.get("flops_per_device_corrected",
                          r.get("flops_per_device", 0.0)), 0.0)
    bytes_dev = max(r.get("bytes_per_device_corrected",
                          r.get("bytes_per_device", 0.0)), 0.0)
    coll_dev = max(r.get("collective_bytes_corrected",
                         (r.get("collective_bytes_per_device") or {})
                         .get("total", 0.0)), 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = r.get("model_flops_global", 0.0)
    hlo_global = flops_dev * n
    out = {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "quant": r.get("quant", "none"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        # roofline fraction: the useful fraction of the bound set by the
        # dominant term (what fraction of ideal-compute time the step needs)
        "roofline_fraction": (model_flops / PEAK_FLOPS / n)
        / max(max(terms.values()), 1e-30),
    }
    return out


def run(paths=("dryrun_both.json", "dryrun_single.json")) -> list:
    t0 = time.perf_counter()
    rows, seen = [], set()
    for p in paths:
        full = os.path.join(RESULTS_DIR, p)
        if not os.path.exists(full):
            continue
        with open(full) as f:
            data = json.load(f)
        for r in data.get("records", []):
            if r.get("mesh") == "multi":
                continue
            key = (r["arch"], r["shape"])
            if key in seen:
                continue
            seen.add(key)
            a = analyze_record(r)
            if a:
                rows.append(a)
    save_json("roofline.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    live = [r for r in rows if "skipped" not in r]
    if live:
        worst = min(live, key=lambda r: r["roofline_fraction"])
        emit("roofline", us,
             f"{len(live)} cells; worst fraction "
             f"{worst['roofline_fraction']:.3f} ({worst['arch']} x "
             f"{worst['shape']})")
    else:
        emit("roofline", us, "no dry-run records yet — run launch/dryrun")
    return rows


def markdown_table(rows: list) -> str:
    live = [r for r in rows if "skipped" not in r]
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(live, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    skipped = [r for r in rows if "skipped" in r]
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                     f"{r['skipped']} | — | — |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
