"""Paper Fig. 4 / Fig. 16: MSE_RUQ / MSE_PANN at matched power, theory and
Monte Carlo, uniform and Gaussian."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import mse as m
from repro.core import power as pw


def run() -> dict:
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    d = 512
    rows = []
    for b in range(2, 9):
        budget = pw.p_mac_unsigned(b)
        bx, _ = m.optimal_bx_tilde(budget, d)
        r = pw.pann_r_for_budget(budget, bx)
        ratio_theory = m.mse_ratio_at_budget(b, d)
        mc_ruq_u = m.mc_mse_ruq(rng, d, b, b, n=2048)
        mc_pann_u = m.mc_mse_pann(rng, d, bx, r, n=2048)
        mc_ruq_g = m.mc_mse_ruq(rng, d, b, b, n=2048, dist="gauss")
        mc_pann_g = m.mc_mse_pann(rng, d, bx, r, n=2048, dist="gauss")
        rows.append({
            "ruq_bits": b, "power": budget, "opt_bx_tilde": bx,
            "r": round(r, 3),
            "ratio_theory": round(ratio_theory, 3),
            "ratio_mc_uniform": round(mc_ruq_u / mc_pann_u, 3),
            "ratio_mc_gaussian": round(mc_ruq_g / mc_pann_g, 3),
        })
    save_json("fig4_mse_ratio.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    lo = rows[0]
    emit("fig4_mse_ratio", us,
         f"2-bit ratio theory {lo['ratio_theory']} / "
         f"mc-gauss {lo['ratio_mc_gaussian']} (PANN wins when > 1)")
    return rows


if __name__ == "__main__":
    run()
