"""Forward-pass power (Giga bit-flips) for every assigned architecture under
the paper's schemes — the Fig.-1-style power axis, extended to the 10-arch
pool. Uses the analytic MAC counts (weight-MACs vs act-MACs split)."""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json
from repro import configs
from repro.core import costs
from repro.core import power as pw


def run() -> dict:
    t0 = time.perf_counter()
    shape = configs.SHAPES_BY_NAME["train_4k"]
    rows = []
    for arch in configs.ARCH_NAMES:
        cfg = configs.get_config(arch)
        macs = costs.network_macs(cfg, shape)
        per_tok = macs.scale(1.0 / (shape.seq_len * shape.global_batch))
        row = {"arch": arch,
               "weight_macs_per_token": f"{per_tok.weight_macs:.3e}",
               "act_macs_per_token": f"{per_tok.act_macs:.3e}"}
        for bits in [8, 4, 2]:
            signed = pw.giga(pw.network_power_bitflips(
                per_tok, scheme="signed", bits=bits))
            unsigned = pw.giga(pw.network_power_bitflips(
                per_tok, scheme="unsigned", bits=bits))
            # PANN at addition factor R=1 with the same activation width —
            # the multiplier-free power floor (accuracy at matched power is
            # what Tables 2-4 measure; here we show the power axis)
            pann = pw.giga(pw.network_power_bitflips(
                per_tok, scheme="pann", r=1.0, b_x_tilde=bits))
            row[f"G_bitflips_tok_signed_{bits}b"] = round(signed, 3)
            row[f"G_bitflips_tok_unsigned_{bits}b"] = round(unsigned, 3)
            row[f"G_bitflips_tok_pann_r1_{bits}b"] = round(pann, 3)
        rows.append(row)
    save_json("arch_power.json", rows)
    us = (time.perf_counter() - t0) * 1e6
    r0 = rows[3]
    emit("arch_power", us,
         f"llama3-8b/tok@4b: signed {r0['G_bitflips_tok_signed_4b']} -> "
         f"unsigned {r0['G_bitflips_tok_unsigned_4b']} -> "
         f"pann(R=1) {r0['G_bitflips_tok_pann_r1_4b']}")
    return rows


if __name__ == "__main__":
    run()
