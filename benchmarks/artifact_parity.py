"""Snapped-budget drift check — the accuracy price of the zero-copy
weight store, measured in theory score at EQUAL POWER.

The one-weight-store materialization (DESIGN.md §11, ``models.serving.
build_weight_store``) quantizes each module once at its maximal ladder
budget and realizes every narrower rung by dropping low bit-planes, so a
rung runs at the SNAPPED budget ``r_max / 2^shift`` (``core.pann.
view_shift``) rather than the exactly-planned R. This benchmark bounds
that drift per rung, in closed form (the retired per-rung "legacy"
quantizer materialized exact budgets; these invariants are why serving
does not need it):

  * ``power_ratio`` — realized snapped power / planned budget. Bounded by
    construction: the shift is the power of two NEAREST r_max/r, so
    r_snap/r_exact lies in [1/sqrt(2), sqrt(2)] and the per-MAC power
    (affine in R) moves by strictly less.
  * ``score_gap_rel`` — at the power the snapped rung ACTUALLY consumes,
    the best exact-R plan (Algorithm 1, theory backend) vs the snapped
    point's own theory score (Eq. 19 MSE). The snapped point serves
    ``r = pann_r_for_budget(p_snap, b)`` exactly, so any gap comes only
    from the planner re-picking b~x at the realized power — usually zero,
    never large. This is the equal-power comparison: same bit-flips,
    exact-R freedom vs the view's power-of-two grid.

``--check`` gates both as hard invariants (no committed baseline needed:
the bounds follow from the snapping rule, not from a snapshot).
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, save_json
from repro.core import mse as mse_theory
from repro.core import pann as pann_core
from repro.core import planner
from repro.core import power as pw
from repro.models.serving import LADDER_PLANE_COUNT

# hard invariants of nearest-power-of-two snapping (see module docstring):
# power moves by < sqrt(2); the equal-power score gap only reflects a b~x
# re-pick on the integer grid, empirically < 20% relative even on the
# 2-bit rung of a 2..6 ladder (the widest snap this repo ships)
MAX_POWER_RATIO = 2.0 ** 0.5
MAX_SCORE_GAP_REL = 0.20


def measure(bits_ladder=(2, 3, 4, 5, 6), d: float = 4096.0) -> list[dict]:
    plans = {b: planner.plan_with_theory(planner.budget_from_bits(b), d)
             for b in sorted({int(b) for b in bits_ladder})}
    r_max = max(p.r for p in plans.values())
    rows = []
    for bits, plan in sorted(plans.items()):
        shift = pann_core.view_shift(r_max, plan.r,
                                     LADDER_PLANE_COUNT - 1)
        r_snap = pann_core.snapped_r(r_max, shift)
        p_plan = plan.power_budget
        p_snap = pw.p_pann(r_snap, plan.b_x_tilde)
        # theory MSE of the point the view actually serves ...
        mse_snap = mse_theory.mse_pann(d, plan.b_x_tilde, r_snap)
        # ... vs the best exact-R plan at the SAME consumed power
        best_at_snap = planner.plan_with_theory(p_snap, d)
        mse_best = -best_at_snap.score
        rows.append({
            "rung_bits": bits,
            "b_x_tilde": plan.b_x_tilde,
            "r_exact": round(plan.r, 4),
            "plane_shift": shift,
            "r_snapped": round(r_snap, 4),
            "power_planned": round(p_plan, 3),
            "power_snapped": round(p_snap, 3),
            "power_ratio": round(p_snap / p_plan, 4),
            "mse_snapped": mse_snap,
            "mse_best_exact_at_equal_power": mse_best,
            "best_b_x_at_equal_power": best_at_snap.b_x_tilde,
            "score_gap_rel": round((mse_snap - mse_best) / mse_best, 4),
        })
    return rows


def check(rows: list[dict]) -> list[str]:
    failures = []
    for r in rows:
        ratio = r["power_ratio"]
        if not (1.0 / MAX_POWER_RATIO) < ratio < MAX_POWER_RATIO:
            failures.append(
                f"rung {r['rung_bits']}b: snapped power is {ratio:.3f}x the "
                f"planned budget — outside the (1/sqrt2, sqrt2) bound the "
                f"nearest-power-of-two snap guarantees")
        if r["score_gap_rel"] < -1e-9:
            failures.append(
                f"rung {r['rung_bits']}b: snapped point scores BETTER than "
                f"the best exact-R plan at equal power "
                f"(gap {r['score_gap_rel']:.4f}) — the planner is no longer "
                f"optimal over its own grid")
        if r["score_gap_rel"] > MAX_SCORE_GAP_REL:
            failures.append(
                f"rung {r['rung_bits']}b: equal-power theory-score gap "
                f"{r['score_gap_rel']:.1%} > {MAX_SCORE_GAP_REL:.0%} — the "
                f"snap costs real accuracy; widen the ladder so this rung "
                f"sits nearer a power-of-two of the top budget")
    top = max(rows, key=lambda r: r["rung_bits"])
    if top["plane_shift"] != 0 or top["power_ratio"] != 1.0:
        failures.append(
            f"max rung {top['rung_bits']}b is not served exactly "
            f"(shift={top['plane_shift']}, ratio={top['power_ratio']}) — "
            f"the store must BE the max rung")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ladder", default="2,3,4,5,6",
                    help="comma-separated rung bit budgets")
    ap.add_argument("--d", type=float, default=4096.0,
                    help="fan-in for the Eq. 19 theory MSE")
    ap.add_argument("--check", action="store_true",
                    help="fail on a snapping-bound or equal-power score-gap "
                         "breach (baseline-free hard invariants)")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    rows = measure([int(b) for b in args.ladder.split(",")], d=args.d)
    save_json("artifact_parity.json", rows)
    worst = max(rows, key=lambda r: r["score_gap_rel"])
    emit("artifact_parity", (time.perf_counter() - t0) * 1e6,
         f"{len(rows)} rungs; worst equal-power score gap "
         f"{worst['score_gap_rel']:.2%} at {worst['rung_bits']}b "
         f"(shift {worst['plane_shift']})")
    for r in rows:
        print(f"[artifact_parity] rung {r['rung_bits']}b: "
              f"R {r['r_exact']} -> {r['r_snapped']} (shift "
              f"{r['plane_shift']}), power x{r['power_ratio']}, "
              f"equal-power score gap {r['score_gap_rel']:.2%}")
    if args.check:
        failures = check(rows)
        if failures:
            for f in failures:
                print(f"[artifact_parity] FAIL: {f}")
            raise SystemExit(1)
        print("[artifact_parity] snapping bounds hold")
    return {"rows": rows}


if __name__ == "__main__":
    main()
