"""Encoder serving benchmark + the CI encoder correctness gate.

Covers the PR-10 workload: conv-stem frontends (vision patchify, speech
feature extractor) served through the quantized conv projection and the
batch-oriented ``EncodeEngine``. Two jobs:

  * timings — items/s per ladder rung for each reduced encoder arch.
    INFORMATIONAL only (CPU interpret-mode hosts are noisy); never gated.
  * ``--check`` — gate the platform-independent invariants against the
    committed baseline (benchmarks/baselines/encoder_bench.json):

      - conv parity is EXACT: ``dispatch.serving_conv`` bit-identical to
        the jnp int32 conv oracle on every backend (ref / fused / packed)
        AND on every rung VIEW of one weight store;
      - the engine's one-compiled-encode-step claim: exactly one jit cache
        entry after warming the whole ladder, zero growth after serving
        mixed-budget traffic (``assert_no_recompile``);
      - structural invariants (encoder token counts, conv role sets,
        per-item Gbit-flips per rung) match the baseline — refresh by
        copying benchmarks/results/encoder_bench.json over it when the
        geometry or cost model legitimately changes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit, save_json  # noqa: E402
from repro import configs  # noqa: E402
from repro.core import costs  # noqa: E402
from repro.data import pipeline  # noqa: E402
from repro.kernels import dispatch  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.models import serving  # noqa: E402
from repro.serve_engine import EncodeEngine, EncodeRequest  # noqa: E402

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "encoder_bench.json")
ARCHS = ("llama-3.2-vision-90b", "seamless-m4t-medium")
BACKENDS = ("ref", "fused:force", "packed:force")
LADDER = (2, 4, 6)


def _exact(a, b) -> dict:
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return {"exact": bool((a == b).all()),
            "max_abs_diff": float(np.abs(a - b).max())}


def _conv_parity(cfg, params, raw) -> dict:
    """serving_conv vs the int32 oracle: per backend on a single-point
    artifact, and per rung view of one plane-packed weight store."""
    out = {}
    spec0 = cfg.conv_stem[0]
    for backend in BACKENDS:
        sp = serving.quantize_params_for_serving(
            params, cfg, spec=serving.ServingQuantSpec(
                r=4.0, act_bits=6,
                pack_planes=backend.startswith("packed")))
        p = sp["conv_stem"]["s0"]
        y = dispatch.serving_conv(raw, p, spec0, backend)
        out[f"backend:{backend}"] = _exact(
            y, dispatch.serving_conv_oracle(raw, p, spec0))
    ws = serving.build_weight_store(
        params, cfg, {2: (2.0, 6), 6: (16.0, 6)},
        spec=serving.ServingQuantSpec(pack_planes=True))
    for rung, view in ws.views.items():
        p = view["conv_stem"]["s0"]
        y = dispatch.serving_conv(raw, p, spec0, "packed:force")
        out[f"view:{rung}b"] = _exact(
            y, dispatch.serving_conv_oracle(raw, p, spec0))
    return out


def _engine_run(cfg, params, raw) -> dict:
    eng = EncodeEngine(cfg, params, ladder_bits=LADDER, max_batch=2,
                       backend="ref", allocation="layerwise")
    eng.warmup()
    budgets = [2, 4, 6, 6, 2, 4]
    reqs = [EncodeRequest(uid=i, item=np.asarray(raw[i % raw.shape[0]]),
                          power_budget_bits=b)
            for i, b in enumerate(budgets)]
    t0 = time.perf_counter()
    responses = eng.encode(reqs)
    dt = time.perf_counter() - t0
    eng.assert_no_recompile()
    conv_roles = sorted(
        k for k in responses[0].metadata["per_module_gbitflips_per_token"]
        if k.startswith("conv."))
    return {
        "compilations_after_warmup": eng.compilations_after_warmup,
        "recompiled": False,
        "conv_roles": conv_roles,
        "encoder_tokens": costs.encoder_tokens(cfg),
        "gflips_per_item_by_rung": {
            str(b): round(float(eng.item_flips(b)) / 1e9, 6)
            for b in LADDER},
        "items_per_s": round(len(responses) / max(dt, 1e-9), 1),
        "rung_bits_served": sorted({r.rung_bits for r in responses}),
    }


def run(check: bool = False) -> dict:
    result = {}
    for arch in ARCHS:
        cfg = configs.reduced(configs.get_config(arch))
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        raw = jnp.asarray(pipeline.frontend_raw_stub(cfg, 2, step=0))
        t0 = time.perf_counter()
        parity = _conv_parity(cfg, params, raw)
        engine = _engine_run(cfg, params, raw)
        emit(f"encoder_bench/{arch}", (time.perf_counter() - t0) * 1e6,
             f"{engine['items_per_s']} items/s; "
             f"{len(parity)} parity checks")
        result[arch] = {"conv_parity": parity, "engine": engine}
    save_json("encoder_bench.json", result)
    return result


def check_baseline(result: dict, baseline_path: str = BASELINE
                   ) -> list[str]:
    failures = []
    # parity is EXACT by construction — gate it regardless of any baseline
    for arch, rec in result.items():
        for name, par in rec["conv_parity"].items():
            if not par["exact"]:
                failures.append(
                    f"{arch} conv parity {name}: NOT bit-identical "
                    f"(max abs diff {par['max_abs_diff']})")
        eng = rec["engine"]
        if eng["compilations_after_warmup"] != 1:
            failures.append(
                f"{arch}: {eng['compilations_after_warmup']} compilations "
                f"after warming the ladder (want exactly 1)")
    if not os.path.exists(baseline_path):
        failures.append(f"missing committed baseline {baseline_path}")
        return failures
    with open(baseline_path) as f:
        base = json.load(f)
    # structural invariants; throughput (items_per_s) is informational
    gated = ("conv_roles", "encoder_tokens", "gflips_per_item_by_rung",
             "rung_bits_served")
    for arch, brec in base.items():
        if arch.startswith("_"):
            continue
        if arch not in result:
            failures.append(f"baseline arch {arch} missing from run")
            continue
        eng, beng = result[arch]["engine"], brec["engine"]
        for key in gated:
            if eng[key] != beng[key]:
                failures.append(
                    f"{arch} {key} drifted from baseline: {eng[key]} != "
                    f"{beng[key]} — refresh {baseline_path} if intended")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="gate conv parity (EXACT), the no-recompile "
                         "claim, and the structural baseline")
    args = ap.parse_args(argv)
    result = run(check=args.check)
    if args.check:
        failures = check_baseline(result)
        if failures:
            for f in failures:
                print(f"[encoder_bench] FAIL: {f}")
            raise SystemExit(1)
        print("[encoder_bench] parity exact; baseline check passed")
    return result


if __name__ == "__main__":
    main()
