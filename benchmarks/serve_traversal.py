"""End-to-end power-accuracy traversal benchmark for repro.serve_engine.

Builds one ServeEngine with a ladder of equal-power PANN operating points,
then (a) sweeps each rung with a pinned request batch to measure tokens/sec
and estimated energy/token, and (b) replays a synthetic MIXED-budget request
stream to demonstrate per-request traversal in a single process (no
re-quantization, no recompilation — asserted, not just claimed).

    PYTHONPATH=src python benchmarks/serve_traversal.py --reduced --check
    PYTHONPATH=src python benchmarks/serve_traversal.py --reduced --check \
        --allocation layerwise

``--allocation layerwise`` sweeps the per-module PolicyTree rungs
(planner.allocate_layerwise) instead of the uniform ones, asserting each
rung's power parity with its uniform twin and its theory-score dominance
in the process; its results and baseline live in *_layerwise.json files so
the two allocations gate independently.

``--check`` gates against the committed baseline snapshot
(benchmarks/baselines/serve_traversal[_layerwise].json): any rung regressing
tokens/sec by more than 30% fails the run (CI uploads the fresh JSON as an
artifact). Refresh the baseline by copying the matching file from
benchmarks/results/ over it when the hardware or the engine legitimately
changes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import common  # noqa: E402
from repro import configs  # noqa: E402
from repro.configs.base import QuantConfig  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.serve_engine import Request, ServeEngine  # noqa: E402

REGRESSION_TOLERANCE = 0.30


def result_name(allocation: str) -> str:
    suffix = "_layerwise" if allocation == "layerwise" else ""
    return f"serve_traversal{suffix}.json"


def baseline_path(allocation: str) -> str:
    return os.path.join(os.path.dirname(__file__), "baselines",
                        result_name(allocation))


BASELINE = baseline_path("uniform")   # legacy alias (tests, callers)


def _make_requests(rng, cfg, n, prompt_len, gen, budgets):
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=gen,
                    power_budget_bits=budgets[i % len(budgets)])
            for i in range(n)]


def _timed_generate(engine, reqs, repeats=2):
    """Best-of-N wall time (the engine is warm; first call is not special).
    Also returns the rung switches of the LAST repeat alone, so callers
    report per-stream switching, not the engine's lifetime counter."""
    best, responses, last_switches = None, None, 0
    for _ in range(repeats):
        s0 = engine.rung_switches
        t0 = time.monotonic()
        responses = engine.generate(reqs)
        dt = time.monotonic() - t0
        last_switches = engine.rung_switches - s0
        best = dt if best is None else min(best, dt)
    n_tok = sum(len(r.tokens) for r in responses)
    return n_tok / max(best, 1e-9), responses, last_switches


def run(args) -> dict:
    cfg = configs.get_config(args.arch, quant=QuantConfig(mode="none"))
    if args.reduced:
        cfg = configs.reduced(cfg)
    ladder_bits = [int(b) for b in args.ladder.split(",")]
    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, ladder_bits=ladder_bits,
                         max_batch=args.batch,
                         max_len=args.prompt_len + args.gen,
                         allocation=args.allocation)
    engine.warmup()
    rng = np.random.default_rng(args.seed)
    total_macs = sum(m.macs for m in engine.profile)

    rungs = []
    for op in engine.ladder:
        reqs = _make_requests(rng, cfg, args.batch, args.prompt_len,
                              args.gen, [op.bits])
        tps, responses, _ = _timed_generate(engine, reqs)
        meta = responses[0].metadata
        row = {
            "bits": op.bits, "b_x_tilde": op.b_x_tilde, "r": round(op.r, 4),
            "power_per_weight_mac": op.power,
            "tok_per_s": round(tps, 1),
            "est_gbitflips_per_token": meta["est_gbitflips_per_token"],
        }
        if op.lw is not None:
            # the layerwise claims, asserted per sweep: same total power as
            # the uniform twin (1%), theory score never below it
            parity = op.lw.total_power / (op.power * total_macs)
            assert abs(parity - 1.0) <= 0.01, (op.bits, parity)
            assert op.lw.score >= op.lw.uniform_score, op.bits
            row.update({
                "power_vs_uniform": round(parity, 6),
                "score": round(op.lw.score, 6),
                "uniform_score": round(op.lw.uniform_score, 6),
            })
        rungs.append(row)
        common.emit(f"serve_traversal/rung{op.bits}b", 1e6 / max(tps, 1e-9),
                    f"tok/s={tps:.1f}")

    mixed_reqs = _make_requests(rng, cfg, args.requests, args.prompt_len,
                                args.gen, ladder_bits)
    tps, responses, mixed_switches = _timed_generate(engine, mixed_reqs)
    engine.assert_no_recompile()
    served_bits = sorted({r.rung_bits for r in responses})
    total_flips = sum(r.metadata["est_bitflips_total"] for r in responses)

    out = {
        "arch": cfg.name,
        "reduced": bool(args.reduced),
        "allocation": args.allocation,
        "batch": args.batch, "prompt_len": args.prompt_len, "gen": args.gen,
        "ladder": [r["bits"] for r in rungs],
        "rungs": rungs,
        "mixed_stream": {
            "requests": len(mixed_reqs),
            "tok_per_s": round(tps, 1),
            "rungs_served": served_bits,
            "rung_switches": mixed_switches,
            "est_gbitflips_total": total_flips / 1e9,
        },
        "compilations_after_warmup": engine.compilations_after_warmup,
    }
    path = common.save_json(result_name(args.allocation), out)
    print(f"[serve_traversal] wrote {path}")
    return out


def check_baseline(result: dict, baseline_path: str = BASELINE) -> list[str]:
    """Fail any rung whose tok/s regressed > REGRESSION_TOLERANCE."""
    with open(baseline_path) as f:
        base = json.load(f)
    base_rungs = {r["bits"]: r for r in base.get("rungs", [])}
    failures = []
    # symmetric drift check: a baseline rung missing from the result means
    # the gate's coverage silently shrank — fail that too
    missing = sorted(set(base_rungs) - {r["bits"] for r in result["rungs"]})
    for bits in missing:
        failures.append(
            f"rung {bits}b: in the baseline but not measured — ladder "
            f"drifted; refresh {baseline_path}")
    for r in result["rungs"]:
        b = base_rungs.get(r["bits"])
        if b is None:
            # a rung with no baseline is an ungated rung — fail loudly so
            # ladder drift forces a baseline refresh instead of a no-op gate
            failures.append(
                f"rung {r['bits']}b: no baseline entry — refresh "
                f"{baseline_path}")
            continue
        floor = (1.0 - REGRESSION_TOLERANCE) * b["tok_per_s"]
        if r["tok_per_s"] < floor:
            failures.append(
                f"rung {r['bits']}b: {r['tok_per_s']:.1f} tok/s < "
                f"{floor:.1f} (baseline {b['tok_per_s']:.1f} - 30%)")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ladder", default="2,3,4,6")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--allocation", default="uniform",
                    choices=["uniform", "layerwise"],
                    help="rung allocation to sweep; layerwise gates "
                         "against its own *_layerwise.json baseline")
    ap.add_argument("--check", action="store_true",
                    help="gate against the committed baseline snapshot")
    args = ap.parse_args(argv)

    result = run(args)
    if args.check:
        failures = check_baseline(result, baseline_path(args.allocation))
        if failures:
            for f in failures:
                print(f"[serve_traversal] REGRESSION: {f}")
            raise SystemExit(1)
        print("[serve_traversal] baseline check passed")
    return result


if __name__ == "__main__":
    main()
