"""Pallas kernel micro-bench (interpret mode on CPU — correctness-path
timing; real perf comes from the TPU dry-run roofline)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, time_call
from repro.kernels import ops, ref


def run() -> dict:
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    x = jnp.abs(jnp.asarray(rng.standard_normal((m, k)), jnp.float32))
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    packed = ops.pann_pack_weights(w, r=2.0)
    out = {}

    us = time_call(lambda: ops.pann_matmul(x, packed, act_bits=8,
                                           interpret=True))
    out["pann_matmul_fused"] = us
    emit("kernel_pann_matmul_fused", us, f"{m}x{k}x{n} int8 bitplane")

    us = time_call(lambda: ops.pann_matmul(x, packed, act_bits=8,
                                           mode="planes", interpret=True))
    out["pann_matmul_planes"] = us
    emit("kernel_pann_matmul_planes", us, "literal Eq.10 dataflow")

    x_q = jnp.asarray(rng.integers(0, 127, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    s_x = jnp.ones((m, 1), jnp.float32)
    s_w = jnp.ones((n,), jnp.float32)
    us = time_call(lambda: ops.unsigned_matmul(x_q, w_q, s_x, s_w,
                                               interpret=True))
    out["unsigned_matmul"] = us
    emit("kernel_unsigned_matmul", us, "Sec.4 split, int32 accum")

    us = time_call(lambda: ops.quantize_act(x, bits=8, interpret=True))
    out["quantize_act"] = us
    emit("kernel_quantize_act", us, "per-row scale + round + clip")

    us = time_call(lambda: ref.quantize_act_ref(x, 8))
    out["quantize_act_ref"] = us
    emit("kernel_quantize_act_ref", us, "jnp oracle")

    from repro.kernels.pann_matmul_packed import (pack_planes,
                                                  pann_matmul_packed)
    pp = pack_planes(packed["planes_pos"])
    pn = pack_planes(packed["planes_neg"])
    x_q = jnp.asarray(rng.integers(0, 128, (m, k)), jnp.int8)
    s_x = jnp.ones((m, 1), jnp.float32)
    us = time_call(lambda: pann_matmul_packed(
        x_q, pp, pn, s_x, packed["gamma"], interpret=True))
    out["pann_matmul_packed"] = us
    emit("kernel_pann_matmul_packed", us,
         f"{packed['n_planes']} planes at 1 bit/weight HBM")
    save_json("kernel_bench.json", out)
    return out


if __name__ == "__main__":
    run()
